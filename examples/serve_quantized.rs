//! Serving example: quantize a model into every serving format and serve a
//! batch of requests from each, printing a latency/throughput comparison —
//! the interactive version of the Table 2 bench.
//!
//!   cargo run --release --example serve_quantized [-- --model tiny --bits 4]

use guidedquant::cfg::PipelineConfig;
use guidedquant::cli::Args;
use guidedquant::coordinator::Pipeline;
use guidedquant::report::{f, Table};
use guidedquant::serve::{build_serving_model, generate_batch, ServeFormat};
use guidedquant::util::{human_bytes, Rng};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let model = args.get_or("model", "tiny").to_string();
    let bits = args.get_usize("bits", 4)? as u32;
    let requests = args.get_usize("requests", 6)?;
    let gen_tokens = args.get_usize("gen-tokens", 32)?;

    let pipeline = Pipeline::new(PipelineConfig {
        model: model.clone(),
        out_dir: "target/serve_example".into(),
        train_steps: 60,
        ..Default::default()
    })?;
    let mut ps = pipeline.init_params();
    println!("training {model} briefly so generations aren't pure noise ...");
    pipeline.train(&mut ps, pipeline.cfg.train_steps, 0)?;

    let mut rng = Rng::new(3);
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|_| (0..12).map(|_| rng.below(ps.cfg.vocab) as u32).collect())
        .collect();

    let mut table = Table::new(
        &format!("serving formats ({model}, {bits}-bit, {requests} reqs × {gen_tokens} tok)"),
        &["format", "tok/s", "p50_ms", "p99_ms", "weights", "kv"],
    );
    for format in [
        ServeFormat::Fp32,
        ServeFormat::UniformScalar,
        ServeFormat::NonUniformScalar,
        ServeFormat::Vector,
        ServeFormat::Trellis,
    ] {
        let m = build_serving_model(&ps, None, format, bits)?;
        let (_, stats) = generate_batch(&m, &prompts, gen_tokens, pipeline.cfg.workers);
        table.row(vec![
            format.name().into(),
            f(stats.tok_per_sec, 1),
            f(stats.p50_ms, 3),
            f(stats.p99_ms, 3),
            human_bytes(stats.weight_bytes as u64),
            human_bytes(stats.kv_bytes as u64),
        ]);
    }
    table.print();
    Ok(())
}
