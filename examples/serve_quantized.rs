//! Serving example: quantize a model into every serving format and serve a
//! batch of requests from each through the continuous-batching scheduler,
//! then sweep the batch width for one format to show the amortized-decode
//! win over the thread-per-sequence baseline — the interactive version of
//! the Table 2 bench.
//!
//!   cargo run --release --example serve_quantized [-- --model tiny --bits 4]

use guidedquant::cfg::{PipelineConfig, ServeConfig};
use guidedquant::cli::Args;
use guidedquant::coordinator::Pipeline;
use guidedquant::report::{f, Table};
use guidedquant::serve::{
    build_serving_model, generate_per_sequence, generate_scheduled, random_prompts, ServeFormat,
};
use guidedquant::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let model = args.get_or("model", "tiny").to_string();
    let bits = args.get_usize("bits", 4)? as u32;
    let requests = args.get_usize("requests", 8)?;
    let gen_tokens = args.get_usize("gen-tokens", 32)?;

    let pipeline = Pipeline::new(PipelineConfig {
        model: model.clone(),
        out_dir: "target/serve_example".into(),
        train_steps: 60,
        ..Default::default()
    })?;
    let mut ps = pipeline.init_params();
    println!("training {model} briefly so generations aren't pure noise ...");
    pipeline.train(&mut ps, pipeline.cfg.train_steps, 0)?;
    let workers = pipeline.cfg.workers;

    let prompts = random_prompts(ps.cfg.vocab, requests, 12, 3);

    // ---- every format through the scheduler at full batch width ---------
    let mut table = Table::new(
        &format!("serving formats ({model}, {bits}-bit, {requests} reqs × {gen_tokens} tok, scheduler)"),
        &["format", "tok/s", "p50_ms", "p99_ms", "ttft_p50", "weights", "kv"],
    );
    for format in [
        ServeFormat::Fp32,
        ServeFormat::UniformScalar,
        ServeFormat::NonUniformScalar,
        ServeFormat::Vector,
        ServeFormat::Trellis,
    ] {
        let m = build_serving_model(&ps, None, format, bits)?;
        let cfg = ServeConfig {
            max_batch: requests.max(1),
            max_queued: requests.max(1),
            ..ServeConfig::default()
        };
        let (_, stats) = generate_scheduled(&m, &prompts, gen_tokens, workers, cfg)?;
        table.row(vec![
            format.name().into(),
            f(stats.tok_per_sec, 1),
            f(stats.p50_ms, 3),
            f(stats.p99_ms, 3),
            f(stats.ttft_p50_ms, 3),
            human_bytes(stats.weight_bytes as u64),
            human_bytes(stats.kv_bytes as u64),
        ]);
    }
    table.print();

    // ---- batch-width sweep: scheduler vs thread-per-sequence -------------
    let m = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, bits)?;
    let mut sweep = Table::new(
        &format!("batch sweep (nonuniform {bits}-bit, {requests} reqs × {gen_tokens} tok)"),
        &["max_batch", "mode", "tok/s", "p50_ms", "queue_ms", "occupancy"],
    );
    let (_, base) = generate_per_sequence(&m, &prompts, gen_tokens, workers)?;
    sweep.row(vec![
        "-".into(),
        "per-seq".into(),
        f(base.tok_per_sec, 1),
        f(base.p50_ms, 3),
        f(0.0, 1),
        f(1.0, 1),
    ]);
    let mut width = 1usize;
    while width <= requests.max(1) {
        let cfg = ServeConfig {
            max_batch: width,
            max_queued: requests.max(1),
            ..ServeConfig::default()
        };
        let (_, s) = generate_scheduled(&m, &prompts, gen_tokens, workers, cfg)?;
        sweep.row(vec![
            width.to_string(),
            "scheduler".into(),
            f(s.tok_per_sec, 1),
            f(s.p50_ms, 3),
            f(s.queue_wait_ms, 1),
            f(s.batch_occupancy, 2),
        ]);
        width *= 2;
    }
    sweep.print();
    Ok(())
}
