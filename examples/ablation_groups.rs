//! Ablation example: sweep the GuidedQuant group count g and watch the
//! layer-wise objective (Eq. 7) and perplexity respond — the interactive
//! version of the Table 13 bench, on the tiny preset.
//!
//!   cargo run --release --example ablation_groups

use guidedquant::cfg::{PipelineConfig, QuantConfig, QuantMethod};
use guidedquant::coordinator::Pipeline;
use guidedquant::data::Split;
use guidedquant::report::{f, Table};

fn main() -> anyhow::Result<()> {
    let pipeline = Pipeline::new(PipelineConfig {
        model: "tiny".into(),
        out_dir: "target/ablation_example".into(),
        train_steps: 100,
        calib_batches: 6,
        eval_batches: 8,
        ..Default::default()
    })?;
    let mut ps = pipeline.init_params();
    println!("training tiny for {} steps ...", pipeline.cfg.train_steps);
    pipeline.train(&mut ps, pipeline.cfg.train_steps, 0)?;
    let stats = pipeline.calib(&ps, true)?;
    let fp = pipeline.perplexity(&ps, Split::Eval, "fwd_loss")?;

    let mut table = Table::new(
        &format!("GuidedQuant group sweep (tiny, LNQ 2-bit; fp32 ppl {fp:.3})"),
        &["groups", "ppl_eval", "Δ vs layer-wise"],
    );
    let mut base = None;
    for g in [0usize, 1, 2, 4] {
        let layers =
            pipeline.quantize(&ps, &stats, &QuantConfig::with(QuantMethod::Lnq, 2, g))?;
        let qps = pipeline.apply_quantized(&ps, &layers);
        let ppl = pipeline.perplexity(&qps, Split::Eval, "fwd_loss")?;
        if g == 0 {
            base = Some(ppl);
        }
        let delta = base.map(|b| ppl - b).unwrap_or(0.0);
        let label = if g == 0 { "layer-wise (no GQ)".to_string() } else { format!("g={g}") };
        table.row(vec![label, f(ppl, 3), f(delta, 3)]);
    }
    table.print();
    Ok(())
}
