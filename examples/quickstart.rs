//! Quickstart: quantize one synthetic linear layer with every scalar
//! method and print the layer-wise objective values — a 30-second tour of
//! the library's core API (no artifacts needed).
//!
//!   cargo run --release --example quickstart

use guidedquant::quant::gptq::Gptq;
use guidedquant::quant::grid::rtn_quantize;
use guidedquant::quant::guided::guided_quantize;
use guidedquant::quant::lnq::Lnq;
use guidedquant::quant::objective::proxy_loss;
use guidedquant::quant::squeezellm::{squeezellm_quantize, SqueezeLlm};
use guidedquant::quant::LayerQuantizer;
use guidedquant::tensor::ops::matmul_tn;
use guidedquant::tensor::Mat;
use guidedquant::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);
    let (n, d_in, d_out, bits) = (512usize, 64usize, 32usize, 2u32);

    // A synthetic "layer": correlated activations + weights, like a real
    // transformer linear sees.
    let x = Mat::randn(n, d_in, 1.0, &mut rng);
    let h = matmul_tn(&x, &x); // layer-wise Hessian H = X^T X
    let w = Mat::randn(d_in, d_out, 1.0, &mut rng);

    // Simulated end-loss output gradients -> per-group saliency Hessians
    // (in the full pipeline these come from the calib_stats artifact).
    let g = 4usize;
    let mut guided_hs = Vec::new();
    for k in 0..g {
        let mut xs = x.clone();
        for i in 0..n {
            let sal = (1.0 + (i % (k + 2)) as f32).sqrt();
            for v in xs.row_mut(i) {
                *v *= sal;
            }
        }
        guided_hs.push(matmul_tn(&xs, &xs));
    }

    println!("quantizing a {d_in}x{d_out} layer at {bits} bits\n");
    println!("{:<28}{:>16}", "method", "objective Δ");

    let report = |name: &str, w_hat: &Mat| {
        println!("{name:<28}{:>16.2}", proxy_loss(&h, &w, w_hat));
    };

    report("rtn", &rtn_quantize(&w, bits).w_hat);
    let sens = Mat::from_fn(d_in, d_out, |_, _| 1.0);
    report(
        "squeezellm (kmeans)",
        &squeezellm_quantize(&w, &sens, &SqueezeLlm::new(bits))?.w_hat,
    );
    report("gptq (uniform)", &Gptq::new(bits).quantize(&h, &w)?.w_hat);
    let lnq = Lnq::new(bits);
    report("lnq", &lnq.quantize(&h, &w)?.w_hat);
    report(
        "lnq + guidedquant (g=4)",
        &guided_quantize(&lnq, &guided_hs, &w)?.w_hat,
    );

    println!("\nlower is better; LNQ(+GQ) should win. Next: `make artifacts`");
    println!("then `cargo run --release --example end_to_end` for the full pipeline.");
    Ok(())
}
