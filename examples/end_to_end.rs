//! End-to-end driver (the repository's headline validation run):
//!
//!   1. trains a MiniLlama from scratch on the synthetic corpus — every
//!      Adam step executes the AOT `train_step` HLO artifact from Rust,
//!      and the loss curve is logged;
//!   2. collects calibration statistics (grouped Fisher Hessians via the
//!      L1 Pallas xtsx kernel inside `calib_stats`);
//!   3. quantizes the model at 2 bits with SqueezeLLM, LNQ, LNQ+GuidedQuant
//!      on the (layer, group) worker pool;
//!   4. evaluates perplexity through the shared `fwd_loss` artifact;
//!   5. serves batched requests from the quantized model and reports
//!      throughput/latency.
//!
//!   cargo run --release --example end_to_end [-- --model small --steps 200]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use guidedquant::cfg::{PipelineConfig, QuantConfig, QuantMethod};
use guidedquant::cli::Args;
use guidedquant::coordinator::Pipeline;
use guidedquant::data::Split;
use guidedquant::report::{f, Table};
use guidedquant::serve::{build_serving_model, generate_batch, ServeFormat};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let model = args.get_or("model", "small").to_string();
    let steps = args.get_usize("steps", if model == "tiny" { 150 } else { 250 })?;

    let cfg = PipelineConfig {
        model: model.clone(),
        out_dir: "target/e2e".into(),
        train_steps: steps,
        calib_batches: 8,
        eval_batches: 12,
        ..Default::default()
    };
    let pipeline = Pipeline::new(cfg)?;

    // ---- 1. train -----------------------------------------------------
    println!("== phase 1: training ({model}, {steps} steps via train_step artifact) ==");
    let mut ps = pipeline.init_params();
    let losses = pipeline.train(&mut ps, steps, (steps / 20).max(1))?;
    println!("loss curve (every {} steps):", (steps / 16).max(1));
    for (i, l) in losses.iter().enumerate().step_by((steps / 16).max(1)) {
        println!("  step {i:4}: {l:.4}");
    }

    // ---- 2. calibration -------------------------------------------------
    println!("\n== phase 2: calibration statistics (Pallas xtsx inside calib_stats) ==");
    let stats = pipeline.calib(&ps, true)?;
    println!(
        "accumulated {} batches, {} layers, cache {}",
        stats.batches,
        stats.layers.len(),
        guidedquant::util::human_bytes(stats.storage_bytes() as u64)
    );

    // ---- 3+4. quantize + evaluate ----------------------------------------
    println!("\n== phase 3/4: quantize (2-bit) + evaluate ==");
    let fp_eval = pipeline.perplexity(&ps, Split::Eval, "fwd_loss")?;
    let fp_shift = pipeline.perplexity(&ps, Split::EvalShift, "fwd_loss")?;
    let mut table = Table::new(
        "end-to-end results (2-bit weight-only scalar)",
        &["method", "avg_bits", "ppl_eval", "ppl_shift"],
    );
    table.row(vec!["original(fp32)".into(), "32".into(), f(fp_eval, 3), f(fp_shift, 3)]);
    for (name, method, groups) in [
        ("squeezellm", QuantMethod::SqueezeLlm, 0usize),
        ("lnq", QuantMethod::Lnq, 0),
        ("lnq+gquant", QuantMethod::Lnq, 4),
    ] {
        let layers = pipeline.quantize(&ps, &stats, &QuantConfig::with(method, 2, groups))?;
        let qps = pipeline.apply_quantized(&ps, &layers);
        table.row(vec![
            name.into(),
            f(pipeline.avg_bits(&layers), 2),
            f(pipeline.perplexity(&qps, Split::Eval, "fwd_loss")?, 3),
            f(pipeline.perplexity(&qps, Split::EvalShift, "fwd_loss")?, 3),
        ]);
    }
    table.print();
    table.save_csv("end_to_end").ok();

    // ---- 5. serve ---------------------------------------------------------
    println!("\n== phase 5: serving (non-uniform LUT format, 4-bit) ==");
    let serving = build_serving_model(&ps, Some(&stats), ServeFormat::NonUniformScalar, 4)?;
    let prompts = guidedquant::serve::random_prompts(serving.cfg.vocab, 4, 16, 1);
    let (outs, sstats) = generate_batch(&serving, &prompts, 32, pipeline.cfg.workers)?;
    println!(
        "served {} requests x 32 tokens: {:.1} tok/s (p50 {:.2} ms, p99 {:.2} ms, ttft_p50 {:.2} ms, batch {:.1}), weights {}",
        outs.len(),
        sstats.tok_per_sec,
        sstats.p50_ms,
        sstats.p99_ms,
        sstats.ttft_p50_ms,
        sstats.batch_occupancy,
        guidedquant::util::human_bytes(sstats.weight_bytes as u64)
    );
    println!("\nall five phases complete.");
    for (k, v) in pipeline.metrics.snapshot() {
        println!("  {k}: {v:.2}");
    }
    Ok(())
}
