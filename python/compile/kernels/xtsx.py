"""Pallas kernel: grouped weighted Gram accumulation H̄_k = X^T·Diag(s_k)·X.

This is GuidedQuant's compute hot spot (Algorithm 1, line 4): for every
linear layer the calibration pass reduces n activation rows into g+1 small
(d_in × d_in) Gram matrices. On the authors' GPUs this is a batched cuBLAS
GEMM; the TPU rethink (DESIGN.md §Hardware-Adaptation) tiles for VMEM:

  grid = (G, n // block_n)   # group-major, row-blocks innermost
  each program holds one (block_n × d_in) X tile, the (1 × block_n) weight
  slice and the full (d_in × d_in) f32 accumulator in VMEM, and feeds the
  MXU with a single (d_in × block_n) @ (block_n × d_in) block product.

VMEM budget at the paper-analog `small` preset (worst d_in = 512):
512·512·4B accumulator (1 MiB) + 256·512·4B tile (0.5 MiB) — far inside the
~16 MiB envelope; at real-LLM d_in the accumulator would be tiled 512² too.

MUST be lowered with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref):
    # Zero the accumulator when entering a fresh group (innermost dim restarts).
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (block_n, d_in)
    s = s_ref[...]  # (1, block_n)
    # Weighted block product on the MXU: (d_in, bn) @ (bn, d_in).
    xw = x * s[0][:, None]
    o_ref[...] += jnp.dot(x.T, xw, preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def xtsx(x: jnp.ndarray, s: jnp.ndarray, *, block_n: int = 256, interpret: bool = True) -> jnp.ndarray:
    """out[g] = X^T·Diag(s[g])·X via a Pallas grid over (groups, row blocks).

    x: (n, d_in) f32, s: (G, n) f32; n must be divisible by block_n.
    Returns (G, d_in, d_in) f32.
    """
    n, d_in = x.shape
    g = s.shape[0]
    if s.shape[1] != n:
        raise ValueError(f"s rows {s.shape} do not match x rows {n}")
    block_n = min(block_n, n)
    if n % block_n != 0:
        raise ValueError(f"n={n} not divisible by block_n={block_n}")
    grid = (g, n // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_in), lambda gi, j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda gi, j: (gi, j)),
        ],
        out_specs=pl.BlockSpec((1, d_in, d_in), lambda gi, j: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, d_in, d_in), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), s.astype(jnp.float32))
