"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every Pallas kernel in this package has a reference implementation here;
pytest (python/tests/test_kernels.py) asserts allclose between the two across
a hypothesis-driven sweep of shapes, and the AOT pipeline's kernel-demo
artifacts are validated against these before being written.
"""

import jax.numpy as jnp


def xtsx_ref(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Grouped weighted Gram matrices: out[g] = X^T · Diag(s[g]) · X.

    x: (n, d_in) activations, s: (G, n) non-negative per-sample weights.
    Returns (G, d_in, d_in). This is GuidedQuant's H̄_k (Algorithm 1, line 4)
    with s[k] the group-averaged squared output gradients; s = 1 gives the
    plain layer-wise Hessian H = X^T X.
    """
    x = x.astype(jnp.float32)
    s = s.astype(jnp.float32)
    return jnp.einsum("ni,gn,nj->gij", x, s, x, precision="highest")


def dequant_ref(codes: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Decode a LUT-coded weight matrix: W[i, j] = codebook[j, codes[i, j]]."""
    # codebook: (d_out, m); codes: (d_in, d_out) -> gather along m per column.
    gathered = jnp.take_along_axis(codebook, codes.T, axis=1)  # (d_out, d_in)
    return gathered.T.astype(jnp.float32)


def lut_matmul_ref(x: jnp.ndarray, codes: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Non-uniform-scalar (LUT) dequant-matmul: y = x @ dequant(codes, codebook).

    x: (n, d_in) f32, codes: (d_in, d_out) int32 in [0, m),
    codebook: (d_out, m) f32 per-output-channel codebooks.
    Returns (n, d_out) f32.
    """
    return jnp.matmul(x.astype(jnp.float32), dequant_ref(codes, codebook), precision="highest")


def diag_fisher_ref(x: jnp.ndarray, grad_z: jnp.ndarray) -> jnp.ndarray:
    """SqueezeLLM-style diagonal Fisher of one linear layer's weights.

    F_diag[k, j] = sum_i (g[i, j] * x[i, k])^2 = (x^2)^T @ (g^2).
    x: (n, d_in), grad_z: (n, d_out) -> (d_in, d_out).
    """
    x2 = jnp.square(x.astype(jnp.float32))
    g2 = jnp.square(grad_z.astype(jnp.float32))
    return jnp.matmul(x2.T, g2, precision="highest")


def group_saliency_ref(grad_z: jnp.ndarray, groups: int) -> jnp.ndarray:
    """Group-averaged squared output gradients s_k (Algorithm 1, line 2).

    grad_z: (n, d_out); channels are split into `groups` consecutive,
    equally-sized groups (d_out % groups == 0). Returns (groups, n).
    """
    n, d_out = grad_z.shape
    g2 = jnp.square(grad_z.astype(jnp.float32))
    g2 = g2.reshape(n, groups, d_out // groups)
    return jnp.mean(g2, axis=2).T
