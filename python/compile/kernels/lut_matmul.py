"""Pallas kernel: fused LUT dequant-matmul y = x @ dequant(codes, codebook).

The serving hot path for non-uniform scalar quantization (paper Table 2,
Any-Precision-LLM kernel analog). The CUDA version stages the per-channel
look-up table in shared memory; the TPU rethink keeps the codebook block
resident in VMEM, gathers the decoded weight tile with take_along_axis, and
issues one MXU matmul per output-channel tile:

  grid = (d_out // block_o,)
  per program: x (n × d_in) resident, codes tile (d_in × block_o),
  codebook tile (block_o × m); decode then (n × d_in) @ (d_in × block_o).

VMEM at the `small` preset (n=512, d_in=512, block_o=128, m=16):
x 1 MiB + decoded tile 0.25 MiB + codes tile 0.25 MiB — comfortable.

interpret=True only on this CPU image (Mosaic custom-calls cannot run here).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, codes_ref, cb_ref, o_ref):
    x = x_ref[...]            # (n, d_in)
    codes = codes_ref[...]    # (d_in, block_o)
    cb = cb_ref[...]          # (block_o, m)
    # Decode: w[i, j] = cb[j, codes[i, j]]  -> gather along the m axis.
    w = jnp.take_along_axis(cb, codes.T, axis=1).T  # (d_in, block_o)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_o", "interpret"))
def lut_matmul(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    codebook: jnp.ndarray,
    *,
    block_o: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (n, d_in) f32, codes: (d_in, d_out) int32, codebook: (d_out, m) f32.

    Returns (n, d_out) f32; d_out must be divisible by block_o.
    """
    n, d_in = x.shape
    d_in2, d_out = codes.shape
    if d_in2 != d_in:
        raise ValueError(f"codes d_in {d_in2} != x d_in {d_in}")
    m = codebook.shape[1]
    block_o = min(block_o, d_out)
    if d_out % block_o != 0:
        raise ValueError(f"d_out={d_out} not divisible by block_o={block_o}")
    grid = (d_out // block_o,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d_in), lambda j: (0, 0)),
            pl.BlockSpec((d_in, block_o), lambda j: (0, j)),
            pl.BlockSpec((block_o, m), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_o), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), codes.astype(jnp.int32), codebook.astype(jnp.float32))
