"""AOT pipeline: lower every L2 graph to HLO *text* artifacts + manifest.

HLO text (NOT lowered.compiler_ir("hlo") protos / .serialize()) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (proto.id() <= INT_MAX);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and its README.

Artifacts per model preset (default `small`), under artifacts/<model>/:
  fwd_loss.hlo.txt          (params..., tokens) -> (loss_sum,)
  fwd_loss_qa4kv4.hlo.txt   idem, activations+KV fake-quant W?A4KV4
  fwd_loss_qa4kv16.hlo.txt  idem, W?A4KV16
  train_step.hlo.txt        (params..., m..., v..., step, tokens) -> (loss, ...)
  calib_stats.hlo.txt       (params..., tokens) -> (loss, [hs, diagf] per linear)
  xtsx_demo.hlo.txt         (x, s) -> (hs,)              [L1 Pallas kernel]
  lut_matmul_demo.hlo.txt   (x, codes, codebook) -> (y,) [L1 Pallas kernel]
  manifest.txt              shapes + arg order, parsed by rust/src/runtime/

Usage: cd python && python -m compile.aot --out ../artifacts [--model small]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .config import BATCHES, DEFAULT_GROUPS, PRESETS
from .kernels.lut_matmul import lut_matmul
from .kernels.xtsx import xtsx


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


class ManifestWriter:
    """Line-based manifest (simple to parse from Rust without serde)."""

    def __init__(self):
        self.lines = []

    def kv(self, key, *vals):
        self.lines.append(" ".join([key, *map(str, vals)]))

    def artifact(self, name, inputs, outputs):
        self.kv("artifact", name)
        for nm, dt, shape in inputs:
            self.kv("  in", nm, dt, *shape)
        for nm, dt, shape in outputs:
            self.kv("  out", nm, dt, *shape)

    def write(self, path):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")


def lower_and_write(fn, arg_specs, out_path):
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def build(model_name: str, out_dir: str, groups: int, lr: float, verbose: bool = True):
    cfg = PRESETS[model_name]
    bc = BATCHES[model_name]
    mdir = os.path.join(out_dir, model_name)
    os.makedirs(mdir, exist_ok=True)

    pspecs = cfg.param_specs()
    param_args = [spec(s) for _, s in pspecs]
    tok = spec((bc.batch, bc.seq), jnp.int32)

    man = ManifestWriter()
    man.kv("model", cfg.name)
    man.kv("vocab", cfg.vocab)
    man.kv("d_model", cfg.d_model)
    man.kv("n_layers", cfg.n_layers)
    man.kv("n_heads", cfg.n_heads)
    man.kv("d_ff", cfg.d_ff)
    man.kv("batch", bc.batch)
    man.kv("seq", bc.seq)
    man.kv("groups", groups)
    man.kv("grad_scale", M.GRAD_SCALE)
    man.kv("lr", lr)
    for name, shape in pspecs:
        man.kv("param", name, *shape)
    for name, d_in, d_out in cfg.linear_specs():
        man.kv("linear", name, d_in, d_out)

    def log(name, nbytes):
        if verbose:
            print(f"  [{model_name}] {name}: {nbytes} chars")

    # --- fwd_loss -----------------------------------------------------------
    n = lower_and_write(
        lambda *a: M.fwd_loss(cfg, list(a[:-1]), a[-1]),
        [*param_args, tok],
        os.path.join(mdir, "fwd_loss.hlo.txt"),
    )
    man.artifact(
        "fwd_loss",
        [("params", "f32", ("...",)), ("tokens", "i32", (bc.batch, bc.seq))],
        [("loss_sum", "f32", ())],
    )
    log("fwd_loss", n)

    # --- fwd_loss_qa variants ------------------------------------------------
    for a_bits, kv_bits in [(4, 4), (4, 16), (8, 8)]:
        nm = f"fwd_loss_qa{a_bits}kv{kv_bits}"
        n = lower_and_write(
            lambda *a, ab=a_bits, kb=kv_bits: M.fwd_loss_qa(cfg, ab, kb, list(a[:-1]), a[-1]),
            [*param_args, tok],
            os.path.join(mdir, nm + ".hlo.txt"),
        )
        man.artifact(
            nm,
            [("params", "f32", ("...",)), ("tokens", "i32", (bc.batch, bc.seq))],
            [("loss_sum", "f32", ())],
        )
        log(nm, n)

    # --- train_step -----------------------------------------------------------
    sstep = spec((), jnp.float32)
    n = lower_and_write(
        lambda *a: M.train_step(
            cfg,
            lr,
            list(a[: len(param_args)]),
            list(a[len(param_args) : 2 * len(param_args)]),
            list(a[2 * len(param_args) : 3 * len(param_args)]),
            a[-2],
            a[-1],
        ),
        [*param_args, *param_args, *param_args, sstep, tok],
        os.path.join(mdir, "train_step.hlo.txt"),
    )
    man.artifact(
        "train_step",
        [
            ("params", "f32", ("...",)),
            ("m", "f32", ("...",)),
            ("v", "f32", ("...",)),
            ("step", "f32", ()),
            ("tokens", "i32", (bc.batch, bc.seq)),
        ],
        [("loss", "f32", ()), ("params_m_v_step", "f32", ("...",))],
    )
    log("train_step", n)

    # --- calib_stats ------------------------------------------------------------
    n = lower_and_write(
        lambda *a: M.calib_stats(cfg, groups, list(a[:-1]), a[-1]),
        [*param_args, tok],
        os.path.join(mdir, "calib_stats.hlo.txt"),
    )
    outs = [("loss_sum", "f32", ())]
    for name, d_in, d_out in cfg.linear_specs():
        outs.append((f"hs.{name}", "f32", (groups + 1, d_in, d_in)))
        outs.append((f"diagf.{name}", "f32", (d_in, d_out)))
    man.artifact(
        "calib_stats",
        [("params", "f32", ("...",)), ("tokens", "i32", (bc.batch, bc.seq))],
        outs,
    )
    log("calib_stats", n)

    # --- grad_taps (Fisher-structure analysis, Figs 3/4) ---------------------
    n = lower_and_write(
        lambda *a: M.grad_taps(cfg, list(a[:-1]), a[-1]),
        [*param_args, tok],
        os.path.join(mdir, "grad_taps.hlo.txt"),
    )
    outs = [("loss_sum", "f32", ())]
    for name, d_in, d_out in cfg.linear_specs():
        outs.append((f"x.{name}", "f32", (bc.tokens, d_in)))
        outs.append((f"g.{name}", "f32", (bc.tokens, d_out)))
    man.artifact(
        "grad_taps",
        [("params", "f32", ("...",)), ("tokens", "i32", (bc.batch, bc.seq))],
        outs,
    )
    log("grad_taps", n)

    # --- L1 kernel demo artifacts -------------------------------------------
    nrows = bc.tokens
    d = cfg.d_model
    n = lower_and_write(
        lambda x, s: (xtsx(x, s),),
        [spec((nrows, d)), spec((groups + 1, nrows))],
        os.path.join(mdir, "xtsx_demo.hlo.txt"),
    )
    man.artifact(
        "xtsx_demo",
        [("x", "f32", (nrows, d)), ("s", "f32", (groups + 1, nrows))],
        [("hs", "f32", (groups + 1, d, d))],
    )
    log("xtsx_demo", n)

    m_cb = 16  # 4-bit LUT
    n = lower_and_write(
        lambda x, c, cb: (lut_matmul(x, c, cb),),
        [spec((nrows, d)), spec((d, d), jnp.int32), spec((d, m_cb))],
        os.path.join(mdir, "lut_matmul_demo.hlo.txt"),
    )
    man.artifact(
        "lut_matmul_demo",
        [
            ("x", "f32", (nrows, d)),
            ("codes", "i32", (d, d)),
            ("codebook", "f32", (d, m_cb)),
        ],
        [("y", "f32", (nrows, d))],
    )
    log("lut_matmul_demo", n)

    man.write(os.path.join(mdir, "manifest.txt"))
    if verbose:
        print(f"  [{model_name}] manifest + {cfg.n_params()} params")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="all", choices=["all", *PRESETS])
    ap.add_argument("--groups", type=int, default=DEFAULT_GROUPS)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    models = list(PRESETS) if args.model == "all" else [args.model]
    for mn in models:
        print(f"lowering artifacts for model preset '{mn}' ...")
        build(mn, args.out, args.groups, args.lr)
    print("AOT done.")


if __name__ == "__main__":
    main()
