"""Model/config presets shared by the L2 model, the AOT pipeline and tests.

The Rust side (rust/src/cfg/presets.rs) mirrors these numbers exactly; the
artifact manifest (artifacts/<model>/manifest.txt) is the source of truth the
runtime checks against at load time.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_specs(self):
        """(name, shape) for every parameter, in the canonical flat order.

        Linear weights are stored as [d_in, d_out] (``Z = X @ W``), matching
        the paper's notation and the Rust param store.
        """
        d, ff, v = self.d_model, self.d_ff, self.vocab
        specs = [("tok_emb", (v, d))]
        for l in range(self.n_layers):
            p = f"layers.{l}."
            specs += [
                (p + "attn_norm", (d,)),
                (p + "wq", (d, d)),
                (p + "wk", (d, d)),
                (p + "wv", (d, d)),
                (p + "wo", (d, d)),
                (p + "mlp_norm", (d,)),
                (p + "wgate", (d, ff)),
                (p + "wup", (d, ff)),
                (p + "wdown", (ff, d)),
            ]
        specs += [("final_norm", (d,)), ("head", (d, v))]
        return specs

    def linear_specs(self):
        """(name, d_in, d_out) for every *quantizable* linear, flat order.

        These are the layers GuidedQuant operates on (7 per block, matching
        Llama's q/k/v/o/gate/up/down). Embedding/head stay fp.
        """
        d, ff = self.d_model, self.d_ff
        out = []
        for l in range(self.n_layers):
            p = f"layers.{l}."
            out += [
                (p + "wq", d, d),
                (p + "wk", d, d),
                (p + "wv", d, d),
                (p + "wo", d, d),
                (p + "wgate", d, ff),
                (p + "wup", d, ff),
                (p + "wdown", ff, d),
            ]
        return out

    def n_params(self) -> int:
        import math

        return sum(math.prod(s) for _, s in self.param_specs())


@dataclass(frozen=True)
class BatchConfig:
    batch: int
    seq: int

    @property
    def tokens(self) -> int:
        return self.batch * self.seq


# Paper-analog family (Llama-2-7B/13B/70B -> tiny/small/base); see DESIGN.md §2.
PRESETS = {
    "tiny": ModelConfig("tiny", vocab=512, d_model=128, n_layers=2, n_heads=4, d_ff=256),
    "small": ModelConfig("small", vocab=2048, d_model=256, n_layers=4, n_heads=8, d_ff=512),
    "base": ModelConfig("base", vocab=4096, d_model=512, n_layers=6, n_heads=8, d_ff=1024),
}

BATCHES = {
    "tiny": BatchConfig(batch=2, seq=64),
    "small": BatchConfig(batch=4, seq=128),
    "base": BatchConfig(batch=2, seq=128),
}

# Number of saliency groups g baked into the calib_stats artifact (paper: g=4
# for 7B/13B). The artifact emits g+1 Gram matrices per linear: index 0 is the
# unweighted H = X^T X (layer-wise objective), 1..g are the GuidedQuant H̄_k.
DEFAULT_GROUPS = 4

# Paper §3.2: gradients are scaled by a large constant before squaring to
# avoid underflow; we keep their value.
GRAD_SCALE = 1.0e3
