"""L2: MiniLlama — the paper-analog transformer, in JAX (build-time only).

A Llama-style decoder (RMSNorm, RoPE, SwiGLU MLP, untied head) whose seven
per-block linears (q/k/v/o/gate/up/down) are the quantization targets, exactly
mirroring the layers GuidedQuant operates on in Llama-2.

Everything here is lowered once by aot.py into HLO-text artifacts:
  * fwd_loss      — summed next-token cross-entropy (perplexity eval path)
  * fwd_loss_qa   — same with activation + KV-cache fake-quant (W&A eval)
  * train_step    — one Adam step (the Rust coordinator drives training)
  * calib_stats   — loss gradients tapped at every linear output, reduced to
                    GuidedQuant saliencies, grouped Hessians (via the Pallas
                    xtsx kernel) and the SqueezeLLM diagonal Fisher.

Parameters flow as a flat list of arrays in the canonical order of
config.ModelConfig.param_specs(); the Rust runtime feeds the same order.
"""

import functools

import jax
import jax.numpy as jnp

from .config import GRAD_SCALE, ModelConfig
from .kernels.ref import diag_fisher_ref, group_saliency_ref
from .kernels.xtsx import xtsx

LINEARS_PER_BLOCK = 7  # q, k, v, o, gate, up, down

# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0):
    """Flat list of f32 arrays in param_specs() order (scaled normal init)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(jax.random.normal(sub, shape, jnp.float32) * (fan_in**-0.5))
    return params


def unflatten(cfg: ModelConfig, flat):
    """Flat param list -> dict keyed by param name."""
    names = [n for n, _ in cfg.param_specs()]
    assert len(names) == len(flat), (len(names), len(flat))
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Model pieces
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, eps=1e-5):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def rope(x, theta: float):
    """Rotary embedding over (B, S, H, hd) with pairwise (even, odd) rotation."""
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _fake_quant_sym(x, bits: int):
    """Per-token (last-axis) symmetric uniform fake-quant, round-to-nearest."""
    if bits >= 16:
        return x
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    return jnp.round(x / scale).clip(-qmax - 1, qmax) * scale


def forward(cfg: ModelConfig, params, tokens, taps=None, a_bits: int = 16, kv_bits: int = 16):
    """Logits + (layer inputs X, linear outputs Z) for every linear.

    tokens: (B, S) int32. `taps` is an optional list of zero arrays (one per
    linear, shape (B, S, d_out)) added to each linear output; differentiating
    w.r.t. them yields the end-loss output gradients ∂ℓ/∂Z (paper Eq. 4).
    a_bits / kv_bits < 16 enable the activation / KV fake-quant used by the
    weight-and-activation eval artifact (QuaRot/SpinQuant setting).

    Returns (logits, xs, zs) with xs[i] the input activations of linear i.
    """
    p = unflatten(cfg, params)
    b, s = tokens.shape
    h = cfg.n_heads
    hd = cfg.head_dim

    def aq(x):
        return _fake_quant_sym(x, a_bits)

    xs, zs = [], []
    ti = 0

    def linear(x_in, w, record_x):
        nonlocal ti
        z = jnp.matmul(aq(x_in), w)
        if taps is not None:
            z = z + taps[ti]
        xs.append(record_x)
        zs.append(z)
        ti += 1
        return z

    x = p["tok_emb"][tokens]  # (B, S, d)
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.finfo(jnp.float32).min
    for l in range(cfg.n_layers):
        pre = f"layers.{l}."
        hpre = rmsnorm(x, p[pre + "attn_norm"])
        q = linear(hpre, p[pre + "wq"], hpre)
        k = linear(hpre, p[pre + "wk"], hpre)
        v = linear(hpre, p[pre + "wv"], hpre)
        q = rope(q.reshape(b, s, h, hd), cfg.rope_theta)
        k = rope(k.reshape(b, s, h, hd), cfg.rope_theta)
        v = v.reshape(b, s, h, hd)
        if kv_bits < 16:
            k = _fake_quant_sym(k, kv_bits)
            v = _fake_quant_sym(v, kv_bits)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, cfg.d_model)
        o = linear(ctx, p[pre + "wo"], ctx)
        x = x + o
        hpre2 = rmsnorm(x, p[pre + "mlp_norm"])
        g = linear(hpre2, p[pre + "wgate"], hpre2)
        u = linear(hpre2, p[pre + "wup"], hpre2)
        act = jax.nn.silu(g) * u
        dwn = linear(act, p[pre + "wdown"], act)
        x = x + dwn
    x = rmsnorm(x, p["final_norm"])
    logits = jnp.matmul(aq(x), p["head"])
    return logits, xs, zs


def loss_sum(cfg: ModelConfig, params, tokens, taps=None, a_bits: int = 16, kv_bits: int = 16):
    """Summed next-token cross-entropy over B×(S−1) positions."""
    logits, _, _ = forward(cfg, params, tokens, taps, a_bits, kv_bits)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll)


def fwd_loss(cfg: ModelConfig, params, tokens):
    return (loss_sum(cfg, params, tokens),)


def fwd_loss_qa(cfg: ModelConfig, a_bits: int, kv_bits: int, params, tokens):
    """W&A eval path: activations/KV fake-quantized in-graph (weights are
    fake-quantized on the Rust side before being fed)."""
    return (loss_sum(cfg, params, tokens, a_bits=a_bits, kv_bits=kv_bits),)


# ---------------------------------------------------------------------------
# Training (driven from Rust through the train_step artifact)
# ---------------------------------------------------------------------------


def train_step(cfg: ModelConfig, lr: float, params, m, v, step, tokens):
    """One Adam step on mean CE. Returns (loss, params', m', v', step+1)."""
    b, s = tokens.shape
    ntok = b * (s - 1)

    def mean_loss(ps):
        return loss_sum(cfg, ps, tokens) / float(ntok)

    loss, grads = jax.value_and_grad(mean_loss)(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    step = step + 1.0
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    new_p, new_m, new_v = [], [], []
    for pi, mi, vi, gi in zip(params, m, v, grads):
        mi = b1 * mi + (1.0 - b1) * gi
        vi = b2 * vi + (1.0 - b2) * jnp.square(gi)
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        new_p.append(pi - lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return (loss, *new_p, *new_m, *new_v, step)


# ---------------------------------------------------------------------------
# Calibration statistics (GuidedQuant Algorithm 1, lines 2 & 4)
# ---------------------------------------------------------------------------


def calib_stats(cfg: ModelConfig, groups: int, params, tokens, *, use_pallas: bool = True):
    """Per-linear quantization statistics for one calibration batch.

    For every quantizable linear (7 per block, flat order):
      hs    — (groups+1, d_in, d_in): index 0 is H = X^T X (layer-wise
              objective), 1..g are GuidedQuant's group-averaged H̄_k built
              from GRAD_SCALE-scaled end-loss output gradients.
      diagf — (d_in, d_out): SqueezeLLM diagonal Fisher of the weights.

    Returns (loss_sum, hs_0, diagf_0, hs_1, diagf_1, ...). The Rust driver
    accumulates these over calibration batches.
    """
    n_lin = cfg.n_layers * LINEARS_PER_BLOCK
    b, s = tokens.shape
    specs = cfg.linear_specs()
    taps = [jnp.zeros((b, s, d_out), jnp.float32) for _, _, d_out in specs]

    def tapped_loss(tps):
        logits, xs, _ = forward(cfg, params, tokens, tps)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(nll) / float(b * (s - 1)), xs

    loss, pullback, xs = jax.vjp(tapped_loss, taps, has_aux=True)
    grads = pullback(jnp.float32(1.0))[0]

    outs = [loss * float(b * (s - 1))]
    for i in range(n_lin):
        _, d_in, d_out = specs[i]
        x = xs[i].reshape(b * s, d_in)
        gz = grads[i].reshape(b * s, d_out) * GRAD_SCALE
        sal = group_saliency_ref(gz, groups)           # (g, n)
        ones = jnp.ones((1, b * s), jnp.float32)
        sall = jnp.concatenate([ones, sal], axis=0)    # (g+1, n)
        if use_pallas:
            hs = xtsx(x, sall)                         # L1 Pallas kernel
        else:
            from .kernels.ref import xtsx_ref

            hs = xtsx_ref(x, sall)
        outs.append(hs)
        outs.append(diag_fisher_ref(x, gz))
    return tuple(outs)


def grad_taps(cfg: ModelConfig, params, tokens):
    """Raw per-linear activations X and end-loss output gradients ∂ℓ/∂Z
    (GRAD_SCALE-scaled), flattened over the batch. Powers the Figure 3/4
    Fisher-structure analysis and the Rust cross-validation of calib_stats.

    Returns (loss_sum, x_0, g_0, x_1, g_1, ...).
    """
    b, s = tokens.shape
    specs = cfg.linear_specs()
    taps = [jnp.zeros((b, s, d_out), jnp.float32) for _, _, d_out in specs]

    def tapped_loss(tps):
        logits, xs, _ = forward(cfg, params, tokens, tps)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(nll) / float(b * (s - 1)), xs

    loss, pullback, xs = jax.vjp(tapped_loss, taps, has_aux=True)
    grads = pullback(jnp.float32(1.0))[0]
    outs = [loss * float(b * (s - 1))]
    for i, (_, d_in, d_out) in enumerate(specs):
        outs.append(xs[i].reshape(b * s, d_in))
        outs.append(grads[i].reshape(b * s, d_out) * GRAD_SCALE)
    return tuple(outs)


# ---------------------------------------------------------------------------
# Jit wrappers used by aot.py and tests
# ---------------------------------------------------------------------------


def jit_fwd_loss(cfg):
    return jax.jit(functools.partial(fwd_loss, cfg))


def jit_fwd_loss_qa(cfg, a_bits, kv_bits):
    return jax.jit(functools.partial(fwd_loss_qa, cfg, a_bits, kv_bits))


def jit_train_step(cfg, lr):
    return jax.jit(functools.partial(train_step, cfg, lr))


def jit_calib_stats(cfg, groups, use_pallas=True):
    return jax.jit(functools.partial(calib_stats, cfg, groups, use_pallas=use_pallas))
