"""AOT pipeline: artifacts exist, parse as HLO text, manifest is consistent,
and a lowered graph numerically round-trips through XLA compilation."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M
from compile.config import BATCHES, PRESETS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "tiny", "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)

EXPECTED = [
    "fwd_loss",
    "fwd_loss_qa4kv4",
    "fwd_loss_qa4kv16",
    "fwd_loss_qa8kv8",
    "train_step",
    "calib_stats",
    "xtsx_demo",
    "lut_matmul_demo",
]


@pytest.mark.parametrize("model", ["tiny", "small", "base"])
def test_all_artifacts_exist(model):
    for name in EXPECTED:
        path = os.path.join(ART, model, name + ".hlo.txt")
        assert os.path.exists(path), path
        head = open(path).read(200)
        assert "HloModule" in head, f"{path} is not HLO text"


def test_manifest_lists_params_and_linears():
    lines = open(os.path.join(ART, "tiny", "manifest.txt")).read().splitlines()
    cfg = PRESETS["tiny"]
    params = [l for l in lines if l.startswith("param ")]
    linears = [l for l in lines if l.startswith("linear ")]
    assert len(params) == len(cfg.param_specs())
    assert len(linears) == len(cfg.linear_specs())
    arts = [l.split()[1] for l in lines if l.startswith("artifact ")]
    assert set(EXPECTED) <= set(arts)


def test_manifest_shapes_match_config():
    lines = open(os.path.join(ART, "tiny", "manifest.txt")).read().splitlines()
    cfg = PRESETS["tiny"]
    got = {}
    for l in lines:
        parts = l.split()
        if parts[0] == "param":
            got[parts[1]] = tuple(int(x) for x in parts[2:])
    for name, shape in cfg.param_specs():
        assert got[name] == tuple(shape), name


def test_hlo_text_parses_and_has_expected_signature():
    """The artifact text must parse back into an HloModule whose entry
    signature matches (params..., tokens) -> (loss,). Numeric round-trip
    execution is covered by the Rust runtime integration tests (the actual
    consumer); jaxlib's private compile API is too version-dependent to pin
    here."""
    cfg = PRESETS["tiny"]
    text = open(os.path.join(ART, "tiny", "fwd_loss.hlo.txt")).read()
    comp = xc._xla.hlo_module_from_text(text)
    xcomp = xc.XlaComputation(comp.as_serialized_hlo_module_proto())
    shape = xcomp.program_shape()
    n_expected = len(cfg.param_specs()) + 1  # params + tokens
    assert len(shape.parameter_shapes()) == n_expected
    # Output is a 1-tuple containing the f32 scalar loss.
    result = shape.result_shape()
    assert result.is_tuple() and len(result.tuple_shapes()) == 1


def test_to_hlo_text_deterministic():
    cfg = PRESETS["tiny"]
    bc = BATCHES["tiny"]
    pspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.param_specs()]
    tok = jax.ShapeDtypeStruct((bc.batch, bc.seq), jnp.int32)
    lowered = jax.jit(lambda *a: M.fwd_loss(cfg, list(a[:-1]), a[-1])).lower(*pspecs, tok)
    t1 = aot.to_hlo_text(lowered)
    t2 = aot.to_hlo_text(lowered)
    assert t1 == t2
