"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; numpy.testing pins tolerances. These tests
are the correctness signal for everything the artifacts compute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lut_matmul import lut_matmul
from compile.kernels.xtsx import xtsx

jax.config.update("jax_platform_name", "cpu")


def rnd(rng, *shape):
    return np.asarray(rng.standard_normal(shape), np.float32)


# ---------------------------------------------------------------------------
# xtsx — grouped weighted Gram
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block_n=st.sampled_from([8, 16, 32]),
    d_in=st.sampled_from([4, 8, 24, 64]),
    g=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_xtsx_matches_ref(n_blocks, block_n, d_in, g, seed):
    rng = np.random.default_rng(seed)
    n = n_blocks * block_n
    x = rnd(rng, n, d_in)
    s = np.abs(rnd(rng, g, n))
    got = np.asarray(xtsx(jnp.asarray(x), jnp.asarray(s), block_n=block_n))
    want = np.asarray(ref.xtsx_ref(jnp.asarray(x), jnp.asarray(s)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_xtsx_identity_weights_is_gram():
    rng = np.random.default_rng(0)
    x = rnd(rng, 64, 16)
    s = np.ones((1, 64), np.float32)
    got = np.asarray(xtsx(jnp.asarray(x), jnp.asarray(s), block_n=32))[0]
    np.testing.assert_allclose(got, x.T @ x, rtol=1e-4, atol=1e-4)


def test_xtsx_output_is_symmetric_psd():
    rng = np.random.default_rng(1)
    x = rnd(rng, 128, 32)
    s = np.abs(rnd(rng, 3, 128))
    hs = np.asarray(xtsx(jnp.asarray(x), jnp.asarray(s), block_n=64))
    for h in hs:
        np.testing.assert_allclose(h, h.T, rtol=1e-5, atol=1e-5)
        evals = np.linalg.eigvalsh(h.astype(np.float64))
        assert evals.min() > -1e-3 * max(1.0, evals.max())


def test_xtsx_bf16_inputs_upcast():
    rng = np.random.default_rng(2)
    x = rnd(rng, 32, 8)
    s = np.abs(rnd(rng, 2, 32))
    got = np.asarray(xtsx(jnp.asarray(x, jnp.bfloat16), jnp.asarray(s), block_n=16))
    want = np.asarray(ref.xtsx_ref(jnp.asarray(x, jnp.bfloat16).astype(jnp.float32), jnp.asarray(s)))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_xtsx_rejects_bad_shapes():
    x = jnp.zeros((10, 4))
    with pytest.raises(ValueError):
        xtsx(x, jnp.zeros((1, 11)))
    with pytest.raises(ValueError):
        xtsx(x, jnp.zeros((1, 10)), block_n=3)


# ---------------------------------------------------------------------------
# lut_matmul — fused dequant matmul
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 4, 16]),
    d_in=st.sampled_from([8, 32]),
    o_blocks=st.integers(1, 3),
    block_o=st.sampled_from([8, 16]),
    bits=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_matmul_matches_ref(n, d_in, o_blocks, block_o, bits, seed):
    rng = np.random.default_rng(seed)
    d_out = o_blocks * block_o
    m = 2**bits
    x = rnd(rng, n, d_in)
    codes = rng.integers(0, m, (d_in, d_out)).astype(np.int32)
    cb = rnd(rng, d_out, m)
    got = np.asarray(lut_matmul(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(cb), block_o=block_o))
    want = np.asarray(ref.lut_matmul_ref(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(cb)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_lut_matmul_equals_dense_matmul_after_decode():
    rng = np.random.default_rng(3)
    x = rnd(rng, 8, 16)
    codes = rng.integers(0, 4, (16, 32)).astype(np.int32)
    cb = rnd(rng, 32, 4)
    w = np.asarray(ref.dequant_ref(jnp.asarray(codes), jnp.asarray(cb)))
    got = np.asarray(lut_matmul(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(cb), block_o=16))
    np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-5)


def test_dequant_ref_gathers_per_output_channel():
    codes = jnp.asarray([[0, 1], [1, 0]], jnp.int32)  # (d_in=2, d_out=2)
    cb = jnp.asarray([[10.0, 11.0], [20.0, 21.0]])  # (d_out=2, m=2)
    w = np.asarray(ref.dequant_ref(codes, cb))
    np.testing.assert_allclose(w, [[10.0, 21.0], [11.0, 20.0]])


# ---------------------------------------------------------------------------
# saliency / diag-Fisher reductions used by calib_stats
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 32]),
    g=st.sampled_from([1, 2, 4]),
    per=st.sampled_from([1, 3, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_group_saliency_matches_loop(n, g, per, seed):
    rng = np.random.default_rng(seed)
    gz = rnd(rng, n, g * per)
    got = np.asarray(ref.group_saliency_ref(jnp.asarray(gz), g))
    want = np.stack([np.mean(gz[:, k * per : (k + 1) * per] ** 2, axis=1) for k in range(g)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_diag_fisher_matches_loop():
    rng = np.random.default_rng(4)
    x, gz = rnd(rng, 16, 6), rnd(rng, 16, 3)
    got = np.asarray(ref.diag_fisher_ref(jnp.asarray(x), jnp.asarray(gz)))
    want = np.zeros((6, 3), np.float32)
    for i in range(16):
        want += np.square(np.outer(x[i], gz[i]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
