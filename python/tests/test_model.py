"""L2 model invariants: shapes, causality, loss behaviour, calib statistics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import GRAD_SCALE, PRESETS
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab, (2, 32)), jnp.int32)


def test_param_specs_cover_init(params):
    specs = CFG.param_specs()
    assert len(specs) == len(params)
    for (name, shape), p in zip(specs, params):
        assert tuple(p.shape) == tuple(shape), name


def test_linear_specs_count():
    assert len(CFG.linear_specs()) == CFG.n_layers * M.LINEARS_PER_BLOCK


def test_forward_shapes(params, tokens):
    logits, xs, zs = M.forward(CFG, params, tokens)
    b, s = tokens.shape
    assert logits.shape == (b, s, CFG.vocab)
    specs = CFG.linear_specs()
    assert len(xs) == len(zs) == len(specs)
    for (name, d_in, d_out), x, z in zip(specs, xs, zs):
        assert x.shape == (b, s, d_in), name
        assert z.shape == (b, s, d_out), name


def test_forward_is_causal(params, tokens):
    """Changing a future token must not change past logits."""
    logits, _, _ = M.forward(CFG, params, tokens)
    toks2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
    logits2, _, _ = M.forward(CFG, params, toks2)
    np.testing.assert_allclose(
        np.asarray(logits[:, :-1]), np.asarray(logits2[:, :-1]), rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(np.asarray(logits[:, -1]), np.asarray(logits2[:, -1]))


def test_initial_loss_near_uniform(params, tokens):
    b, s = tokens.shape
    loss = float(M.fwd_loss(CFG, params, tokens)[0]) / (b * (s - 1))
    assert abs(loss - np.log(CFG.vocab)) < 1.5


def test_taps_zero_do_not_change_loss(params, tokens):
    b, s = tokens.shape
    taps = [jnp.zeros((b, s, d_out), jnp.float32) for _, _, d_out in CFG.linear_specs()]
    l0 = float(M.loss_sum(CFG, params, tokens))
    l1 = float(M.loss_sum(CFG, params, tokens, taps))
    assert l0 == pytest.approx(l1, rel=1e-6)


def test_train_step_decreases_loss(params, tokens):
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    ts = M.jit_train_step(CFG, 1e-3)
    out = ts(params, m, v, jnp.float32(0), tokens)
    l0 = float(out[0])
    np_ = len(params)
    p1 = list(out[1 : 1 + np_])
    m1 = list(out[1 + np_ : 1 + 2 * np_])
    v1 = list(out[1 + 2 * np_ : 1 + 3 * np_])
    for _ in range(5):
        out = ts(p1, m1, v1, out[-1], tokens)
        p1 = list(out[1 : 1 + np_])
        m1 = list(out[1 + np_ : 1 + 2 * np_])
        v1 = list(out[1 + 2 * np_ : 1 + 3 * np_])
    assert float(out[0]) < l0


def test_fake_quant_roundtrip_high_bits_is_identity():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(M._fake_quant_sym(x, 16)), np.asarray(x))
    got8 = np.asarray(M._fake_quant_sym(x, 8))
    assert np.max(np.abs(got8 - np.asarray(x))) < 0.05


def test_fake_quant_reduces_levels():
    x = jnp.asarray(np.linspace(-1, 1, 101), jnp.float32).reshape(1, -1)
    got = np.asarray(M._fake_quant_sym(x, 3))
    assert len(np.unique(got)) <= 8


def test_qa_loss_degrades_gracefully(params, tokens):
    b, s = tokens.shape
    l16 = float(M.fwd_loss(CFG, params, tokens)[0])
    l8 = float(M.fwd_loss_qa(CFG, 8, 8, params, tokens)[0])
    l4 = float(M.fwd_loss_qa(CFG, 4, 4, params, tokens)[0])
    assert abs(l8 - l16) / l16 < 0.05
    assert l4 == pytest.approx(l16, rel=0.6)


class TestCalibStats:
    @pytest.fixture(scope="class")
    def stats(self, params, tokens):
        return M.jit_calib_stats(CFG, 2)(params, tokens)

    def test_output_count(self, stats):
        assert len(stats) == 1 + 2 * len(CFG.linear_specs())

    def test_loss_matches_fwd(self, stats, params, tokens):
        assert float(stats[0]) == pytest.approx(float(M.fwd_loss(CFG, params, tokens)[0]), rel=1e-5)

    def test_h0_is_plain_gram(self, stats, params, tokens):
        _, xs, _ = M.forward(CFG, params, tokens)
        for i, (name, d_in, _) in enumerate(CFG.linear_specs()):
            x = np.asarray(xs[i]).reshape(-1, d_in)
            h0 = np.asarray(stats[1 + 2 * i][0])
            np.testing.assert_allclose(h0, x.T @ x, rtol=2e-4, atol=2e-4, err_msg=name)

    def test_guided_hessians_match_manual_grads(self, stats, params, tokens):
        """H̄_k from the artifact graph == manual jax.grad computation."""
        b, s = tokens.shape
        specs = CFG.linear_specs()
        taps = [jnp.zeros((b, s, d_out), jnp.float32) for _, _, d_out in specs]

        def tl(tps):
            return M.loss_sum(CFG, params, tokens, tps) / (b * (s - 1))

        grads = jax.grad(tl)(taps)
        for i, (name, d_in, d_out) in enumerate(specs[:3]):
            gz = np.asarray(grads[i]).reshape(-1, d_out) * GRAD_SCALE
            _, xs, _ = M.forward(CFG, params, tokens)
            x = np.asarray(xs[i]).reshape(-1, d_in)
            sal = np.asarray(ref.group_saliency_ref(jnp.asarray(gz), 2))
            for k in range(2):
                want = (x * sal[k][:, None]).T @ x
                got = np.asarray(stats[1 + 2 * i][1 + k])
                np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3, err_msg=f"{name} g{k}")

    def test_diagf_nonnegative(self, stats):
        for i in range(len(CFG.linear_specs())):
            assert float(np.asarray(stats[2 + 2 * i]).min()) >= 0.0

    def test_pallas_and_ref_paths_agree(self, params, tokens):
        a = M.jit_calib_stats(CFG, 2, use_pallas=True)(params, tokens)
        b_ = M.jit_calib_stats(CFG, 2, use_pallas=False)(params, tokens)
        for i in (1, 3, 5):
            np.testing.assert_allclose(np.asarray(a[i]), np.asarray(b_[i]), rtol=2e-4, atol=2e-4)


class TestGradTaps:
    def test_output_structure_and_x_matches_forward(self, params, tokens):
        outs = M.grad_taps(CFG, params, tokens)
        specs = CFG.linear_specs()
        assert len(outs) == 1 + 2 * len(specs)
        logits, xs, _ = M.forward(CFG, params, tokens)
        b, s = tokens.shape
        for i, (name, d_in, d_out) in enumerate(specs):
            x = np.asarray(outs[1 + 2 * i])
            g = np.asarray(outs[2 + 2 * i])
            assert x.shape == (b * s, d_in), name
            assert g.shape == (b * s, d_out), name
            np.testing.assert_allclose(
                x, np.asarray(xs[i]).reshape(b * s, d_in), rtol=1e-5, atol=1e-5
            )

    def test_grads_consistent_with_calib_saliency(self, params, tokens):
        """Group-averaging grad_taps' G² must reproduce calib_stats' H̄."""
        outs = M.grad_taps(CFG, params, tokens)
        stats = M.jit_calib_stats(CFG, 2)(params, tokens)
        i = 0  # first linear
        x = np.asarray(outs[1])
        g = np.asarray(outs[2])
        sal = np.asarray(ref.group_saliency_ref(jnp.asarray(g), 2))
        want = (x * sal[0][:, None]).T @ x
        got = np.asarray(stats[1][1])
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
