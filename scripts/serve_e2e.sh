#!/usr/bin/env bash
# End-to-end smoke for the `gq serve --http` front-end.
#
# Boots the real release binary on the tiny preset (port 0 = kernel-picked,
# read back from the log), then drives every endpoint over real HTTP:
#   * /healthz and /metrics probes,
#   * one blocking completion,
#   * one streamed completion (chunk ordering + terminal [DONE] event,
#     token-for-token identical to the blocking response),
#   * a malformed body (400),
#   * a 12-request burst against max_batch=2/max_queued=2 (at least one
#     429, accepted requests still complete),
#   * a second server booted with --kv-dtype f16: its blocking completion
#     must be token-for-token identical to the f32 one (greedy argmax is
#     validated ULP-close in unit tests; here the end-to-end tokens must
#     agree) and its /metrics must report kv_dtype "f16" with halved
#     kv_bytes gauges relative to page capacity,
#   * a shared-prefix burst: a warm request donates its prompt's KV chunks,
#     a burst of same-prompt requests must answer token-for-token identical
#     with /metrics showing prefix_hits > 0 and prefill_tokens_saved > 0,
#     and a fourth server booted with --prefix-cache off must return the
#     same tokens (cache on/off bit-identity) with both gauges at 0,
#   * a mixed-precision burst against one --format anyprec server:
#     /v1/capabilities advertises precisions [2,3,4], per-request
#     "precision" is honored (responses echo the effective precision,
#     repeat requests at each precision are deterministic), an unsupported
#     precision answers a 400 with the structured v1 error envelope, and
#     /metrics' completed_by_precision counters sum to completed.
#
# All intermediate files land in ./serve-e2e/ so CI can upload them as an
# artifact when a step fails. Usage: scripts/serve_e2e.sh [path-to-gq]

set -euo pipefail

GQ=${1:-target/release/gq}
DIR=serve-e2e
rm -rf "$DIR"
mkdir -p "$DIR"
LOG="$DIR/server.log"

fail() {
    echo "FAIL: $*" >&2
    echo "---- server log ----" >&2
    cat "$LOG" >&2 || true
    exit 1
}

[ -x "$GQ" ] || { echo "FAIL: binary $GQ not found (run cargo build --release)" >&2; exit 1; }

"$GQ" serve --model tiny --format nonuniform --bits 4 \
    --http 127.0.0.1:0 --max-batch 2 --max-queued 2 >"$LOG" 2>&1 &
SERVER=$!
trap 'kill "$SERVER" 2>/dev/null || true; wait "$SERVER" 2>/dev/null || true' EXIT

ADDR=
for _ in $(seq 1 240); do
    ADDR=$(sed -n 's/^http: listening on //p' "$LOG" | head -n 1)
    [ -n "$ADDR" ] && break
    kill -0 "$SERVER" 2>/dev/null || fail "server exited during startup"
    sleep 0.25
done
[ -n "$ADDR" ] || fail "server never reported a listening address"
BASE="http://$ADDR"
echo "server up at $BASE"

# --- /healthz ---------------------------------------------------------------
curl -fsS "$BASE/healthz" >"$DIR/healthz.json"
jq -e '.status == "ok" and .engine_alive == true and .engine_restarts == 0' \
    "$DIR/healthz.json" >/dev/null \
    || fail "/healthz not ok / liveness fields wrong: $(cat "$DIR/healthz.json")"

# --- unknown route ----------------------------------------------------------
CODE=$(curl -s -o /dev/null -w '%{http_code}' "$BASE/nope")
[ "$CODE" = 404 ] || fail "unknown route returned $CODE, want 404"

# --- blocking completion ----------------------------------------------------
curl -fsS -X POST "$BASE/v1/completions" \
    -d '{"prompt": [1, 2, 3, 4], "max_tokens": 8}' >"$DIR/blocking.json"
jq -e '.tokens | length == 8' "$DIR/blocking.json" >/dev/null \
    || fail "blocking completion did not return 8 tokens: $(cat "$DIR/blocking.json")"

# --- streamed completion: chunk ordering + terminal event -------------------
curl -fsS -N -X POST "$BASE/v1/completions" \
    -d '{"prompt": [1, 2, 3, 4], "max_tokens": 8, "stream": true}' >"$DIR/stream.txt"
grep '^data: ' "$DIR/stream.txt" >"$DIR/events.txt"
N=$(wc -l <"$DIR/events.txt")
[ "$N" -eq 10 ] || fail "expected 10 SSE events (8 tokens + done + [DONE]), got $N"
[ "$(tail -n 1 "$DIR/events.txt")" = "data: [DONE]" ] || fail "stream did not end with [DONE]"
sed -n "$((N - 1))p" "$DIR/events.txt" | grep -q '"done":true' \
    || fail "penultimate stream event is not the done summary"
STREAMED=$(grep -o '"token":[0-9]*' "$DIR/events.txt" | cut -d: -f2 | paste -sd, -)
BLOCKING=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/blocking.json")
[ "$STREAMED" = "$BLOCKING" ] \
    || fail "streamed tokens [$STREAMED] differ from blocking tokens [$BLOCKING]"

# --- malformed body -> 400 --------------------------------------------------
CODE=$(curl -s -o "$DIR/bad.json" -w '%{http_code}' -X POST "$BASE/v1/completions" -d '{oops')
[ "$CODE" = 400 ] || fail "malformed body returned $CODE, want 400"
jq -e 'has("error")' "$DIR/bad.json" >/dev/null || fail "400 body carries no error"

# --- burst past max_queued -> 429s, accepted requests complete --------------
PIDS=()
for i in $(seq 1 12); do
    curl -s -o "$DIR/burst_body_$i.json" -w '%{http_code}\n' -X POST "$BASE/v1/completions" \
        -d '{"prompt": [5, 6, 7], "max_tokens": 512}' >"$DIR/burst_code_$i" &
    PIDS+=("$!")
done
for p in "${PIDS[@]}"; do
    wait "$p" || true
done
cat "$DIR"/burst_code_* >"$DIR/burst_codes"
N429=$(grep -cx 429 "$DIR/burst_codes" || true)
N200=$(grep -cx 200 "$DIR/burst_codes" || true)
echo "burst: $N200 served, $N429 rejected"
[ "$N429" -ge 1 ] || fail "no 429 in a 12-request burst: $(tr '\n' ' ' <"$DIR/burst_codes")"
[ "$N200" -ge 1 ] || fail "no burst request succeeded: $(tr '\n' ' ' <"$DIR/burst_codes")"
[ $((N429 + N200)) -eq 12 ] \
    || fail "unexpected status codes in burst: $(tr '\n' ' ' <"$DIR/burst_codes")"

# --- /metrics reflects the traffic ------------------------------------------
curl -fsS "$BASE/metrics" >"$DIR/metrics.json"
jq -e ".completed >= 2 and .rejected >= $N429
       and (.ttft_ms | has(\"p50\")) and (.token_ms | has(\"p99\"))
       and .kv_dtype == \"f32\"
       and has(\"kv_bytes\") and has(\"kv_allocated_bytes\")
       and .engine_restarts == 0 and .failed == 0
       and has(\"cancelled\") and has(\"timed_out\")" \
    "$DIR/metrics.json" >/dev/null \
    || fail "metrics missing expected fields: $(cat "$DIR/metrics.json")"

# --- f16 KV cache: greedy tokens match f32, gauges report the dtype ---------
LOG16="$DIR/server_f16.log"
"$GQ" serve --model tiny --format nonuniform --bits 4 --kv-dtype f16 \
    --http 127.0.0.1:0 --max-batch 2 --max-queued 2 >"$LOG16" 2>&1 &
SERVER16=$!
trap 'kill "$SERVER" "$SERVER16" 2>/dev/null || true
      wait "$SERVER" "$SERVER16" 2>/dev/null || true' EXIT

ADDR16=
for _ in $(seq 1 240); do
    ADDR16=$(sed -n 's/^http: listening on //p' "$LOG16" | head -n 1)
    [ -n "$ADDR16" ] && break
    kill -0 "$SERVER16" 2>/dev/null \
        || { LOG="$LOG16"; fail "f16 server exited during startup"; }
    sleep 0.25
done
[ -n "$ADDR16" ] || { LOG="$LOG16"; fail "f16 server never reported a listening address"; }
BASE16="http://$ADDR16"
echo "f16 server up at $BASE16"

curl -fsS -X POST "$BASE16/v1/completions" \
    -d '{"prompt": [1, 2, 3, 4], "max_tokens": 8}' >"$DIR/blocking_f16.json"
TOK16=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/blocking_f16.json")
[ "$TOK16" = "$BLOCKING" ] \
    || { LOG="$LOG16"; fail "f16 greedy tokens [$TOK16] differ from f32 tokens [$BLOCKING]"; }

curl -fsS "$BASE16/metrics" >"$DIR/metrics_f16.json"
jq -e '.kv_dtype == "f16" and .completed >= 1
       and has("kv_bytes") and has("kv_allocated_bytes")' \
    "$DIR/metrics_f16.json" >/dev/null \
    || { LOG="$LOG16"; fail "f16 metrics wrong: $(cat "$DIR/metrics_f16.json")"; }

# --- shared-prefix burst: prefix hits, prefill savings, on/off identity -----
# A 130-token prompt spans two page-aligned 64-position chunks; the warm
# request donates them on finish, so every burst request maps 128 cached
# positions copy-on-write and skips that much prefill. The off server is
# the control: same tokens, gauges pinned at zero.
boot_server() { # <logfile> <extra args...>; sets BOOT_ADDR and BOOTED_PID
    local log=$1
    shift
    "$GQ" serve --model tiny --format nonuniform --bits 4 \
        --http 127.0.0.1:0 --max-batch 4 --max-queued 8 "$@" >"$log" 2>&1 &
    BOOTED_PID=$!
    BOOT_ADDR=
    for _ in $(seq 1 240); do
        BOOT_ADDR=$(sed -n 's/^http: listening on //p' "$log" | head -n 1)
        [ -n "$BOOT_ADDR" ] && break
        kill -0 "$BOOTED_PID" 2>/dev/null \
            || { LOG="$log"; fail "server ($log) exited during startup"; }
        sleep 0.25
    done
    [ -n "$BOOT_ADDR" ] || { LOG="$log"; fail "server ($log) never reported an address"; }
}

LOGPC="$DIR/server_prefix.log"
LOGOFF="$DIR/server_prefix_off.log"
boot_server "$LOGPC"
SERVERPC=$BOOTED_PID
BASEPC="http://$BOOT_ADDR"
boot_server "$LOGOFF" --prefix-cache off
SERVEROFF=$BOOTED_PID
BASEOFF="http://$BOOT_ADDR"
trap 'kill "$SERVER" "$SERVER16" "$SERVERPC" "$SERVEROFF" 2>/dev/null || true
      wait 2>/dev/null || true' EXIT
echo "prefix servers up at $BASEPC (on) and $BASEOFF (off)"

PLONG="[$(for i in $(seq 0 129); do printf '%s,' $((i % 50 + 1)); done | sed 's/,$//')]"
PBODY="{\"prompt\": $PLONG, \"max_tokens\": 4}"

curl -fsS -X POST "$BASEPC/v1/completions" -d "$PBODY" >"$DIR/prefix_warm.json"
PWARM=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/prefix_warm.json")
[ -n "$PWARM" ] || { LOG="$LOGPC"; fail "prefix warm request returned no tokens"; }

PIDS=()
for i in $(seq 1 6); do
    curl -fsS -X POST "$BASEPC/v1/completions" -d "$PBODY" >"$DIR/prefix_burst_$i.json" &
    PIDS+=("$!")
done
for p in "${PIDS[@]}"; do
    wait "$p" || { LOG="$LOGPC"; fail "shared-prefix burst request failed"; }
done
for i in $(seq 1 6); do
    GOT=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/prefix_burst_$i.json")
    [ "$GOT" = "$PWARM" ] \
        || { LOG="$LOGPC"; fail "burst request $i tokens [$GOT] differ from warm [$PWARM]"; }
done

curl -fsS "$BASEPC/metrics" >"$DIR/metrics_prefix.json"
jq -e '.prefix_hits > 0 and .prefill_tokens_saved > 0 and .completed >= 7' \
    "$DIR/metrics_prefix.json" >/dev/null \
    || { LOG="$LOGPC"; fail "prefix gauges flat after burst: $(cat "$DIR/metrics_prefix.json")"; }
echo "prefix burst: $(jq -r '"\(.prefix_hits) hits, \(.prefill_tokens_saved) prefill tokens saved"' \
    "$DIR/metrics_prefix.json")"

curl -fsS -X POST "$BASEOFF/v1/completions" -d "$PBODY" >"$DIR/prefix_off.json"
POFF=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/prefix_off.json")
[ "$POFF" = "$PWARM" ] \
    || { LOG="$LOGOFF"; fail "--prefix-cache off tokens [$POFF] differ from on [$PWARM]"; }
curl -fsS "$BASEOFF/metrics" >"$DIR/metrics_prefix_off.json"
jq -e '.prefix_hits == 0 and .prefill_tokens_saved == 0 and .prefix_cached_pages == 0' \
    "$DIR/metrics_prefix_off.json" >/dev/null \
    || { LOG="$LOGOFF"; fail "off-server prefix gauges nonzero: $(cat "$DIR/metrics_prefix_off.json")"; }

# --- mixed-precision burst: one anyprec artifact serves 2/3/4-bit -----------
# One server, one bit-plane weight artifact; every request picks its own
# decode precision. Repeat requests at the same precision must be
# deterministic (greedy), the response must echo the effective precision,
# and the per-precision completion counters must add up to the total.
LOGAP="$DIR/server_anyprec.log"
boot_server_fmt() { # <logfile> <format> <extra args...>; sets BOOT_ADDR/BOOTED_PID
    local log=$1 fmt=$2
    shift 2
    "$GQ" serve --model tiny --format "$fmt" --bits 4 \
        --http 127.0.0.1:0 --max-batch 4 --max-queued 8 "$@" >"$log" 2>&1 &
    BOOTED_PID=$!
    BOOT_ADDR=
    for _ in $(seq 1 240); do
        BOOT_ADDR=$(sed -n 's/^http: listening on //p' "$log" | head -n 1)
        [ -n "$BOOT_ADDR" ] && break
        kill -0 "$BOOTED_PID" 2>/dev/null \
            || { LOG="$log"; fail "server ($log) exited during startup"; }
        sleep 0.25
    done
    [ -n "$BOOT_ADDR" ] || { LOG="$log"; fail "server ($log) never reported an address"; }
}
boot_server_fmt "$LOGAP" anyprec
SERVERAP=$BOOTED_PID
BASEAP="http://$BOOT_ADDR"
trap 'kill "$SERVER" "$SERVER16" "$SERVERPC" "$SERVEROFF" "$SERVERAP" 2>/dev/null || true
      wait 2>/dev/null || true' EXIT
echo "anyprec server up at $BASEAP"

curl -fsS "$BASEAP/v1/capabilities" >"$DIR/capabilities.json"
jq -e '.api == "v1" and .format == "anyprec"
       and .precisions == [2, 3, 4] and .default_precision == 4' \
    "$DIR/capabilities.json" >/dev/null \
    || { LOG="$LOGAP"; fail "capabilities wrong: $(cat "$DIR/capabilities.json")"; }

PIDS=()
for prec in 2 3 4; do
    for rep in 1 2; do
        curl -fsS -X POST "$BASEAP/v1/completions" \
            -d "{\"prompt\": [1, 2, 3, 4], \"max_tokens\": 8, \"precision\": $prec}" \
            >"$DIR/anyprec_p${prec}_$rep.json" &
        PIDS+=("$!")
    done
done
curl -fsS -X POST "$BASEAP/v1/completions" \
    -d '{"prompt": [1, 2, 3, 4], "max_tokens": 8}' >"$DIR/anyprec_default.json" &
PIDS+=("$!")
for p in "${PIDS[@]}"; do
    wait "$p" || { LOG="$LOGAP"; fail "mixed-precision burst request failed"; }
done
for prec in 2 3 4; do
    jq -e ".precision == $prec and (.tokens | length == 8)" \
        "$DIR/anyprec_p${prec}_1.json" >/dev/null \
        || { LOG="$LOGAP"; fail "precision $prec response wrong: $(cat "$DIR/anyprec_p${prec}_1.json")"; }
    T1=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/anyprec_p${prec}_1.json")
    T2=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/anyprec_p${prec}_2.json")
    [ "$T1" = "$T2" ] \
        || { LOG="$LOGAP"; fail "precision $prec nondeterministic: [$T1] vs [$T2]"; }
done
# The default request runs at the native 4-bit precision — bit-identical to
# an explicit precision=4 request and to the nonuniform LUT server's output.
TDEF=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/anyprec_default.json")
T4=$(jq -r '.tokens | map(tostring) | join(",")' "$DIR/anyprec_p4_1.json")
jq -e '.precision == 4' "$DIR/anyprec_default.json" >/dev/null \
    || { LOG="$LOGAP"; fail "default request did not run at native precision: $(cat "$DIR/anyprec_default.json")"; }
[ "$TDEF" = "$T4" ] \
    || { LOG="$LOGAP"; fail "default tokens [$TDEF] differ from explicit 4-bit [$T4]"; }
[ "$T4" = "$BLOCKING" ] \
    || { LOG="$LOGAP"; fail "anyprec 4-bit tokens [$T4] differ from lut server [$BLOCKING]"; }

# Unsupported precision: a 400 with the structured v1 envelope, and the
# legacy plain-string body behind the Accept fallback.
CODE=$(curl -s -o "$DIR/anyprec_bad.json" -w '%{http_code}' -X POST "$BASEAP/v1/completions" \
    -d '{"prompt": [1, 2], "max_tokens": 4, "precision": 7}')
[ "$CODE" = 400 ] || { LOG="$LOGAP"; fail "unsupported precision returned $CODE, want 400"; }
jq -e '.error.type == "invalid_request" and (.error.message | test("7"))
       and .error.retry_after_s == 0' "$DIR/anyprec_bad.json" >/dev/null \
    || { LOG="$LOGAP"; fail "400 body is not the v1 envelope: $(cat "$DIR/anyprec_bad.json")"; }
curl -s -o "$DIR/anyprec_bad_v0.json" -H 'Accept: application/vnd.gq.v0+json' \
    -X POST "$BASEAP/v1/completions" \
    -d '{"prompt": [1, 2], "max_tokens": 4, "precision": 7}'
jq -e '.error | type == "string"' "$DIR/anyprec_bad_v0.json" >/dev/null \
    || { LOG="$LOGAP"; fail "legacy Accept did not get a plain-string error: $(cat "$DIR/anyprec_bad_v0.json")"; }

# Per-precision completion counters add up to the total.
curl -fsS "$BASEAP/metrics" >"$DIR/metrics_anyprec.json"
jq -e '.completed == 7
       and .completed_by_precision["2"] == 2
       and .completed_by_precision["3"] == 2
       and .completed_by_precision["4"] == 3
       and ([.completed_by_precision[]] | add) == .completed
       and .precision_downshifts == 0' \
    "$DIR/metrics_anyprec.json" >/dev/null \
    || { LOG="$LOGAP"; fail "anyprec metrics wrong: $(cat "$DIR/metrics_anyprec.json")"; }
echo "mixed-precision burst: $(jq -c '.completed_by_precision' "$DIR/metrics_anyprec.json") of $(jq -r .completed "$DIR/metrics_anyprec.json") completions"

echo "serve-e2e OK"
