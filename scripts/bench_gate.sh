#!/usr/bin/env bash
# Micro-kernel regression gate: compare a measured BENCH_micro_kernels run
# against the committed baseline and fail on regressions.
#
# usage:
#   scripts/bench_gate.sh <measured.json> [baseline.json]
#   scripts/bench_gate.sh --update <measured.json> [baseline.json]
#
# Rows are keyed by kernel|format|batch|ctx|threads. Every baseline row is
# printed expected-vs-measured; only rows marked `"gated": true` in the
# baseline are ENFORCED. A gated row fails when its measured speedup falls
# below the row's floor:
#   floor = min_speedup                         (explicit bootstrap floor)
#         = speedup * (1 - GQ_BENCH_TOL)        (default tolerance 0.15)
# The committed baseline is a bootstrap (authored estimates with
# conservative explicit floors); refresh it from a trusted CI run with
# --update, which rewrites the measured numbers while preserving each
# row's gated/min_speedup annotations — rows that then carry no
# min_speedup are gated at the measured speedup minus the tolerance.
#
# Implemented with python3 (present on CI runners and dev boxes alike;
# the jq in CI only validates the JSON shape).
set -euo pipefail

UPDATE=0
if [ "${1:-}" = "--update" ]; then
  UPDATE=1
  shift
fi
MEASURED="${1:?usage: bench_gate.sh [--update] <measured.json> [baseline.json]}"
BASELINE="${2:-BENCH_micro_kernels.json}"
[ -f "$MEASURED" ] || { echo "bench_gate: measured file $MEASURED not found" >&2; exit 2; }
[ -f "$BASELINE" ] || { echo "bench_gate: baseline file $BASELINE not found" >&2; exit 2; }

GQ_BENCH_TOL="${GQ_BENCH_TOL:-0.15}" UPDATE="$UPDATE" \
  python3 - "$MEASURED" "$BASELINE" <<'PY'
import json, os, sys

measured_path, baseline_path = sys.argv[1], sys.argv[2]
tol = float(os.environ["GQ_BENCH_TOL"])
update = os.environ["UPDATE"] == "1"

with open(measured_path) as f:
    measured = json.load(f)
with open(baseline_path) as f:
    baseline = json.load(f)


def key(row):
    return "|".join(
        str(row.get(k, "-")) for k in ("kernel", "format", "batch", "ctx", "threads")
    )


meas = {key(r): r for r in measured.get("rows", [])}
base = {key(r): r for r in baseline.get("rows", [])}

if update:
    # Rewrite the baseline from the measured run, carrying each row's
    # gated/min_speedup annotations over by key. Measured-only rows join
    # ungated; baseline-only rows (kernels that no longer exist) drop.
    rows = []
    for k, r in meas.items():
        ann = base.get(k, {})
        out = dict(r)
        out["gated"] = bool(ann.get("gated", False))
        if "min_speedup" in ann:
            out["min_speedup"] = ann["min_speedup"]
        rows.append(out)
    doc = dict(measured)
    doc["rows"] = rows
    doc["provenance"] = "scripts/bench_gate.sh --update"
    with open(baseline_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"bench_gate: rewrote {baseline_path} from {measured_path} ({len(rows)} rows)")
    sys.exit(0)

failures = 0
missing = 0
print(f"bench_gate: {len(base)} baseline rows, tolerance {tol:.2f}")
print(f"{'':5} {'row':52} {'expected':>9} {'floor':>7} {'measured':>9}")
for k in sorted(base):
    b = base[k]
    gated = bool(b.get("gated", False))
    floor = b.get("min_speedup", b.get("speedup", 0.0) * (1.0 - tol))
    m = meas.get(k)
    tag = "gate" if gated else "info"
    if m is None:
        state = "MISSING"
        got = "-"
        if gated:
            failures += 1
        else:
            missing += 1
    else:
        sp = m.get("speedup", 0.0)
        got = f"{sp:9.2f}"
        if gated and sp < floor:
            state = "FAIL"
            failures += 1
        else:
            state = "ok"
    print(f"{tag:5} {k:52} {b.get('speedup', 0.0):9.2f} {floor:7.2f} {got:>9} {state}")
for k in sorted(set(meas) - set(base)):
    print(f"new   {k:52} {'-':>9} {'-':>7} {meas[k].get('speedup', 0.0):9.2f} "
          "(not in baseline; add via --update)")
if missing:
    print(f"bench_gate: {missing} ungated baseline row(s) absent from the measured run")
if failures:
    print(f"bench_gate: FAILED — {failures} gated row(s) regressed past their floor "
          f"(>{tol:.0%} below baseline unless a min_speedup floor applies)")
    sys.exit(1)
print("bench_gate: all gated rows within tolerance")
PY
