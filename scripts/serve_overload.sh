#!/usr/bin/env bash
# Overload end-to-end for the KV-governance serving stack (PR 8).
#
# Boots the release binary on the tiny preset with a deliberately small
# KV budget (--kv-budget-mb 1) and drives it past its capacity three
# different ways:
#
#   * flood     - a long-prompt flood: more worst-case KV cost in flight
#                 than the budget can hold. Admission must gate on cost,
#                 brownouts may clamp max_tokens (degraded: true), the
#                 supervisor may preempt-and-requeue — but
#                 kv_allocated_bytes must NEVER exceed kv_budget_bytes,
#                 /healthz must stay 200 throughout, and every request
#                 must resolve as either a bit-identical 200 (a degraded
#                 200 is a bit-identical PREFIX) or a 429 whose
#                 Retry-After is computed (1..60s), never a hang.
#   * slowloris - clients that trickle their request bodies byte by byte.
#                 Each stall pins only its own connection thread: parallel
#                 normal requests and health probes are served promptly,
#                 and the slow bodies still complete with 200s.
#   * burst     - a mixed-deadline burst behind a long-running request:
#                 tight timeout_ms values are shed up front (429 with
#                 Retry-After) or answered with partial "timeout" output;
#                 generous ones complete. Nothing hangs.
#
# After every scenario the server must still serve tokens bit-identical
# to an unloaded baseline server.
#
# All intermediate files land in ./serve-overload/ so CI can upload them
# on failure. Usage: scripts/serve_overload.sh [path-to-gq]
#   OVERLOAD_SCENARIO=flood|slowloris|burst|all (default all)

set -euo pipefail

GQ=${1:-target/release/gq}
SCENARIO=${OVERLOAD_SCENARIO:-all}
DIR=serve-overload
rm -rf "$DIR"
mkdir -p "$DIR"
LOG="$DIR/boot.log"

PIDS=()
cleanup() {
    for p in "${PIDS[@]:-}"; do
        kill "$p" 2>/dev/null || true
        wait "$p" 2>/dev/null || true
    done
}
trap cleanup EXIT

fail() {
    echo "FAIL: $*" >&2
    echo "---- server log ($LOG) ----" >&2
    cat "$LOG" >&2 || true
    exit 1
}

[ -x "$GQ" ] || { echo "FAIL: binary $GQ not found (run cargo build --release)" >&2; exit 1; }

# boot <name> [extra serve flags ...]: start a server, wait for its
# address. Sets LOG, SERVER, ADDR, BASE.
boot() {
    local name=$1
    shift
    LOG="$DIR/$name.log"
    "$GQ" serve --model tiny --format nonuniform --bits 4 \
        --http 127.0.0.1:0 "$@" >"$LOG" 2>&1 &
    SERVER=$!
    PIDS+=("$SERVER")
    ADDR=
    for _ in $(seq 1 240); do
        ADDR=$(sed -n 's/^http: listening on //p' "$LOG" | head -n 1)
        [ -n "$ADDR" ] && break
        kill -0 "$SERVER" 2>/dev/null || fail "$name server exited during startup"
        sleep 0.25
    done
    [ -n "$ADDR" ] || fail "$name server never reported a listening address"
    BASE="http://$ADDR"
    echo "[$name] server up at $BASE"
}

stop() {
    kill "$SERVER" 2>/dev/null || true
    wait "$SERVER" 2>/dev/null || true
}

tokens_of() {
    jq -r '.tokens | map(tostring) | join(",")' "$1"
}

# A 429 must carry a computed Retry-After inside the 1..60s clamp.
assert_retry_after() { # assert_retry_after <headers-file> <what>
    local ra
    ra=$(sed -n 's/^[Rr]etry-[Aa]fter: *//p' "$1" | head -n 1 | tr -d '\r')
    [ -n "$ra" ] || fail "$2: 429 without a Retry-After header"
    [ "$ra" -ge 1 ] && [ "$ra" -le 60 ] \
        || fail "$2: Retry-After $ra outside the 1..60s clamp"
}

# The unloaded request every scenario replays to prove the server still
# serves bit-identical tokens after the overload.
PROMPT='{"prompt": [1, 2, 3, 4], "max_tokens": 8}'

assert_baseline_tokens() { # assert_baseline_tokens <name>
    curl -fsS -X POST "$BASE/v1/completions" -d "$PROMPT" >"$DIR/$1_after.json" \
        || fail "$1: post-overload request did not get a 200"
    local got
    got=$(tokens_of "$DIR/$1_after.json")
    [ "$got" = "$REF" ] || fail "$1: post-overload tokens [$got] differ from baseline [$REF]"
}

want_scenario() {
    [ "$SCENARIO" = all ] || [ "$SCENARIO" = "$1" ]
}

flood_body() { # flood_body <i> — a distinct ~200-token prompt per client
    jq -nc --argjson i "$1" \
        '{prompt: [range(200) | ((. * 7 + $i * 31) % 500) + 1], max_tokens: 64}'
}

N_FLOOD=12

# --- baseline: unloaded reference tokens -------------------------------------
# No KV budget here: this server is the unloaded oracle for every
# bit-identity assertion below, including one reference output per flood
# prompt (served one at a time, zero pressure).
boot baseline
curl -fsS -X POST "$BASE/v1/completions" -d "$PROMPT" >"$DIR/baseline.json"
REF=$(tokens_of "$DIR/baseline.json")
[ -n "$REF" ] || fail "baseline returned no tokens"
echo "baseline tokens: $REF"
for i in $(seq 1 "$N_FLOOD"); do
    flood_body "$i" >"$DIR/flood_req_$i.json"
    curl -fsS -X POST "$BASE/v1/completions" -d @"$DIR/flood_req_$i.json" \
        >"$DIR/flood_ref_$i.json" || fail "baseline: flood reference $i failed"
done
stop

# --- flood: long-prompt flood against a 1 MB KV budget -----------------------
if want_scenario flood; then
    boot flood --kv-budget-mb 1 --max-batch 4 --max-queued 8
    FLOOD_PIDS=()
    for i in $(seq 1 "$N_FLOOD"); do
        (
            curl -s --max-time 120 -D "$DIR/flood_h_$i.txt" -o "$DIR/flood_b_$i.json" \
                -w '%{http_code}' -X POST "$BASE/v1/completions" \
                -d @"$DIR/flood_req_$i.json" >"$DIR/flood_c_$i.txt"
        ) &
        FLOOD_PIDS+=($!)
    done
    # While the flood is in flight: the budget is a hard ceiling and the
    # health probe must keep answering.
    for _ in $(seq 1 40); do
        if curl -fsS "$BASE/metrics" >"$DIR/flood_metrics.json" 2>/dev/null; then
            jq -e '.kv_allocated_bytes <= .kv_budget_bytes' "$DIR/flood_metrics.json" >/dev/null \
                || fail "flood: kv_allocated_bytes exceeded kv_budget_bytes: $(cat "$DIR/flood_metrics.json")"
        fi
        curl -fsS -o /dev/null "$BASE/healthz" || fail "flood: healthz went dark under load"
        sleep 0.1
    done
    for p in "${FLOOD_PIDS[@]}"; do
        wait "$p" || fail "flood: a client worker exited abnormally (hung request?)"
    done
    SERVED=0
    for i in $(seq 1 "$N_FLOOD"); do
        CODE=$(cat "$DIR/flood_c_$i.txt")
        case "$CODE" in
        200)
            # Under pressure a request may be browned out (degraded: true,
            # clamped length) — but whatever was served must be an exact
            # prefix of the unloaded reference output.
            jq -e --slurpfile ref "$DIR/flood_ref_$i.json" \
                '(.tokens == ($ref[0].tokens[0:(.tokens | length)]))
                 and ((.degraded == true) or (.tokens == $ref[0].tokens))' \
                "$DIR/flood_b_$i.json" >/dev/null \
                || fail "flood: request $i diverged from the unloaded reference: $(cat "$DIR/flood_b_$i.json")"
            SERVED=$((SERVED + 1))
            ;;
        429)
            assert_retry_after "$DIR/flood_h_$i.txt" "flood request $i"
            ;;
        *)
            fail "flood: request $i resolved with unexpected status $CODE: $(cat "$DIR/flood_b_$i.json")"
            ;;
        esac
    done
    [ "$SERVED" -ge 1 ] || fail "flood: every request was shed"
    echo "[flood] $SERVED/$N_FLOOD served, rest shed with sane Retry-After"
    curl -fsS "$BASE/metrics" >"$DIR/flood_final_metrics.json"
    jq -e '.kv_allocated_bytes <= .kv_budget_bytes' "$DIR/flood_final_metrics.json" >/dev/null \
        || fail "flood: post-flood allocation exceeds budget"
    assert_baseline_tokens flood
    stop
    echo "[flood] OK"
fi

# --- slowloris: trickled request bodies don't wedge the server ---------------
if want_scenario slowloris; then
    boot slowloris --kv-budget-mb 1 --max-batch 2 --max-queued 4
    HOST=${ADDR%:*}
    PORT=${ADDR##*:}
    SLOW_BODY='{"prompt": [1, 2, 3, 4], "max_tokens": 8}'
    slow_writer() { # slow_writer <i> — trickle the body 4 bytes / 150 ms
        local i=$1 out="$DIR/slowloris_resp_$1.txt"
        exec 3<>"/dev/tcp/$HOST/$PORT" || return 1
        printf 'POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: %d\r\nConnection: close\r\n\r\n' \
            "${#SLOW_BODY}" >&3
        local j
        for ((j = 0; j < ${#SLOW_BODY}; j += 4)); do
            printf '%s' "${SLOW_BODY:j:4}" >&3
            sleep 0.15
        done
        cat <&3 >"$out"
        exec 3>&- 3<&-
    }
    SLOW_PIDS=()
    for i in 1 2 3; do
        slow_writer "$i" &
        SLOW_PIDS+=($!)
    done
    # While three connections trickle: normal traffic must be unaffected.
    sleep 0.3
    curl -fsS --max-time 5 "$BASE/healthz" >/dev/null \
        || fail "slowloris: healthz queued behind trickled bodies"
    curl -fsS --max-time 10 -X POST "$BASE/v1/completions" -d "$PROMPT" \
        >"$DIR/slowloris_parallel.json" \
        || fail "slowloris: a parallel normal request must be served promptly"
    GOT=$(tokens_of "$DIR/slowloris_parallel.json")
    [ "$GOT" = "$REF" ] || fail "slowloris: parallel tokens [$GOT] differ from baseline [$REF]"
    for p in "${SLOW_PIDS[@]}"; do
        wait "$p" || fail "slowloris: a slow writer failed"
    done
    for i in 1 2 3; do
        head -n 1 "$DIR/slowloris_resp_$i.txt" | grep -q ' 200 ' \
            || fail "slowloris: trickled request $i was not served: $(head -n 1 "$DIR/slowloris_resp_$i.txt")"
        SLOW_TOKS=$(grep -o '"tokens":[^]]*]' "$DIR/slowloris_resp_$i.txt" | head -n 1 | tr -cd '0-9,')
        [ "$SLOW_TOKS" = "$REF" ] \
            || fail "slowloris: trickled tokens [$SLOW_TOKS] differ from baseline [$REF]"
    done
    assert_baseline_tokens slowloris
    stop
    echo "[slowloris] OK"
fi

# --- burst: mixed deadlines behind a long request — nothing hangs ------------
if want_scenario burst; then
    boot burst --kv-budget-mb 1 --max-batch 1 --max-queued 4
    # Occupy the single lane with a long request. 380 tokens keeps its
    # worst-case KV cost (3 + 380 positions = 6 chunks) under the high
    # watermark, so it is admitted rather than refused.
    curl -s --max-time 120 -X POST "$BASE/v1/completions" \
        -d '{"prompt": [9, 8, 7], "max_tokens": 380}' >"$DIR/burst_long.json" &
    LONG_PID=$!
    sleep 0.1
    DEADLINES=(1 5 50 200 1000 5000 0 0) # 0 => no timeout_ms field
    BURST_PIDS=()
    for k in "${!DEADLINES[@]}"; do
        T=${DEADLINES[$k]}
        if [ "$T" = 0 ]; then
            BODY='{"prompt": [2, 4, 6], "max_tokens": 16}'
        else
            BODY=$(jq -nc --argjson t "$T" '{prompt: [2, 4, 6], max_tokens: 16, timeout_ms: $t}')
        fi
        (
            curl -s --max-time 120 -D "$DIR/burst_h_$k.txt" -o "$DIR/burst_b_$k.json" \
                -w '%{http_code}' -X POST "$BASE/v1/completions" -d "$BODY" \
                >"$DIR/burst_c_$k.txt"
        ) &
        BURST_PIDS+=($!)
    done
    for p in "${BURST_PIDS[@]}" "$LONG_PID"; do
        wait "$p" || fail "burst: a client worker exited abnormally (hung request?)"
    done
    for k in "${!DEADLINES[@]}"; do
        CODE=$(cat "$DIR/burst_c_$k.txt")
        case "$CODE" in
        200)
            jq -e '.finish_reason == "length" or .finish_reason == "timeout"' \
                "$DIR/burst_b_$k.json" >/dev/null \
                || fail "burst: request $k (timeout ${DEADLINES[$k]}ms) wrong shape: $(cat "$DIR/burst_b_$k.json")"
            ;;
        429)
            assert_retry_after "$DIR/burst_h_$k.txt" "burst request $k"
            ;;
        *)
            fail "burst: request $k resolved with unexpected status $CODE"
            ;;
        esac
    done
    jq -e '.tokens | length == 380' "$DIR/burst_long.json" >/dev/null \
        || fail "burst: the long request must complete in full: $(head -c 300 "$DIR/burst_long.json")"
    curl -fsS "$BASE/metrics" >"$DIR/burst_metrics.json"
    echo "[burst] shed_predicted_deadline=$(jq '.shed_predicted_deadline' "$DIR/burst_metrics.json")"
    assert_baseline_tokens burst
    stop
    echo "[burst] OK"
fi

echo "serve-overload OK (scenario: $SCENARIO)"
