#!/usr/bin/env bash
# Chaos end-to-end for the fault-tolerant serving stack.
#
# Boots the release binary on the tiny preset with a deterministic fault
# injected via GQ_FAULT=<site>:<nth> (see rust/src/util/fault.rs), drives
# real HTTP traffic into the fault, and asserts the supervision contract:
#
#   * step-panic    - an engine-step panic on a single lane answers 500,
#                     the engine does NOT restart, and the next request
#                     returns bit-identical greedy tokens.
#   * nan-logits    - a poisoned (all-NaN) logit row fails only that
#                     request (500), never serves garbage tokens.
#   * engine-stall  - a 1.5s stall in one decode step delays but never
#                     corrupts output.
#   * slow-client   - client-side trouble: a stalled SSE chunk write, a
#                     mid-stream client hang-up (lane cancelled, KV pages
#                     freed), and an expired per-request deadline
#                     (finish_reason "timeout" with partial output).
#   * kv-exhaust    - a spurious KV-exhaustion report at one admission
#                     check sheds exactly that request with a 429 whose
#                     Retry-After is computed (1..60s); the next request
#                     is served normally.
#   * slow-read     - one request body read stalls 1s on its own
#                     connection thread; the response is late but
#                     bit-identical and health probes never queue behind
#                     it.
#   * prefix-evict  - the shared-prefix index is force-cleared while a
#                     lane borrowing cached pages is mid-decode; the
#                     borrower's own page refs keep it bit-identical and
#                     the server stays healthy.
#
# After every fault the server must keep serving tokens bit-identical to
# the fault-free baseline, and kv_bytes must return to the idle baseline.
#
# All intermediate files land in ./serve-chaos/ so CI can upload them on
# failure. Usage: scripts/serve_chaos.sh [path-to-gq]
#   CHAOS_SCENARIO=step-panic|nan-logits|engine-stall|slow-client|
#   kv-exhaust|slow-read|prefix-evict|all (default all) selects one
#   scenario for CI matrix fan-out.

set -euo pipefail

GQ=${1:-target/release/gq}
SCENARIO=${CHAOS_SCENARIO:-all}
DIR=serve-chaos
rm -rf "$DIR"
mkdir -p "$DIR"
LOG="$DIR/boot.log"

PIDS=()
cleanup() {
    for p in "${PIDS[@]:-}"; do
        kill "$p" 2>/dev/null || true
        wait "$p" 2>/dev/null || true
    done
}
trap cleanup EXIT

fail() {
    echo "FAIL: $*" >&2
    echo "---- server log ($LOG) ----" >&2
    cat "$LOG" >&2 || true
    exit 1
}

[ -x "$GQ" ] || { echo "FAIL: binary $GQ not found (run cargo build --release)" >&2; exit 1; }

# boot <name> [KEY=VALUE ...]: start a server (faults via env), wait for
# its address. Sets LOG, SERVER, BASE.
boot() {
    local name=$1
    shift
    LOG="$DIR/$name.log"
    env "$@" "$GQ" serve --model tiny --format nonuniform --bits 4 \
        --http 127.0.0.1:0 --max-batch 2 --max-queued 4 >"$LOG" 2>&1 &
    SERVER=$!
    PIDS+=("$SERVER")
    local addr=
    for _ in $(seq 1 240); do
        addr=$(sed -n 's/^http: listening on //p' "$LOG" | head -n 1)
        [ -n "$addr" ] && break
        kill -0 "$SERVER" 2>/dev/null || fail "$name server exited during startup"
        sleep 0.25
    done
    [ -n "$addr" ] || fail "$name server never reported a listening address"
    BASE="http://$addr"
    echo "[$name] server up at $BASE"
}

stop() {
    kill "$SERVER" 2>/dev/null || true
    wait "$SERVER" 2>/dev/null || true
}

tokens_of() {
    jq -r '.tokens | map(tostring) | join(",")' "$1"
}

# poll_metrics <jq-predicate> <description>
poll_metrics() {
    for _ in $(seq 1 120); do
        curl -fsS "$BASE/metrics" >"$DIR/poll.json" 2>/dev/null || true
        if jq -e "$1" "$DIR/poll.json" >/dev/null 2>&1; then
            return 0
        fi
        sleep 0.25
    done
    fail "timed out waiting for: $2 ($(cat "$DIR/poll.json" 2>/dev/null))"
}

# The fault-free request every scenario replays to prove the server still
# serves bit-identical greedy tokens.
PROMPT='{"prompt": [1, 2, 3, 4], "max_tokens": 8}'

assert_baseline_tokens() { # assert_baseline_tokens <name>
    curl -fsS -X POST "$BASE/v1/completions" -d "$PROMPT" >"$DIR/$1_after.json" \
        || fail "$1: post-fault request did not get a 200"
    local got
    got=$(tokens_of "$DIR/$1_after.json")
    [ "$got" = "$REF" ] || fail "$1: post-fault tokens [$got] differ from baseline [$REF]"
}

want_scenario() {
    [ "$SCENARIO" = all ] || [ "$SCENARIO" = "$1" ]
}

# --- baseline: fault-free reference tokens -----------------------------------
boot baseline
curl -fsS -X POST "$BASE/v1/completions" -d "$PROMPT" >"$DIR/baseline.json"
REF=$(tokens_of "$DIR/baseline.json")
[ -n "$REF" ] || fail "baseline returned no tokens"
echo "baseline tokens: $REF"
stop

# --- step-panic: single-lane engine panic -> 500, no restart -----------------
if want_scenario step-panic; then
    boot step-panic GQ_FAULT=step-panic:3
    CODE=$(curl -s -o "$DIR/step-panic_hit.json" -w '%{http_code}' \
        -X POST "$BASE/v1/completions" -d "$PROMPT")
    [ "$CODE" = 500 ] || fail "step-panic: poisoned request returned $CODE, want 500"
    jq -e 'has("error")' "$DIR/step-panic_hit.json" >/dev/null \
        || fail "step-panic: 500 body carries no error"
    curl -fsS "$BASE/healthz" >"$DIR/step-panic_healthz.json"
    jq -e '.status == "ok" and .engine_alive == true and .engine_restarts == 0' \
        "$DIR/step-panic_healthz.json" >/dev/null \
        || fail "step-panic: single-lane fault must not restart: $(cat "$DIR/step-panic_healthz.json")"
    poll_metrics '.failed >= 1 and .kv_bytes == 0' "failed counter + kv release"
    assert_baseline_tokens step-panic
    stop
    echo "[step-panic] OK"
fi

# --- nan-logits: poisoned logit row -> 500, never garbage tokens -------------
if want_scenario nan-logits; then
    boot nan-logits GQ_FAULT=nan-logits:4
    CODE=$(curl -s -o "$DIR/nan-logits_hit.json" -w '%{http_code}' \
        -X POST "$BASE/v1/completions" -d "$PROMPT")
    [ "$CODE" = 500 ] || fail "nan-logits: poisoned request returned $CODE, want 500"
    poll_metrics '.failed >= 1 and .kv_bytes == 0' "poisoned lane failure"
    assert_baseline_tokens nan-logits
    stop
    echo "[nan-logits] OK"
fi

# --- engine-stall: delayed step, identical tokens ----------------------------
if want_scenario engine-stall; then
    boot engine-stall GQ_FAULT=engine-stall:4
    curl -fsS -X POST "$BASE/v1/completions" -d "$PROMPT" >"$DIR/engine-stall_hit.json" \
        || fail "engine-stall: stalled request must still complete"
    GOT=$(tokens_of "$DIR/engine-stall_hit.json")
    [ "$GOT" = "$REF" ] || fail "engine-stall: tokens [$GOT] differ from baseline [$REF]"
    assert_baseline_tokens engine-stall
    stop
    echo "[engine-stall] OK"
fi

# --- slow-client: slow writes, mid-stream hang-up, expired deadline ----------
if want_scenario slow-client; then
    # (a) one SSE chunk write stalls 1s: the stream pauses, tokens identical.
    boot slow-write GQ_FAULT=slow-write:2
    curl -fsS -N -X POST "$BASE/v1/completions" \
        -d '{"prompt": [1, 2, 3, 4], "max_tokens": 8, "stream": true}' \
        >"$DIR/slow-write_stream.txt" \
        || fail "slow-write: streamed request failed"
    tail -n 2 "$DIR/slow-write_stream.txt" | grep -q '^data: \[DONE\]' \
        || fail "slow-write: stream did not end with [DONE]"
    STREAMED=$(grep -o '"token":[0-9]*' "$DIR/slow-write_stream.txt" | cut -d: -f2 | paste -sd, -)
    [ "$STREAMED" = "$REF" ] \
        || fail "slow-write: streamed tokens [$STREAMED] differ from baseline [$REF]"
    stop
    echo "[slow-write] OK"

    # (b) the client hangs up mid-stream: the lane is cancelled and its KV
    # pages return to the arena (no fault site needed — this is pure
    # client-side chaos).
    boot hangup
    curl -s -N --max-time 1 -X POST "$BASE/v1/completions" \
        -d '{"prompt": [5, 6, 7], "max_tokens": 4096, "stream": true}' \
        >"$DIR/hangup_stream.txt" || true
    poll_metrics '.cancelled >= 1 and .active == 0 and .kv_bytes == 0' \
        "hang-up cancellation + kv release"
    assert_baseline_tokens hangup
    stop
    echo "[hangup] OK"

    # (c) an expired per-request deadline returns partial output flagged
    # "timeout" and frees the lane.
    boot deadline
    curl -fsS -X POST "$BASE/v1/completions" \
        -d '{"prompt": [5, 6, 7], "max_tokens": 4000, "timeout_ms": 80}' \
        >"$DIR/deadline.json" \
        || fail "deadline: timed-out request must still answer 200 with partial output"
    jq -e '.finish_reason == "timeout" and (.tokens | length > 0) and (.tokens | length < 4000)' \
        "$DIR/deadline.json" >/dev/null \
        || fail "deadline: wrong shape: $(cat "$DIR/deadline.json")"
    poll_metrics '.timed_out >= 1 and .kv_bytes == 0' "timeout counter + kv release"
    assert_baseline_tokens deadline
    stop
    echo "[deadline] OK"
fi

# --- kv-exhaust: spurious admission-time exhaustion -> one 429, then normal --
if want_scenario kv-exhaust; then
    boot kv-exhaust GQ_FAULT=kv-exhaust:1
    CODE=$(curl -s -D "$DIR/kv-exhaust_headers.txt" -o "$DIR/kv-exhaust_hit.json" \
        -w '%{http_code}' -X POST "$BASE/v1/completions" -d "$PROMPT")
    [ "$CODE" = 429 ] || fail "kv-exhaust: shed request returned $CODE, want 429"
    RA=$(sed -n 's/^[Rr]etry-[Aa]fter: *//p' "$DIR/kv-exhaust_headers.txt" | head -n 1 | tr -d '\r')
    [ -n "$RA" ] || fail "kv-exhaust: 429 without a Retry-After header"
    { [ "$RA" -ge 1 ] && [ "$RA" -le 60 ]; } \
        || fail "kv-exhaust: Retry-After $RA outside the 1..60s clamp"
    poll_metrics '.rejected >= 1' "shed counter"
    curl -fsS "$BASE/healthz" >/dev/null || fail "kv-exhaust: healthz went dark"
    assert_baseline_tokens kv-exhaust
    stop
    echo "[kv-exhaust] OK"
fi

# --- slow-read: a stalled body read delays one connection, not the server ----
if want_scenario slow-read; then
    boot slow-read GQ_FAULT=slow-read:1
    T0=$(date +%s%N)
    curl -fsS --max-time 30 -X POST "$BASE/v1/completions" -d "$PROMPT" \
        >"$DIR/slow-read_hit.json" \
        || fail "slow-read: stalled request must still complete"
    ELAPSED_MS=$(( ($(date +%s%N) - T0) / 1000000 ))
    [ "$ELAPSED_MS" -ge 900 ] \
        || fail "slow-read: stall site never fired (request took ${ELAPSED_MS}ms)"
    GOT=$(tokens_of "$DIR/slow-read_hit.json")
    [ "$GOT" = "$REF" ] || fail "slow-read: tokens [$GOT] differ from baseline [$REF]"
    curl -fsS "$BASE/healthz" >/dev/null || fail "slow-read: healthz went dark"
    assert_baseline_tokens slow-read
    stop
    echo "[slow-read] OK"
fi

# --- prefix-evict: forced cache clear never corrupts a borrowing lane --------
if want_scenario prefix-evict; then
    # A >64-token prompt so a finished lane donates page-aligned chunks;
    # the resubmission borrows them. The warm request runs exactly 8
    # decode steps (one per generated token), so hit 9 of the site lands
    # on the borrower's FIRST decode step — the index is cleared while it
    # decodes over borrowed pages.
    LONG="[$(for i in $(seq 0 129); do printf '%s,' $((i % 50 + 1)); done | sed 's/,$//')]"
    LONG_PROMPT="{\"prompt\": $LONG, \"max_tokens\": 8}"
    boot prefix-evict GQ_FAULT=prefix-evict:9
    curl -fsS -X POST "$BASE/v1/completions" -d "$LONG_PROMPT" \
        >"$DIR/prefix-evict_warm.json" \
        || fail "prefix-evict: warm-up request failed"
    WARM=$(tokens_of "$DIR/prefix-evict_warm.json")
    poll_metrics '.prefix_cached_pages > 0' "prefix donation"
    curl -fsS -X POST "$BASE/v1/completions" -d "$LONG_PROMPT" \
        >"$DIR/prefix-evict_hit.json" \
        || fail "prefix-evict: borrowing request must still complete"
    GOT=$(tokens_of "$DIR/prefix-evict_hit.json")
    [ "$GOT" = "$WARM" ] \
        || fail "prefix-evict: tokens [$GOT] differ from warm-up [$WARM] — eviction corrupted a borrower"
    poll_metrics '.prefix_hits >= 1 and .prefill_tokens_saved >= 128' "prefix hit gauges"
    curl -fsS "$BASE/healthz" >/dev/null || fail "prefix-evict: healthz went dark"
    assert_baseline_tokens prefix-evict
    stop
    echo "[prefix-evict] OK"
fi

echo "serve-chaos OK (scenario: $SCENARIO)"
