//! Offline stub of the `xla` crate (PJRT CPU bindings).
//!
//! The real `xla_extension` shared library is unavailable in this build
//! environment, so this crate provides just enough surface for the
//! workspace to compile and for the non-PJRT paths to run:
//!
//! * [`Literal`] is a real, in-memory implementation (vec1 / reshape /
//!   scalar / to_vec / array_shape / tuples), so value round-trip code and
//!   its tests work.
//! * [`PjRtClient`] constructs, but [`PjRtClient::compile`] and HLO parsing
//!   return a clear "PJRT unavailable" error. Callers already skip
//!   gracefully when artifacts are missing, which is the only situation in
//!   which compilation would be reached here.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the native xla_extension runtime, which is not \
         available in this offline build"
    )))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F16,
    F32,
    F64,
}

/// Element types the stub can hold in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn write(data: &[Self]) -> Data;
    fn read(data: &Data) -> Option<Vec<Self>>;
}

#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn write(data: &[f32]) -> Data {
        Data::F32(data.to_vec())
    }
    fn read(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn write(data: &[i32]) -> Data {
        Data::I32(data.to_vec())
    }
    fn read(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// In-memory tensor literal (row-major), mirroring the real crate's API.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::write(data) }
    }

    pub fn scalar(v: f32) -> Literal {
        Literal { dims: Vec::new(), data: Data::F32(vec![v]) }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape { ty, dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.data).ok_or_else(|| {
            Error(format!("literal is not of the requested element type ({:?})", T::TY))
        })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![elems.len() as i64], data: Data::Tuple(elems) }
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("parsing HLO text")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching device buffers")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a PJRT program")
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an XLA computation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn bad_reshape_rejected() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn client_is_inert() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(client.compile(&XlaComputation).is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
