//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so the subset of anyhow
//! the workspace actually uses is reimplemented here: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Error values are flattened to strings (context frames joined
//! with `": "`), which is all the callers rely on.

use std::fmt;

/// A string-backed error value. Like anyhow's, it deliberately does NOT
/// implement `std::error::Error`, so the blanket `From<E: Error>` below
/// never overlaps the identity `From` impl.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context frame (`outer: inner`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(anyhow!("n={}", 2).to_string(), "n=2");
    }
}
