//! Table rendering + CSV export for the bench harnesses (every paper
//! table/figure bench prints rows and writes target/benchres/<name>.csv).

use std::path::PathBuf;

/// Simple column-aligned table with CSV export.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write CSV under target/benchres/<name>.csv.
    pub fn save_csv(&self, name: &str) -> std::io::Result<PathBuf> {
        let dir = PathBuf::from("target/benchres");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut text = self.headers.join(",") + "\n";
        for row in &self.rows {
            text.push_str(&row.join(","));
            text.push('\n');
        }
        std::fs::write(&path, text)?;
        Ok(path)
    }
}

/// Format helper: fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "ppl"]);
        t.row(vec!["lnq".into(), "8.83".into()]);
        t.row(vec!["squeezellm".into(), "39.58".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("squeezellm"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_round_trip() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let p = t.save_csv("test_table").unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn fmt_helper() {
        assert_eq!(f(3.14159, 2), "3.14");
    }
}
