//! Named parameter store in the canonical flat order shared with the
//! artifacts (cfg::ModelConfig::param_specs == python param_specs).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::cfg::ModelConfig;
use crate::tensor::io::TensorFile;
use crate::tensor::Mat;
use crate::util::Rng;

#[derive(Clone)]
pub struct ParamStore {
    pub cfg: ModelConfig,
    mats: Vec<Mat>,
    index: BTreeMap<String, usize>,
}

impl ParamStore {
    /// Scaled-normal init matching the Python scheme (norm scales = 1,
    /// weights ~ N(0, 1/fan_in)). Values differ from jax's PRNG — training
    /// happens through the train_step artifact, so only shapes must agree.
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> Self {
        let mut mats = Vec::new();
        let mut index = BTreeMap::new();
        for (i, spec) in cfg.param_specs().iter().enumerate() {
            let m = if spec.name.ends_with("norm") {
                Mat::from_vec(spec.rows, spec.cols, vec![1.0; spec.rows * spec.cols])
            } else {
                Mat::randn(spec.rows, spec.cols, (spec.rows as f32).powf(-0.5), rng)
            };
            index.insert(spec.name.clone(), i);
            mats.push(m);
        }
        ParamStore { cfg: cfg.clone(), mats, index }
    }

    /// [`ParamStore::init`] seeded from a pipeline seed — the ONE canonical
    /// derivation (`seed ^ 0x1a17`), shared by `Pipeline::init_params` and
    /// artifact-free consumers (`gq serve`, the HTTP front-end) so their
    /// fresh-init weights always agree bit-for-bit.
    pub fn init_seeded(cfg: &ModelConfig, pipeline_seed: u64) -> Self {
        Self::init(cfg, &mut Rng::new(pipeline_seed ^ 0x1a17))
    }

    pub fn get(&self, name: &str) -> &Mat {
        &self.mats[*self.index.get(name).unwrap_or_else(|| panic!("no param `{name}`"))]
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Mat {
        let i = *self.index.get(name).unwrap_or_else(|| panic!("no param `{name}`"));
        &mut self.mats[i]
    }

    pub fn set(&mut self, name: &str, m: Mat) {
        let i = *self.index.get(name).unwrap_or_else(|| panic!("no param `{name}`"));
        assert_eq!(
            (self.mats[i].rows, self.mats[i].cols),
            (m.rows, m.cols),
            "shape mismatch for {name}"
        );
        self.mats[i] = m;
    }

    /// Flat views in artifact argument order.
    pub fn flat(&self) -> &[Mat] {
        &self.mats
    }

    /// Replace all tensors from a flat list (artifact outputs).
    pub fn set_flat(&mut self, mats: Vec<Mat>) {
        assert_eq!(mats.len(), self.mats.len());
        for (old, new) in self.mats.iter().zip(&mats) {
            assert_eq!((old.rows, old.cols), (new.rows, new.cols));
        }
        self.mats = mats;
    }

    pub fn names(&self) -> Vec<&str> {
        self.index.keys().map(|k| k.as_str()).collect()
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut tf = TensorFile::new();
        for (spec, m) in self.cfg.param_specs().iter().zip(&self.mats) {
            tf.insert(spec.name.clone(), m.clone());
        }
        tf.save(path)
    }

    pub fn load(cfg: &ModelConfig, path: impl AsRef<std::path::Path>) -> Result<Self> {
        let tf = TensorFile::load(path)?;
        let mut mats = Vec::new();
        let mut index = BTreeMap::new();
        for (i, spec) in cfg.param_specs().iter().enumerate() {
            let Some(m) = tf.get(&spec.name) else {
                bail!("missing param `{}` in checkpoint", spec.name);
            };
            if (m.rows, m.cols) != (spec.rows, spec.cols) {
                bail!(
                    "param `{}`: shape {}x{} != expected {}x{}",
                    spec.name,
                    m.rows,
                    m.cols,
                    spec.rows,
                    spec.cols
                );
            }
            index.insert(spec.name.clone(), i);
            mats.push(m.clone());
        }
        Ok(ParamStore { cfg: cfg.clone(), mats, index })
    }

    /// Clone with one linear's weight replaced (quantized model assembly).
    pub fn with_weight(&self, name: &str, w: Mat) -> ParamStore {
        let mut out = self.clone();
        out.set(name, w);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;

    #[test]
    fn init_shapes_match_specs() {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        for spec in cfg.param_specs() {
            let m = ps.get(&spec.name);
            assert_eq!((m.rows, m.cols), (spec.rows, spec.cols), "{}", spec.name);
        }
        assert_eq!(ps.flat().len(), cfg.param_specs().len());
    }

    #[test]
    fn norm_params_init_to_one() {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        assert!(ps.get("final_norm").data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn init_seeded_is_the_canonical_derivation() {
        // `gq serve` (artifact-free) and `Pipeline::init_params` both go
        // through init_seeded, which must stay equal to the historical
        // explicit derivation so fresh-init weights never diverge.
        let (cfg, _) = preset("tiny");
        let a = ParamStore::init_seeded(&cfg, 7);
        let b = ParamStore::init(&cfg, &mut Rng::new(7 ^ 0x1a17));
        assert_eq!(a.get("layers.0.wq"), b.get("layers.0.wq"));
        assert_eq!(a.get("tok_emb"), b.get("tok_emb"));
    }

    #[test]
    fn save_load_round_trip() {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(3));
        let path = std::env::temp_dir().join(format!("gq_params_{}.gqtb", std::process::id()));
        ps.save(&path).unwrap();
        let back = ParamStore::load(&cfg, &path).unwrap();
        assert_eq!(back.get("layers.0.wq"), ps.get("layers.0.wq"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_wrong_config() {
        let (tiny, _) = preset("tiny");
        let (small, _) = preset("small");
        let ps = ParamStore::init(&tiny, &mut Rng::new(0));
        let path = std::env::temp_dir().join(format!("gq_params_bad_{}.gqtb", std::process::id()));
        ps.save(&path).unwrap();
        assert!(ParamStore::load(&small, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "no param")]
    fn unknown_param_panics() {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        ps.get("nonexistent");
    }
}
