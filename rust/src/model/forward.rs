//! Native MiniLlama forward pass (f32 reference + incremental decode).
//!
//! Numerics mirror python/compile/model.py exactly (RMSNorm eps 1e-5,
//! half-split RoPE, SwiGLU, causal softmax) so the native path can be
//! cross-validated against the `fwd_loss` HLO artifact, and the serving
//! engine can swap any linear for a quantized format via [`LinearOp`].

use crate::cfg::ModelConfig;
use crate::tensor::Mat;

use super::params::ParamStore;

/// A linear layer `z = x @ W` with `W: [d_in, d_out]`. Implemented by plain
/// `Mat` (fp32) here and by every quantized serving format in
/// `quant::formats` — the decode loop is format-agnostic.
pub trait LinearOp: Send + Sync {
    fn d_in(&self) -> usize;
    fn d_out(&self) -> usize;
    /// out += is NOT implied: `out` is overwritten.
    fn matvec(&self, x: &[f32], out: &mut [f32]);
    /// Bytes of weight storage (for the Table 2 bits/OOM accounting).
    fn storage_bytes(&self) -> usize;
}

impl LinearOp for Mat {
    fn d_in(&self) -> usize {
        self.rows
    }

    fn d_out(&self) -> usize {
        self.cols
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (o, w) in out.iter_mut().zip(row) {
                *o += xi * w;
            }
        }
    }

    fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

pub struct Block {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub wq: Box<dyn LinearOp>,
    pub wk: Box<dyn LinearOp>,
    pub wv: Box<dyn LinearOp>,
    pub wo: Box<dyn LinearOp>,
    pub wgate: Box<dyn LinearOp>,
    pub wup: Box<dyn LinearOp>,
    pub wdown: Box<dyn LinearOp>,
}

pub struct NativeModel {
    pub cfg: ModelConfig,
    pub tok_emb: Mat,
    pub head: Box<dyn LinearOp>,
    pub final_norm: Vec<f32>,
    pub blocks: Vec<Block>,
}

/// Growing per-sequence KV cache.
pub struct DecodeState {
    /// keys[block] : flat [pos][d_model] (heads contiguous within d_model).
    keys: Vec<Vec<f32>>,
    vals: Vec<Vec<f32>>,
    pub pos: usize,
}

impl DecodeState {
    pub fn new(n_layers: usize) -> Self {
        DecodeState {
            keys: vec![Vec::new(); n_layers],
            vals: vec![Vec::new(); n_layers],
            pos: 0,
        }
    }

    pub fn kv_bytes(&self) -> usize {
        self.keys.iter().chain(&self.vals).map(|v| v.len() * 4).sum()
    }
}

fn rmsnorm(x: &[f32], gamma: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gamma) {
        *o = v * inv * g;
    }
}

/// In-place half-split RoPE on one head slice (matches python `rope`).
fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[half + i]);
        x[i] = a * cos - b * sin;
        x[half + i] = a * sin + b * cos;
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

impl NativeModel {
    /// fp32 model straight from a parameter store.
    pub fn from_params(ps: &ParamStore) -> Self {
        let cfg = ps.cfg.clone();
        let lin = |name: String| -> Box<dyn LinearOp> { Box::new(ps.get(&name).clone()) };
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                let p = format!("layers.{l}.");
                Block {
                    attn_norm: ps.get(&format!("{p}attn_norm")).data.clone(),
                    mlp_norm: ps.get(&format!("{p}mlp_norm")).data.clone(),
                    wq: lin(format!("{p}wq")),
                    wk: lin(format!("{p}wk")),
                    wv: lin(format!("{p}wv")),
                    wo: lin(format!("{p}wo")),
                    wgate: lin(format!("{p}wgate")),
                    wup: lin(format!("{p}wup")),
                    wdown: lin(format!("{p}wdown")),
                }
            })
            .collect();
        NativeModel {
            tok_emb: ps.get("tok_emb").clone(),
            head: Box::new(ps.get("head").clone()),
            final_norm: ps.get("final_norm").data.clone(),
            cfg,
            blocks,
        }
    }

    pub fn new_state(&self) -> DecodeState {
        DecodeState::new(self.cfg.n_layers)
    }

    /// Total weight bytes across the seven quantizable linears (all blocks).
    pub fn linear_storage_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| {
                b.wq.storage_bytes()
                    + b.wk.storage_bytes()
                    + b.wv.storage_bytes()
                    + b.wo.storage_bytes()
                    + b.wgate.storage_bytes()
                    + b.wup.storage_bytes()
                    + b.wdown.storage_bytes()
            })
            .sum()
    }

    /// One decode step: append `token`, return next-token logits.
    pub fn step(&self, state: &mut DecodeState, token: u32) -> Vec<f32> {
        self.step_inner(state, token, None)
    }

    /// Decode step that also records the input activations of every linear
    /// (7 per block, flat order) — used by the calibration cross-check and
    /// the PV-tuning-lite cascade refit.
    pub fn step_recorded(
        &self,
        state: &mut DecodeState,
        token: u32,
        rec: &mut Vec<Vec<f32>>,
    ) -> Vec<f32> {
        self.step_inner(state, token, Some(rec))
    }

    fn step_inner(
        &self,
        state: &mut DecodeState,
        token: u32,
        mut rec: Option<&mut Vec<Vec<f32>>>,
    ) -> Vec<f32> {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let theta = self.cfg.rope_theta;
        let pos = state.pos;

        let mut x = self.tok_emb.row(token as usize).to_vec();
        let mut normed = vec![0.0f32; d];
        let mut q = vec![0.0f32; d];
        let mut k = vec![0.0f32; d];
        let mut v = vec![0.0f32; d];
        let mut ctx = vec![0.0f32; d];
        let mut o = vec![0.0f32; d];
        let ff = self.cfg.d_ff;
        let mut gate = vec![0.0f32; ff];
        let mut up = vec![0.0f32; ff];
        let mut down = vec![0.0f32; d];

        for (l, blk) in self.blocks.iter().enumerate() {
            rmsnorm(&x, &blk.attn_norm, &mut normed);
            if let Some(r) = rec.as_deref_mut() {
                // wq/wk/wv share the same input.
                r.push(normed.clone());
                r.push(normed.clone());
                r.push(normed.clone());
            }
            blk.wq.matvec(&normed, &mut q);
            blk.wk.matvec(&normed, &mut k);
            blk.wv.matvec(&normed, &mut v);
            for head in 0..h {
                rope_inplace(&mut q[head * hd..(head + 1) * hd], pos, theta);
                rope_inplace(&mut k[head * hd..(head + 1) * hd], pos, theta);
            }
            state.keys[l].extend_from_slice(&k);
            state.vals[l].extend_from_slice(&v);
            let n_pos = pos + 1;
            let scale = 1.0 / (hd as f32).sqrt();
            ctx.fill(0.0);
            for head in 0..h {
                let qh = &q[head * hd..(head + 1) * hd];
                // scores over all cached positions
                let mut scores = Vec::with_capacity(n_pos);
                let mut max_s = f32::NEG_INFINITY;
                for p in 0..n_pos {
                    let kh = &state.keys[l][p * d + head * hd..p * d + (head + 1) * hd];
                    let s = crate::tensor::ops::dot(qh, kh) * scale;
                    max_s = max_s.max(s);
                    scores.push(s);
                }
                let mut denom = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - max_s).exp();
                    denom += *s;
                }
                let ctx_h = &mut ctx[head * hd..(head + 1) * hd];
                for p in 0..n_pos {
                    let w = scores[p] / denom;
                    let vh = &state.vals[l][p * d + head * hd..p * d + (head + 1) * hd];
                    for (c, &vv) in ctx_h.iter_mut().zip(vh) {
                        *c += w * vv;
                    }
                }
            }
            if let Some(r) = rec.as_deref_mut() {
                r.push(ctx.clone());
            }
            blk.wo.matvec(&ctx, &mut o);
            for (xv, &ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }
            rmsnorm(&x, &blk.mlp_norm, &mut normed);
            if let Some(r) = rec.as_deref_mut() {
                r.push(normed.clone());
                r.push(normed.clone());
            }
            blk.wgate.matvec(&normed, &mut gate);
            blk.wup.matvec(&normed, &mut up);
            for (g, &u) in gate.iter_mut().zip(&up) {
                *g = silu(*g) * u;
            }
            if let Some(r) = rec.as_deref_mut() {
                r.push(gate.clone());
            }
            blk.wdown.matvec(&gate, &mut down);
            for (xv, &dv) in x.iter_mut().zip(&down) {
                *xv += dv;
            }
        }
        state.pos += 1;
        rmsnorm(&x.clone(), &self.final_norm, &mut x);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        self.head.matvec(&x, &mut logits);
        logits
    }

    /// Input activations of every linear over a full sequence: one
    /// (seq_len × d_in) matrix per linear, flat (layer, kind) order.
    pub fn record_linear_inputs(&self, tokens: &[u32]) -> Vec<Mat> {
        let n_lin = self.cfg.n_layers * 7;
        let specs = self.cfg.linear_specs();
        let mut state = self.new_state();
        let mut mats: Vec<Mat> =
            specs.iter().map(|s| Mat::zeros(tokens.len(), s.d_in)).collect();
        for (t, &tok) in tokens.iter().enumerate() {
            let mut rec = Vec::with_capacity(n_lin);
            self.step_recorded(&mut state, tok, &mut rec);
            assert_eq!(rec.len(), n_lin);
            for (li, x) in rec.into_iter().enumerate() {
                mats[li].row_mut(t).copy_from_slice(&x);
            }
        }
        mats
    }

    /// Full-sequence logits (row t = logits after consuming tokens[..=t]).
    pub fn forward_sequence(&self, tokens: &[u32]) -> Mat {
        let mut state = self.new_state();
        let mut out = Mat::zeros(tokens.len(), self.cfg.vocab);
        for (t, &tok) in tokens.iter().enumerate() {
            let logits = self.step(&mut state, tok);
            out.row_mut(t).copy_from_slice(&logits);
        }
        out
    }

    /// Summed next-token cross-entropy over a sequence (matches fwd_loss
    /// semantics for batch rows processed independently).
    pub fn loss_sum(&self, tokens: &[u32]) -> f64 {
        let logits = self.forward_sequence(tokens);
        let mut total = 0.0f64;
        for t in 0..tokens.len() - 1 {
            let row = logits.row(t);
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let lse = max as f64
                + row
                    .iter()
                    .map(|&v| ((v - max) as f64).exp())
                    .sum::<f64>()
                    .ln();
            total += lse - row[tokens[t + 1] as usize] as f64;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::util::Rng;

    fn tiny_model() -> NativeModel {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        NativeModel::from_params(&ps)
    }

    #[test]
    fn step_produces_finite_logits() {
        let m = tiny_model();
        let mut st = m.new_state();
        let logits = m.step(&mut st, 3);
        assert_eq!(logits.len(), m.cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(st.pos, 1);
    }

    #[test]
    fn decode_matches_fresh_replay() {
        // Incremental decode over [a, b, c] must equal replaying the prefix.
        let m = tiny_model();
        let toks = [5u32, 9, 200, 43];
        let full = m.forward_sequence(&toks);
        let mut st = m.new_state();
        for (t, &tok) in toks.iter().enumerate() {
            let logits = m.step(&mut st, tok);
            crate::testing::assert_close(&logits, full.row(t), 1e-5, 1e-5)
                .unwrap_or_else(|e| panic!("pos {t}: {e}"));
        }
    }

    #[test]
    fn initial_loss_near_uniform() {
        let m = tiny_model();
        let mut rng = Rng::new(1);
        let toks: Vec<u32> = (0..48).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let per_tok = m.loss_sum(&toks) / (toks.len() - 1) as f64;
        let uniform = (m.cfg.vocab as f64).ln();
        assert!((per_tok - uniform).abs() < 1.5, "{per_tok} vs {uniform}");
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(2);
        let mut x: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let before: f32 = x.iter().map(|v| v * v).sum();
        rope_inplace(&mut x, 17, 10000.0);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() < 1e-3 * before);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let orig = x.clone();
        rope_inplace(&mut x, 0, 10000.0);
        crate::testing::assert_close(&x, &orig, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn kv_cache_grows_linearly() {
        let m = tiny_model();
        let mut st = m.new_state();
        m.step(&mut st, 0);
        let b1 = st.kv_bytes();
        m.step(&mut st, 1);
        assert_eq!(st.kv_bytes(), 2 * b1);
    }

    #[test]
    fn storage_accounting_positive() {
        let m = tiny_model();
        assert!(m.linear_storage_bytes() > 0);
        // fp32: 7 linears per block * d*d-ish * 4 bytes
        let (cfg, _) = preset("tiny");
        assert_eq!(m.linear_storage_bytes(), cfg.n_linear_params() * 4);
    }
}
