//! Native MiniLlama forward pass (f32 reference + incremental decode).
//!
//! Numerics mirror python/compile/model.py exactly (RMSNorm eps 1e-5,
//! half-split RoPE, SwiGLU, causal softmax) so the native path can be
//! cross-validated against the `fwd_loss` HLO artifact, and the serving
//! engine can swap any linear for a quantized format via [`LinearOp`].

use crate::cfg::ModelConfig;
use crate::tensor::gemm::{self, ColWindow};
use crate::tensor::Mat;

use super::attention::{self, DecodeState, KvArena, KvLaneMut};
use super::params::ParamStore;

/// A linear layer `z = x @ W` with `W: [d_in, d_out]`. Implemented by plain
/// `Mat` (fp32) here and by every quantized serving format in
/// `quant::formats` — the decode loop is format-agnostic.
///
/// ## The tile contract
///
/// Serving formats expose their weights to the shared tiled GEMM engine
/// (`tensor::gemm`) through three hooks:
///
/// * [`LinearOp::decode_tile`] decodes rows `[i0, i1)` × columns
///   `[lo, hi)` of the *pre-epilogue* weight matrix `D` into a caller
///   f32 tile — once per tile per batched product, with any code→value
///   tables pre-expanded to f32 at construction.
/// * The engine accumulates `acc[r][j] = Σ_i xs[r][i] · D[i][j]` with a
///   register-blocked micro-kernel. Each `(lane, column)` sum is a single
///   flat chain in ascending `i` (resumed across tiles), with NO zero-skip
///   branches.
/// * [`LinearOp::tile_epilogue`] turns the raw sums into final outputs
///   (e.g. the uniform grid's `acc·scale + Σx·zero`, the trellis
///   per-column scale).
///
/// Every kernel — [`LinearOp::matvec`], the row-at-a-time
/// [`LinearOp::matmul_cols`] fallback, and the tiled engine — must produce
/// exactly equal results per output element (f32 `==`): the same flat
/// ascending-`i` accumulation per element and the same epilogue
/// arithmetic. (Reference kernels may still skip `x_i == 0` terms: adding
/// `±0.0` to a finite running sum can change at most the sign of a zero,
/// which `==` treats as equal and no downstream computation distinguishes.)
/// The continuous-batching engine relies on this to keep batched greedy
/// decode bit-identical to the per-sequence path at any tile height, shard
/// count, and thread count.
pub trait LinearOp: Send + Sync {
    fn d_in(&self) -> usize;
    fn d_out(&self) -> usize;
    /// out += is NOT implied: `out` is overwritten.
    fn matvec(&self, x: &[f32], out: &mut [f32]);
    /// Batched linear: `out.row(r) = xs.row(r) @ W` for every row.
    /// `xs: [batch, d_in]`, `out: [batch, d_out]`, both overwritten
    /// row-major.
    ///
    /// The default loops [`LinearOp::matvec`]; serving formats override it
    /// with [`matmul_col_sharded`], which splits the output columns across
    /// the worker pool and runs the tiled GEMM engine (or the row-at-a-time
    /// window kernel when `GQ_TILE=0`) per shard — decoding each weight
    /// tile ONCE per step and applying it to all batch lanes. Per-lane
    /// results must equal `matvec` exactly (see the trait docs).
    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        debug_assert_eq!(xs.cols, self.d_in());
        debug_assert_eq!(out.cols, self.d_out());
        debug_assert_eq!(xs.rows, out.rows);
        for r in 0..xs.rows {
            self.matvec(xs.row(r), out.row_mut(r));
        }
    }
    /// Row-at-a-time window kernel: write columns `[out.lo(), out.hi())` of
    /// the batched product into the window (`out.row_mut(r)` is that slice
    /// of output row `r`, overwritten). This is the `GQ_TILE=0` fallback
    /// and the shard-level unit of [`matmul_col_sharded`]; per-element
    /// arithmetic must match `matvec` exactly. The default loops `matvec`
    /// into thread-local full-width scratch and copies the window out.
    fn matmul_cols(&self, xs: &Mat, out: &mut ColWindow) {
        debug_assert_eq!(xs.cols, self.d_in());
        debug_assert_eq!(xs.rows, out.rows());
        let (lo, hi) = (out.lo(), out.hi());
        gemm::with_full_scratch(self.d_out(), |full| {
            for r in 0..xs.rows {
                self.matvec(xs.row(r), full);
                out.row_mut(r).copy_from_slice(&full[lo..hi]);
            }
        });
    }
    /// Whether this format implements [`LinearOp::decode_tile`] (the tiled
    /// engine is only routed to when true).
    fn supports_decode_tile(&self) -> bool {
        false
    }
    /// Decode rows `[i0, i1)` × columns `[lo, hi)` of the pre-epilogue
    /// weight matrix into `tile` (row-major `(i1-i0) × (hi-lo)`, fully
    /// overwritten). Called once per tile per batched product; decoded
    /// values must be exactly the per-weight values `matvec` multiplies by
    /// before its epilogue.
    fn decode_tile(&self, i0: usize, i1: usize, lo: usize, hi: usize, tile: &mut [f32]) {
        let _ = (i0, i1, lo, hi, tile);
        unimplemented!("decode_tile unsupported (supports_decode_tile() is false)");
    }
    /// Transform one lane's raw tile-accumulated window sums into final
    /// outputs: `out_w` is the `[lo, lo + out_w.len())` slice of that
    /// lane's output row, `x` the lane's full input row (for input-sum
    /// terms). Default: identity (decoded values are already final).
    fn tile_epilogue(&self, x: &[f32], out_w: &mut [f32], lo: usize) {
        let _ = (x, out_w, lo);
    }
    /// Bytes of weight storage (for the Table 2 bits/OOM accounting).
    fn storage_bytes(&self) -> usize;
}

/// Minimum `batch * d_in * d_out` before a batched product is sharded
/// across output columns on the worker pool.
const SHARD_MIN_WORK: usize = 1 << 16;

/// Drive a batched linear through column shards on the shared worker pool.
///
/// Each shard decodes its own weight tiles once and serves every batch
/// lane, so the result is bit-identical to the serial batched kernel (and
/// per lane to `matvec`) at any shard count: each output element is
/// produced by exactly one shard with unchanged accumulation order. Small
/// products stay serial.
pub fn matmul_col_sharded(op: &dyn LinearOp, xs: &Mat, out: &mut Mat) {
    let d_out = op.d_out();
    let work = xs.rows * op.d_in() * d_out;
    let shards = if work < SHARD_MIN_WORK {
        1
    } else {
        crate::tensor::ops::num_threads().min(d_out.max(1))
    };
    matmul_col_sharded_with(op, xs, out, shards);
}

/// [`matmul_col_sharded`] with an explicit shard count (1 = the serial
/// whole-width kernel). Exposed for bit-identity tests and the
/// serial-vs-pool bench rows; shard counts that do not divide `d_out` are
/// fine (the last shard is narrower).
///
/// Shards write their column windows IN PLACE into `out` (disjoint
/// [`ColWindow`]s over one buffer) and run as indexed scatter items on the
/// pool ([`crate::coordinator::run_indexed`]): no per-shard staging
/// buffer, no paste copy, and — with the formats' thread-local decode
/// scratch — no heap allocation on a warm call.
pub fn matmul_col_sharded_with(op: &dyn LinearOp, xs: &Mat, out: &mut Mat, shards: usize) {
    debug_assert_eq!(xs.cols, op.d_in());
    debug_assert_eq!(out.cols, op.d_out());
    debug_assert_eq!(xs.rows, out.rows);
    let d_out = op.d_out();
    let shards = shards.clamp(1, d_out.max(1));
    if shards <= 1 {
        gemm::matmul_cols_auto(op, xs, &mut ColWindow::full(out));
        return;
    }
    let b = xs.rows;
    // Align shard boundaries to the packed-code word (32 covers every
    // power-of-two bit width's per-word count), so each shard's decode
    // start stays on the word-at-a-time fast path whenever the serial
    // whole-width kernel's would. Only applied when shards are at least a
    // word-group wide — narrow shards (tiny layers, many threads) keep the
    // exact split. Partitioning never changes values, only which shard
    // computes which column.
    const COL_ALIGN: usize = 32;
    let mut per = d_out.div_ceil(shards);
    if per >= COL_ALIGN {
        per = per.div_ceil(COL_ALIGN) * COL_ALIGN;
    }
    let n_shards = d_out.div_ceil(per);
    let scatter = crate::coordinator::Scatter::new(&mut out.data);
    crate::coordinator::run_indexed(n_shards, n_shards, &|t| {
        let lo = t * per;
        let hi = (lo + per).min(d_out);
        // SAFETY: shard t writes only the [lo, hi) column window — windows
        // of distinct shards are disjoint, and `out` is not touched again
        // until every shard has completed.
        let mut win = unsafe { ColWindow::from_raw(scatter.as_mut_ptr(), b, d_out, lo, hi) };
        gemm::matmul_cols_auto(op, xs, &mut win);
    });
}

impl LinearOp for Mat {
    fn d_in(&self) -> usize {
        self.rows
    }

    fn d_out(&self) -> usize {
        self.cols
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (o, w) in out.iter_mut().zip(row) {
                *o += xi * w;
            }
        }
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut ColWindow) {
        debug_assert_eq!(xs.cols, self.rows);
        debug_assert_eq!(xs.rows, out.rows());
        let (lo, hi) = (out.lo(), out.hi());
        out.fill(0.0);
        // Weight row i is read once and applied to every lane (per-lane op
        // order matches `matvec`: i ascending, j ascending, zeros skipped).
        for i in 0..self.rows {
            let wrow = &self.row(i)[lo..hi];
            for r in 0..xs.rows {
                let xi = xs.at(r, i);
                if xi == 0.0 {
                    continue;
                }
                for (o, w) in out.row_mut(r).iter_mut().zip(wrow) {
                    *o += xi * w;
                }
            }
        }
    }

    fn supports_decode_tile(&self) -> bool {
        true
    }

    fn decode_tile(&self, i0: usize, i1: usize, lo: usize, hi: usize, tile: &mut [f32]) {
        let w = hi - lo;
        for (i, trow) in (i0..i1).zip(tile.chunks_exact_mut(w)) {
            trow.copy_from_slice(&self.row(i)[lo..hi]);
        }
    }

    fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

pub struct Block {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub wq: Box<dyn LinearOp>,
    pub wk: Box<dyn LinearOp>,
    pub wv: Box<dyn LinearOp>,
    pub wo: Box<dyn LinearOp>,
    pub wgate: Box<dyn LinearOp>,
    pub wup: Box<dyn LinearOp>,
    pub wdown: Box<dyn LinearOp>,
}

pub struct NativeModel {
    pub cfg: ModelConfig,
    pub tok_emb: Mat,
    pub head: Box<dyn LinearOp>,
    pub final_norm: Vec<f32>,
    pub blocks: Vec<Block>,
}

/// Reusable activation buffers for [`NativeModel::step_batch_with`]. The
/// decode loop owns one of these; buffers are resized only when the batch
/// width changes (lanes joining/leaving), not on every step. Every buffer
/// is fully overwritten within a step before it is read.
pub struct BatchScratch {
    x: Mat,
    normed: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    ctx: Mat,
    o: Mat,
    gate: Mat,
    up: Mat,
    down: Mat,
    logits: Mat,
    pre: Vec<f32>,
}

impl Default for BatchScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchScratch {
    pub fn new() -> Self {
        let empty = || Mat::zeros(0, 0);
        BatchScratch {
            x: empty(),
            normed: empty(),
            q: empty(),
            k: empty(),
            v: empty(),
            ctx: empty(),
            o: empty(),
            gate: empty(),
            up: empty(),
            down: empty(),
            logits: empty(),
            pre: Vec::new(),
        }
    }

    /// Next-token logits from the last [`NativeModel::step_batch_with`]
    /// call: row `r` belongs to lane `r` of that call.
    pub fn logits(&self) -> &Mat {
        &self.logits
    }

    /// Mutable logits access — exists for the fault-injection harness
    /// (`util::fault::NAN_LOGITS` corrupts a lane's row in place to model
    /// degenerate numerics); production code never writes logits here.
    pub fn logits_mut(&mut self) -> &mut Mat {
        &mut self.logits
    }

    fn ensure(&mut self, b: usize, d: usize, ff: usize, vocab: usize) {
        // Reshape in place, keeping each buffer's capacity: the chunked
        // prefill shrinks the batch width as prompts end and grows it back
        // at the next admission, and a warm flip-flop must not reallocate
        // (capacity converges to the widest batch seen).
        fn reshape(m: &mut Mat, rows: usize, cols: usize) {
            if m.rows == rows && m.cols == cols {
                return;
            }
            let mut data = std::mem::take(&mut m.data);
            data.resize(rows * cols, 0.0);
            *m = Mat::from_vec(rows, cols, data);
        }
        for m in [
            &mut self.x,
            &mut self.normed,
            &mut self.q,
            &mut self.k,
            &mut self.v,
            &mut self.ctx,
            &mut self.o,
            &mut self.down,
        ] {
            reshape(m, b, d);
        }
        reshape(&mut self.gate, b, ff);
        reshape(&mut self.up, b, ff);
        reshape(&mut self.logits, b, vocab);
    }
}

fn rmsnorm(x: &[f32], gamma: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gamma) {
        *o = v * inv * g;
    }
}

/// In-place half-split RoPE on one head slice (matches python `rope`).
fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[half + i]);
        x[i] = a * cos - b * sin;
        x[half + i] = a * sin + b * cos;
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

impl NativeModel {
    /// fp32 model straight from a parameter store.
    pub fn from_params(ps: &ParamStore) -> Self {
        let cfg = ps.cfg.clone();
        let lin = |name: String| -> Box<dyn LinearOp> { Box::new(ps.get(&name).clone()) };
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                let p = format!("layers.{l}.");
                Block {
                    attn_norm: ps.get(&format!("{p}attn_norm")).data.clone(),
                    mlp_norm: ps.get(&format!("{p}mlp_norm")).data.clone(),
                    wq: lin(format!("{p}wq")),
                    wk: lin(format!("{p}wk")),
                    wv: lin(format!("{p}wv")),
                    wo: lin(format!("{p}wo")),
                    wgate: lin(format!("{p}wgate")),
                    wup: lin(format!("{p}wup")),
                    wdown: lin(format!("{p}wdown")),
                }
            })
            .collect();
        NativeModel {
            tok_emb: ps.get("tok_emb").clone(),
            head: Box::new(ps.get("head").clone()),
            final_norm: ps.get("final_norm").data.clone(),
            cfg,
            blocks,
        }
    }

    pub fn new_state(&self) -> DecodeState {
        DecodeState::new(self.cfg.n_layers, self.cfg.n_heads, self.cfg.head_dim())
    }

    /// [`NativeModel::new_state`] at an explicit KV storage dtype (the
    /// `kv_dtype = f16` serving opt-in).
    pub fn new_state_with(&self, dtype: crate::cfg::KvDtype) -> DecodeState {
        DecodeState::with_dtype(self.cfg.n_layers, self.cfg.n_heads, self.cfg.head_dim(), dtype)
    }

    pub fn new_arena(&self) -> KvArena {
        KvArena::new(self.cfg.n_layers, self.cfg.n_heads, self.cfg.head_dim())
    }

    /// [`NativeModel::new_arena`] at an explicit KV storage dtype.
    pub fn new_arena_with(&self, dtype: crate::cfg::KvDtype) -> KvArena {
        KvArena::with_dtype(self.cfg.n_layers, self.cfg.n_heads, self.cfg.head_dim(), dtype)
    }

    /// Total weight bytes across the seven quantizable linears (all blocks).
    pub fn linear_storage_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| {
                b.wq.storage_bytes()
                    + b.wk.storage_bytes()
                    + b.wv.storage_bytes()
                    + b.wo.storage_bytes()
                    + b.wgate.storage_bytes()
                    + b.wup.storage_bytes()
                    + b.wdown.storage_bytes()
            })
            .sum()
    }

    /// One decode step: append `token`, return next-token logits.
    pub fn step(&self, state: &mut DecodeState, token: u32) -> Vec<f32> {
        self.step_inner(state, token, None)
    }

    /// Decode step that also records the input activations of every linear
    /// (7 per block, flat order) — used by the calibration cross-check and
    /// the PV-tuning-lite cascade refit.
    pub fn step_recorded(
        &self,
        state: &mut DecodeState,
        token: u32,
        rec: &mut Vec<Vec<f32>>,
    ) -> Vec<f32> {
        self.step_inner(state, token, Some(rec))
    }

    fn step_inner(
        &self,
        state: &mut DecodeState,
        token: u32,
        mut rec: Option<&mut Vec<Vec<f32>>>,
    ) -> Vec<f32> {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let theta = self.cfg.rope_theta;
        let scale = 1.0 / (hd as f32).sqrt();
        let pos = state.pos;

        let mut x = self.tok_emb.row(token as usize).to_vec();
        let mut normed = vec![0.0f32; d];
        let mut q = vec![0.0f32; d];
        let mut k = vec![0.0f32; d];
        let mut v = vec![0.0f32; d];
        let mut ctx = vec![0.0f32; d];
        let mut o = vec![0.0f32; d];
        let ff = self.cfg.d_ff;
        let mut gate = vec![0.0f32; ff];
        let mut up = vec![0.0f32; ff];
        let mut down = vec![0.0f32; d];

        for (l, blk) in self.blocks.iter().enumerate() {
            rmsnorm(&x, &blk.attn_norm, &mut normed);
            if let Some(r) = rec.as_deref_mut() {
                // wq/wk/wv share the same input.
                r.push(normed.clone());
                r.push(normed.clone());
                r.push(normed.clone());
            }
            blk.wq.matvec(&normed, &mut q);
            blk.wk.matvec(&normed, &mut k);
            blk.wv.matvec(&normed, &mut v);
            for head in 0..h {
                rope_inplace(&mut q[head * hd..(head + 1) * hd], pos, theta);
                rope_inplace(&mut k[head * hd..(head + 1) * hd], pos, theta);
            }
            state.append_kv(l, &k, &v);
            attention::attention_single(l, h, hd, scale, &q, state, &mut ctx);
            if let Some(r) = rec.as_deref_mut() {
                r.push(ctx.clone());
            }
            blk.wo.matvec(&ctx, &mut o);
            for (xv, &ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }
            rmsnorm(&x, &blk.mlp_norm, &mut normed);
            if let Some(r) = rec.as_deref_mut() {
                r.push(normed.clone());
                r.push(normed.clone());
            }
            blk.wgate.matvec(&normed, &mut gate);
            blk.wup.matvec(&normed, &mut up);
            for (g, &u) in gate.iter_mut().zip(&up) {
                *g = silu(*g) * u;
            }
            if let Some(r) = rec.as_deref_mut() {
                r.push(gate.clone());
            }
            blk.wdown.matvec(&gate, &mut down);
            for (xv, &dv) in x.iter_mut().zip(&down) {
                *xv += dv;
            }
        }
        state.pos += 1;
        // Reuse `normed` (free here) as the pre-norm copy instead of
        // cloning `x` for the in-place final rmsnorm.
        normed.copy_from_slice(&x);
        rmsnorm(&normed, &self.final_norm, &mut x);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        self.head.matvec(&x, &mut logits);
        logits
    }

    /// One decode step over a slab of independent sequences (continuous
    /// batching): lane `r` appends `tokens[r]` to `states[r]`, and row `r`
    /// of the returned matrix holds its next-token logits.
    ///
    /// Every linear runs through the batched [`LinearOp::matmul`], so each
    /// quantized weight tile is decoded once per step instead of once per
    /// lane; attention fans the independent (lane, head) items across the
    /// worker pool ([`attention::attention_batch`]), with lanes free to sit
    /// at different positions. Per-lane arithmetic is bit-identical to
    /// [`NativeModel::step`] at any thread count.
    ///
    /// Lanes are any [`KvLaneMut`] slice: a contiguous `&mut [DecodeState]`
    /// slab (the scheduler's zero-allocation path) or a gathered
    /// `&mut [&mut DecodeState]`.
    pub fn step_batch<S: KvLaneMut>(&self, states: &mut [S], tokens: &[u32]) -> Mat {
        let mut scratch = BatchScratch::new();
        self.step_batch_with(&mut scratch, states, tokens);
        scratch.logits
    }

    /// [`NativeModel::step_batch`] with caller-owned scratch buffers: the
    /// decode loop calls this once per generated token, so the per-step
    /// activation buffers — the logits matrix included — are reused instead
    /// of reallocated (they are only re-sized when the batch width changes).
    /// All buffers are fully overwritten before being read, so reuse cannot
    /// leak state between steps. Results land in [`BatchScratch::logits`].
    pub fn step_batch_with<S: KvLaneMut>(
        &self,
        scratch: &mut BatchScratch,
        states: &mut [S],
        tokens: &[u32],
    ) {
        assert_eq!(states.len(), tokens.len(), "one state per token lane");
        let b = tokens.len();
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let theta = self.cfg.rope_theta;
        let scale = 1.0 / (hd as f32).sqrt();
        let ff = self.cfg.d_ff;

        scratch.ensure(b, d, ff, self.cfg.vocab);
        let BatchScratch {
            x,
            normed,
            q,
            k,
            v,
            ctx,
            o,
            gate,
            up,
            down,
            logits,
            pre,
        } = scratch;
        for (r, &tok) in tokens.iter().enumerate() {
            x.row_mut(r).copy_from_slice(self.tok_emb.row(tok as usize));
        }

        for (l, blk) in self.blocks.iter().enumerate() {
            for r in 0..b {
                rmsnorm(x.row(r), &blk.attn_norm, normed.row_mut(r));
            }
            blk.wq.matmul(&normed, &mut q);
            blk.wk.matmul(&normed, &mut k);
            blk.wv.matmul(&normed, &mut v);
            for r in 0..b {
                let pos = states[r].kv().pos;
                for head in 0..h {
                    rope_inplace(&mut q.row_mut(r)[head * hd..(head + 1) * hd], pos, theta);
                    rope_inplace(&mut k.row_mut(r)[head * hd..(head + 1) * hd], pos, theta);
                }
                states[r].kv_mut().append_kv(l, k.row(r), v.row(r));
            }
            attention::attention_batch(l, h, hd, scale, q, &*states, ctx);
            blk.wo.matmul(&ctx, &mut o);
            for (xv, &ov) in x.data.iter_mut().zip(&o.data) {
                *xv += ov;
            }
            for r in 0..b {
                rmsnorm(x.row(r), &blk.mlp_norm, normed.row_mut(r));
            }
            blk.wgate.matmul(&normed, &mut gate);
            blk.wup.matmul(&normed, &mut up);
            for (g, &u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * u;
            }
            blk.wdown.matmul(&gate, &mut down);
            for (xv, &dv) in x.data.iter_mut().zip(&down.data) {
                *xv += dv;
            }
        }
        for st in states.iter_mut() {
            st.kv_mut().pos += 1;
        }
        for r in 0..b {
            pre.clear();
            pre.extend_from_slice(x.row(r));
            rmsnorm(pre, &self.final_norm, x.row_mut(r));
        }
        self.head.matmul(x, logits);
    }

    /// Input activations of every linear over a full sequence: one
    /// (seq_len × d_in) matrix per linear, flat (layer, kind) order.
    pub fn record_linear_inputs(&self, tokens: &[u32]) -> Vec<Mat> {
        let n_lin = self.cfg.n_layers * 7;
        let specs = self.cfg.linear_specs();
        let mut state = self.new_state();
        let mut mats: Vec<Mat> =
            specs.iter().map(|s| Mat::zeros(tokens.len(), s.d_in)).collect();
        for (t, &tok) in tokens.iter().enumerate() {
            let mut rec = Vec::with_capacity(n_lin);
            self.step_recorded(&mut state, tok, &mut rec);
            assert_eq!(rec.len(), n_lin);
            for (li, x) in rec.into_iter().enumerate() {
                mats[li].row_mut(t).copy_from_slice(&x);
            }
        }
        mats
    }

    /// Full-sequence logits (row t = logits after consuming tokens[..=t]).
    pub fn forward_sequence(&self, tokens: &[u32]) -> Mat {
        let mut state = self.new_state();
        let mut out = Mat::zeros(tokens.len(), self.cfg.vocab);
        for (t, &tok) in tokens.iter().enumerate() {
            let logits = self.step(&mut state, tok);
            out.row_mut(t).copy_from_slice(&logits);
        }
        out
    }

    /// Summed next-token cross-entropy over a sequence (matches fwd_loss
    /// semantics for batch rows processed independently).
    pub fn loss_sum(&self, tokens: &[u32]) -> f64 {
        let logits = self.forward_sequence(tokens);
        let mut total = 0.0f64;
        for t in 0..tokens.len() - 1 {
            let row = logits.row(t);
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let lse = max as f64
                + row
                    .iter()
                    .map(|&v| ((v - max) as f64).exp())
                    .sum::<f64>()
                    .ln();
            total += lse - row[tokens[t + 1] as usize] as f64;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::util::Rng;

    fn tiny_model() -> NativeModel {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        NativeModel::from_params(&ps)
    }

    #[test]
    fn step_produces_finite_logits() {
        let m = tiny_model();
        let mut st = m.new_state();
        let logits = m.step(&mut st, 3);
        assert_eq!(logits.len(), m.cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(st.pos, 1);
    }

    #[test]
    fn decode_matches_fresh_replay() {
        // Incremental decode over [a, b, c] must equal replaying the prefix.
        let m = tiny_model();
        let toks = [5u32, 9, 200, 43];
        let full = m.forward_sequence(&toks);
        let mut st = m.new_state();
        for (t, &tok) in toks.iter().enumerate() {
            let logits = m.step(&mut st, tok);
            crate::testing::assert_close(&logits, full.row(t), 1e-5, 1e-5)
                .unwrap_or_else(|e| panic!("pos {t}: {e}"));
        }
    }

    #[test]
    fn f16_kv_decode_tracks_f32_with_greedy_token_equality() {
        // Same model, same token stream, one state per KV dtype: logits
        // must stay ULP-close (the only divergence source is the f16 store
        // rounding of cached K/V) and the greedy continuation must match —
        // the tiny preset's logit gaps dwarf the f16 KV error.
        let m = tiny_model();
        let mut f32_st = m.new_state();
        let mut f16_st = m.new_state_with(crate::cfg::KvDtype::F16);
        assert_eq!(f16_st.kv_dtype(), crate::cfg::KvDtype::F16);
        let argmax = |logits: &[f32]| {
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as u32)
                .unwrap()
        };
        let mut tok_f32 = 7u32;
        let mut tok_f16 = 7u32;
        for step in 0..8 {
            let want = m.step(&mut f32_st, tok_f32);
            let got = m.step(&mut f16_st, tok_f16);
            // ~2^15 f32 ulps ≈ 16 f16 rounding steps of headroom (the
            // error compounds mildly across layers and positions), with an
            // absolute floor for logits that land near zero.
            crate::testing::assert_close_ulp(&got, &want, 1 << 15, 2e-2)
                .unwrap_or_else(|e| panic!("step {step}: {e}"));
            tok_f32 = argmax(&want);
            tok_f16 = argmax(&got);
            assert_eq!(tok_f32, tok_f16, "greedy tokens diverged at step {step}");
        }
        assert_eq!(f16_st.kv_bytes() * 2, f32_st.kv_bytes());
    }

    #[test]
    fn initial_loss_near_uniform() {
        let m = tiny_model();
        let mut rng = Rng::new(1);
        let toks: Vec<u32> = (0..48).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let per_tok = m.loss_sum(&toks) / (toks.len() - 1) as f64;
        let uniform = (m.cfg.vocab as f64).ln();
        assert!((per_tok - uniform).abs() < 1.5, "{per_tok} vs {uniform}");
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(2);
        let mut x: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let before: f32 = x.iter().map(|v| v * v).sum();
        rope_inplace(&mut x, 17, 10000.0);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() < 1e-3 * before);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let orig = x.clone();
        rope_inplace(&mut x, 0, 10000.0);
        crate::testing::assert_close(&x, &orig, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn kv_cache_grows_linearly() {
        let m = tiny_model();
        let mut st = m.new_state();
        m.step(&mut st, 0);
        let b1 = st.kv_bytes();
        m.step(&mut st, 1);
        assert_eq!(st.kv_bytes(), 2 * b1);
    }

    #[test]
    fn step_batch_bitwise_matches_sequential_step() {
        // Three lanes fed different tokens must produce, per lane and per
        // step, EXACTLY the logits the scalar `step` path produces — the
        // invariant the continuous-batching scheduler relies on.
        let m = tiny_model();
        let lanes: [[u32; 3]; 3] = [[5, 9, 2], [3, 8, 1], [250, 0, 7]];

        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for lane in &lanes {
            let mut st = m.new_state();
            want.push(lane.iter().map(|&t| m.step(&mut st, t)).collect());
        }

        let mut states: Vec<DecodeState> = (0..3).map(|_| m.new_state()).collect();
        for step in 0..3 {
            let tokens: Vec<u32> = lanes.iter().map(|l| l[step]).collect();
            let mut refs: Vec<&mut DecodeState> = states.iter_mut().collect();
            let logits = m.step_batch(&mut refs, &tokens);
            for (r, w) in want.iter().enumerate() {
                assert_eq!(logits.row(r), &w[step][..], "lane {r} step {step}");
            }
        }
        for st in &states {
            assert_eq!(st.pos, 3);
        }
    }

    #[test]
    fn step_batch_handles_mixed_positions() {
        // Lanes entering at different times (different pos) stay per-lane
        // consistent with scalar decode.
        let m = tiny_model();
        let mut early = m.new_state();
        m.step(&mut early, 4);
        m.step(&mut early, 11);
        let mut late = m.new_state();
        m.step(&mut late, 9);

        let mut ref_early = m.new_state();
        m.step(&mut ref_early, 4);
        m.step(&mut ref_early, 11);
        let want_early = m.step(&mut ref_early, 2);
        let mut ref_late = m.new_state();
        m.step(&mut ref_late, 9);
        let want_late = m.step(&mut ref_late, 7);

        let mut refs: Vec<&mut DecodeState> = vec![&mut early, &mut late];
        let logits = m.step_batch(&mut refs, &[2, 7]);
        assert_eq!(logits.row(0), &want_early[..]);
        assert_eq!(logits.row(1), &want_late[..]);
        assert_eq!(early.pos, 3);
        assert_eq!(late.pos, 2);
    }

    #[test]
    fn kv_arena_recycles_states_and_pages() {
        let m = tiny_model();
        let mut arena = m.new_arena();
        let mut s = arena.acquire();
        m.step(&mut s, 1);
        m.step(&mut s, 2);
        assert!(s.kv_bytes() > 0);
        let pages_held = s.kv_allocated_bytes();
        assert!(pages_held > 0);
        arena.release(s);
        assert_eq!(arena.pooled(), 1);
        assert!(arena.pooled_pages() > 0, "eviction must return pages to the slab");
        let mut s2 = arena.acquire();
        assert_eq!(arena.pooled(), 0);
        assert_eq!(s2.pos, 0);
        assert_eq!(s2.kv_bytes(), 0);
        // The recycled state re-pages from the slab instead of allocating:
        // after one step it holds slab pages again and the slab drained.
        let pooled_before = arena.pooled_pages();
        m.step(&mut s2, 3);
        assert_eq!(s2.kv_allocated_bytes(), pages_held);
        assert!(arena.pooled_pages() < pooled_before);
    }

    #[test]
    fn decode_across_page_boundary_matches_replay() {
        // A sequence longer than one KV page must keep matching the
        // full-sequence replay bitwise-closely across the boundary.
        use crate::model::KV_PAGE_POS;
        let m = tiny_model();
        let mut rng = Rng::new(21);
        let toks: Vec<u32> =
            (0..KV_PAGE_POS + 5).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let full = m.forward_sequence(&toks);
        let mut st = m.new_state();
        for (t, &tok) in toks.iter().enumerate() {
            let logits = m.step(&mut st, tok);
            crate::testing::assert_close(&logits, full.row(t), 1e-5, 1e-5)
                .unwrap_or_else(|e| panic!("pos {t}: {e}"));
        }
        assert!(st.kv_allocated_bytes() > st.kv_bytes(), "second page only part-filled");
    }

    #[test]
    fn step_batch_matches_step_across_page_boundary() {
        // One lane past the page boundary, one fresh lane: the paged
        // batched path must equal scalar decode EXACTLY for both.
        use crate::model::KV_PAGE_POS;
        let m = tiny_model();
        let depth = KV_PAGE_POS + 2;
        let mut long = m.new_state();
        let mut long_ref = m.new_state();
        for i in 0..depth {
            let t = (i % 97) as u32;
            m.step(&mut long, t);
            m.step(&mut long_ref, t);
        }
        let mut short = m.new_state();
        let mut short_ref = m.new_state();
        m.step(&mut short, 9);
        m.step(&mut short_ref, 9);
        let want_long = m.step(&mut long_ref, 4);
        let want_short = m.step(&mut short_ref, 7);
        let mut refs: Vec<&mut DecodeState> = vec![&mut long, &mut short];
        let logits = m.step_batch(&mut refs, &[4, 7]);
        assert_eq!(logits.row(0), &want_long[..]);
        assert_eq!(logits.row(1), &want_short[..]);
    }

    #[test]
    fn step_batch_accepts_owned_state_slabs() {
        // The scheduler's zero-allocation path passes `&mut [DecodeState]`
        // directly; it must be bit-identical to the gathered-refs form.
        let m = tiny_model();
        let mut slab: Vec<DecodeState> = (0..2).map(|_| m.new_state()).collect();
        let logits_slab = m.step_batch(&mut slab, &[5, 11]);
        let mut a = m.new_state();
        let mut b = m.new_state();
        let mut refs: Vec<&mut DecodeState> = vec![&mut a, &mut b];
        let logits_refs = m.step_batch(&mut refs, &[5, 11]);
        assert_eq!(logits_slab.data, logits_refs.data);
        assert_eq!(slab[0].pos, 1);
    }

    #[test]
    fn mat_matmul_matches_looped_matvec_exactly() {
        let mut rng = Rng::new(9);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let mut xs = Mat::randn(4, 24, 1.0, &mut rng);
        for r in 0..4 {
            xs.row_mut(r)[r] = 0.0; // exercise the zero-skip path
        }
        let mut want = Mat::zeros(4, 10);
        for r in 0..4 {
            LinearOp::matvec(&w, xs.row(r), want.row_mut(r));
        }
        let mut got = Mat::zeros(4, 10);
        LinearOp::matmul(&w, &xs, &mut got);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn col_sharded_matmul_is_bit_identical_at_any_shard_count() {
        let mut rng = Rng::new(10);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let xs = Mat::randn(5, 24, 1.0, &mut rng);
        let mut want = Mat::zeros(5, 10);
        for r in 0..5 {
            LinearOp::matvec(&w, xs.row(r), want.row_mut(r));
        }
        // Includes shard counts that do not divide d_out = 10, and counts
        // above d_out (clamped to one column per shard).
        for shards in [1usize, 2, 3, 4, 7, 10, 13] {
            let mut got = Mat::zeros(5, 10);
            matmul_col_sharded_with(&w, &xs, &mut got, shards);
            assert_eq!(got.data, want.data, "shards={shards}");
        }
        // Wide output exercises the word-aligned boundary branch
        // (per >= 32 rounds up to a multiple of 32; 96/2 -> 64 + 32).
        let w = Mat::randn(16, 96, 1.0, &mut rng);
        let xs = Mat::randn(3, 16, 1.0, &mut rng);
        let mut want = Mat::zeros(3, 96);
        for r in 0..3 {
            LinearOp::matvec(&w, xs.row(r), want.row_mut(r));
        }
        for shards in [2usize, 3, 5] {
            let mut got = Mat::zeros(3, 96);
            matmul_col_sharded_with(&w, &xs, &mut got, shards);
            assert_eq!(got.data, want.data, "wide shards={shards}");
        }
    }

    #[test]
    fn default_matmul_cols_window_matches_matvec() {
        // A LinearOp that only provides matvec exercises the trait-default
        // matmul_cols (full matvec + window copy) and the non-tiled branch
        // of the auto router; it must agree bitwise with Mat's windowed
        // override, shard-by-shard.
        struct MatvecOnly(Mat);
        impl LinearOp for MatvecOnly {
            fn d_in(&self) -> usize {
                self.0.rows
            }
            fn d_out(&self) -> usize {
                self.0.cols
            }
            fn matvec(&self, x: &[f32], out: &mut [f32]) {
                LinearOp::matvec(&self.0, x, out)
            }
            fn storage_bytes(&self) -> usize {
                LinearOp::storage_bytes(&self.0)
            }
        }
        let mut rng = Rng::new(11);
        let w = Mat::randn(16, 9, 1.0, &mut rng);
        let xs = Mat::randn(3, 16, 1.0, &mut rng);
        let wrapped = MatvecOnly(w.clone());
        assert!(!wrapped.supports_decode_tile());
        let (lo, hi) = (2usize, 7usize);
        let mut want = Mat::zeros(3, 9);
        LinearOp::matmul_cols(&w, &xs, &mut ColWindow::window(&mut want, lo, hi));
        let mut got = Mat::zeros(3, 9);
        wrapped.matmul_cols(&xs, &mut ColWindow::window(&mut got, lo, hi));
        for r in 0..3 {
            assert_eq!(got.row(r)[lo..hi], want.row(r)[lo..hi], "row {r}");
        }
        // And the sharded driver over the matvec-only op stays bit-exact.
        let mut full_want = Mat::zeros(3, 9);
        for r in 0..3 {
            LinearOp::matvec(&w, xs.row(r), full_want.row_mut(r));
        }
        let mut full_got = Mat::zeros(3, 9);
        matmul_col_sharded_with(&wrapped, &xs, &mut full_got, 4);
        assert_eq!(full_got.data, full_want.data);
    }

    #[test]
    fn mat_decode_tile_copies_weight_windows() {
        let mut rng = Rng::new(12);
        let w = Mat::randn(10, 7, 1.0, &mut rng);
        let (i0, i1, lo, hi) = (3usize, 8usize, 2usize, 6usize);
        let mut tile = vec![0.0f32; (i1 - i0) * (hi - lo)];
        w.decode_tile(i0, i1, lo, hi, &mut tile);
        for i in i0..i1 {
            for j in lo..hi {
                assert_eq!(tile[(i - i0) * (hi - lo) + (j - lo)], w.at(i, j));
            }
        }
    }

    #[test]
    fn warm_sharded_matmul_is_allocation_free() {
        // Acceptance criterion: the column-sharded batched product must not
        // touch the heap once warm — in-place shard windows, the pool's
        // plain-data helper stubs, and thread-local decode scratch replace
        // every per-call buffer. The probe counts the submitting thread,
        // which always participates in the scatter.
        use crate::testing::alloc_count::count_allocs;
        let mut rng = Rng::new(13);
        let w = Mat::randn(48, 96, 1.0, &mut rng);
        let xs = Mat::randn(4, 48, 1.0, &mut rng);
        let mut out = Mat::zeros(4, 96);
        for _ in 0..3 {
            matmul_col_sharded_with(&w, &xs, &mut out, 4);
        }
        let ((), allocs) = count_allocs(|| {
            for _ in 0..2 {
                matmul_col_sharded_with(&w, &xs, &mut out, 4);
            }
        });
        assert_eq!(allocs, 0, "warm sharded matmul allocated {allocs} time(s)");
    }

    #[test]
    fn storage_accounting_positive() {
        let m = tiny_model();
        assert!(m.linear_storage_bytes() > 0);
        // fp32: 7 linears per block * d*d-ish * 4 bytes
        let (cfg, _) = preset("tiny");
        assert_eq!(m.linear_storage_bytes(), cfg.n_linear_params() * 4);
    }
}
