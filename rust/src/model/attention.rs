//! Decode-time attention: head-major paged KV cache + lane×head-parallel
//! score/context kernels.
//!
//! ## Why this layout
//!
//! The original `DecodeState` stored each layer's cache as one growing
//! `Vec<f32>` in `[pos][d_model]` order, so a head's score loop strided by
//! `d_model` on every dot product and a long-context decode step was
//! dominated by cache misses. Here the cache is **head-major and paged**:
//! each (layer, head) owns a list of fixed-size pages, and page `p` holds
//! positions `[p*KV_PAGE_POS, (p+1)*KV_PAGE_POS)` as contiguous
//! `[pos][head_dim]` rows. A head's score and context loops stream over
//! contiguous memory, and evicting a lane returns whole pages to a shared
//! slab (recycled through [`KvArena`]) instead of freeing one monolithic
//! buffer per layer.
//!
//! ## Parallelism
//!
//! Attention work items are the independent (lane, head) pairs of a batch
//! step: every item reads its own query row and KV page list and writes its
//! own disjoint `head_dim` slice of the context matrix. [`attention_batch`]
//! fans contiguous item ranges across the shared worker pool
//! (`coordinator::run_unit_jobs`) above a work threshold and runs serially
//! below it; per-head accumulation order is identical on both paths, so
//! results are **bit-identical at any thread count**. Score buffers live in
//! a per-worker thread-local scratch sized to the longest context seen, so
//! a warm steady-state step allocates nothing.
//!
//! ## KV storage dtype
//!
//! Pages store either exact `f32` rows (the default) or packed IEEE
//! binary16 rows ([`KvDtype::F16`], the `kv_dtype = f16` serving opt-in),
//! halving the bytes streamed per attended position. f16 rows are widened
//! on read inside the score/context kernels (`simd::dot_f16` /
//! `simd::axpy_f16`) — widening is exact, so the f16 path is just as
//! bit-stable across SIMD levels and thread counts as the f32 path; only
//! the *store* rounds (to nearest even), which is why f32 outputs and f16
//! outputs are ULP-close rather than bit-equal.
//!
//! ## Sharing and copy-on-write
//!
//! Page storage is refcounted (`Arc`), so the *same* physical page can sit
//! in any number of lanes' page lists at once — the substrate of the
//! scheduler's prefix cache: a finished lane's prompt pages are donated to
//! a prefix index and mapped read-only into later lanes that share the
//! prompt prefix. Writes go through [`DecodeState::append_kv`], which only
//! ever touches the *tail* page of each list; appending into a shared tail
//! forks it first (copy-on-write: the already-written rows are copied into
//! a fresh page from the slab and the lane's reference is swapped), so a
//! donor lane's pages are never mutated by a borrower. Full shared pages
//! are never written at all — a page-aligned append opens a fresh page.
//! [`DecodeState::reset`] pools only uniquely-owned pages; shared pages
//! just drop the lane's reference and live on wherever else they are held.

use std::cell::RefCell;
use std::sync::{Arc, Mutex};

use crate::cfg::KvDtype;
use crate::tensor::ops::{axpy, dot, num_threads};
use crate::tensor::{simd, Mat};
use crate::util::half::narrow_slice;

/// Positions per KV page. 64 positions × `head_dim` elements keeps pages in
/// the tens-of-KB range (L1/L2-resident while a head streams them) and
/// makes slab traffic rare: a lane touches the slab once per 64 tokens.
pub const KV_PAGE_POS: usize = 64;

/// One KV page: `KV_PAGE_POS * head_dim` elements in `[pos][head_dim]`
/// rows, stored at the cache's dtype. Storage is refcounted so the same
/// physical page can back any number of lanes (prefix sharing); writers
/// must hold the only reference — [`DecodeState::append_kv`] forks shared
/// tails before writing (copy-on-write).
pub(crate) enum Page {
    F32(Arc<[f32]>),
    F16(Arc<[u16]>),
}

impl Page {
    fn len(&self) -> usize {
        match self {
            Page::F32(p) => p.len(),
            Page::F16(p) => p.len(),
        }
    }

    /// Another reference to the same physical page (refcount bump, no copy).
    pub(crate) fn clone_ref(&self) -> Page {
        match self {
            Page::F32(p) => Page::F32(Arc::clone(p)),
            Page::F16(p) => Page::F16(Arc::clone(p)),
        }
    }

    /// Sole owner of the storage? Only unique pages may be written or
    /// returned to the slab pool; the prefix index evicts only nodes
    /// whose pages are unique (nobody borrows them anymore).
    pub(crate) fn is_unique(&mut self) -> bool {
        match self {
            Page::F32(p) => Arc::get_mut(p).is_some(),
            Page::F16(p) => Arc::get_mut(p).is_some(),
        }
    }

    /// Write one position row, narrowing if the page is f16. The page must
    /// be uniquely owned (append forks shared tails before storing).
    fn store_row(&mut self, slot: usize, hd: usize, row: &[f32]) {
        match self {
            Page::F32(p) => {
                let p = Arc::get_mut(p).expect("COW invariant: writing a shared page");
                p[slot * hd..(slot + 1) * hd].copy_from_slice(row);
            }
            Page::F16(p) => {
                let p = Arc::get_mut(p).expect("COW invariant: writing a shared page");
                narrow_slice(row, &mut p[slot * hd..(slot + 1) * hd]);
            }
        }
    }

    /// Copy the first `elems` elements of `src` into this (uniquely owned)
    /// page — the copy half of a copy-on-write fork.
    fn copy_prefix_from(&mut self, src: &Page, elems: usize) {
        match (self, src) {
            (Page::F32(dst), Page::F32(src)) => {
                let dst = Arc::get_mut(dst).expect("COW fork target must be unique");
                dst[..elems].copy_from_slice(&src[..elems]);
            }
            (Page::F16(dst), Page::F16(src)) => {
                let dst = Arc::get_mut(dst).expect("COW fork target must be unique");
                dst[..elems].copy_from_slice(&src[..elems]);
            }
            _ => unreachable!("a slab's pages share one dtype"),
        }
    }
}

/// Shared recycling slab of KV pages (all pages of one model share a size
/// and dtype, so any lane's freed page can back any other lane's growth).
/// Lock traffic is confined to page-boundary crossings and lane eviction.
pub(crate) struct PageSlab {
    page_elems: usize,
    dtype: KvDtype,
    free: Mutex<Vec<Page>>,
}

impl PageSlab {
    fn new(head_dim: usize, dtype: KvDtype) -> Self {
        PageSlab { page_elems: KV_PAGE_POS * head_dim, dtype, free: Mutex::new(Vec::new()) }
    }

    fn fresh(&self) -> Page {
        match self.dtype {
            KvDtype::F32 => Page::F32(vec![0.0f32; self.page_elems].into()),
            KvDtype::F16 => Page::F16(vec![0u16; self.page_elems].into()),
        }
    }

    /// Pop a recycled page, or allocate a fresh zeroed one (cold path).
    fn take(&self) -> Page {
        self.free.lock().unwrap().pop().unwrap_or_else(|| self.fresh())
    }

    fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    fn reserve(&self, pages: usize) {
        let mut free = self.free.lock().unwrap();
        while free.len() < pages {
            free.push(self.fresh());
        }
    }
}

/// Per-sequence KV cache, head-major and paged: page list `[layer][head]`
/// (flattened `layer * n_heads + head`), keys and values separate so the
/// score pass streams key pages and the context pass streams value pages.
pub struct DecodeState {
    n_heads: usize,
    head_dim: usize,
    dtype: KvDtype,
    key_pages: Vec<Vec<Page>>,
    val_pages: Vec<Vec<Page>>,
    /// Number of completed decode steps (the next append writes slot
    /// `pos % KV_PAGE_POS` of page `pos / KV_PAGE_POS`).
    pub pos: usize,
    /// Leading pages (per list) borrowed from a shared prefix rather than
    /// owned: [`DecodeState::kv_owned_bytes`] excludes them so the memory
    /// governor charges shared pages once (to their cache), and the count
    /// drops by one when a borrowed partial tail is forked on write.
    borrowed_pages: usize,
    slab: Arc<PageSlab>,
}

impl DecodeState {
    /// A standalone state with its own private page slab (pages still
    /// recycle across [`DecodeState::reset`]). Serving lanes should come
    /// from a [`KvArena`] instead so evicted pages are shared. Exact f32
    /// storage; see [`DecodeState::with_dtype`] for the f16 opt-in.
    pub fn new(n_layers: usize, n_heads: usize, head_dim: usize) -> Self {
        Self::with_dtype(n_layers, n_heads, head_dim, KvDtype::F32)
    }

    /// [`DecodeState::new`] at an explicit KV storage dtype.
    pub fn with_dtype(n_layers: usize, n_heads: usize, head_dim: usize, dtype: KvDtype) -> Self {
        Self::with_slab(n_layers, n_heads, head_dim, Arc::new(PageSlab::new(head_dim, dtype)))
    }

    fn with_slab(n_layers: usize, n_heads: usize, head_dim: usize, slab: Arc<PageSlab>) -> Self {
        let lists = n_layers * n_heads;
        DecodeState {
            n_heads,
            head_dim,
            dtype: slab.dtype,
            key_pages: (0..lists).map(|_| Vec::new()).collect(),
            val_pages: (0..lists).map(|_| Vec::new()).collect(),
            pos: 0,
            borrowed_pages: 0,
            slab,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.key_pages.len() / self.n_heads.max(1)
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Storage dtype of this cache's pages.
    pub fn kv_dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Logical cache size: bytes of K+V actually stored, linear in `pos`
    /// and in the dtype width (page-granular over-allocation is reported by
    /// [`DecodeState::kv_allocated_bytes`]).
    pub fn kv_bytes(&self) -> usize {
        2 * self.key_pages.len() * self.head_dim * self.pos * self.dtype.bytes()
    }

    /// Bytes of page storage this state references (a multiple of the page
    /// size). Shared prefix pages count here too — see
    /// [`DecodeState::kv_owned_bytes`] for the governor's charged-once view.
    pub fn kv_allocated_bytes(&self) -> usize {
        let pages: usize = self.key_pages.iter().chain(&self.val_pages).map(Vec::len).sum();
        pages * KV_PAGE_POS * self.head_dim * self.dtype.bytes()
    }

    /// Bytes of page storage this lane *owns*: referenced pages minus the
    /// leading pages borrowed from a shared prefix (those are charged once,
    /// to the cache that holds them). Falls back to the full count when
    /// nothing is borrowed, so non-sharing callers see no change.
    pub fn kv_owned_bytes(&self) -> usize {
        let pages: usize = self.key_pages.iter().chain(&self.val_pages).map(Vec::len).sum();
        let borrowed = 2 * self.key_pages.len() * self.borrowed_pages;
        pages.saturating_sub(borrowed) * KV_PAGE_POS * self.head_dim * self.dtype.bytes()
    }

    /// Leading pages (per list) currently borrowed from a shared prefix.
    pub fn borrowed_prefix_pages(&self) -> usize {
        self.borrowed_pages
    }

    /// Fork `list`'s tail page if it is shared: copy the `elems` elements
    /// already written into a fresh page from the slab and swap the lane's
    /// reference to it. No-op (and no copy) when the tail is already
    /// uniquely owned — the steady-state decode path.
    fn fork_shared_tail(list: &mut [Page], elems: usize, slab: &PageSlab) {
        let tail = list.last_mut().expect("fork target list is non-empty");
        if tail.is_unique() {
            return;
        }
        let mut fresh = slab.take();
        fresh.copy_prefix_from(tail, elems);
        *tail = fresh;
    }

    /// Append one step's K/V rows (`d_model` floats each) for `layer` at
    /// the current position, splitting them per head into the page tails.
    /// Grabs a page from the slab when the position opens a new page, and
    /// copy-on-write-forks a shared tail page before writing into it — a
    /// lane extending a borrowed prefix never mutates the donor's pages.
    pub fn append_kv(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let hd = self.head_dim;
        debug_assert_eq!(k.len(), self.n_heads * hd);
        debug_assert_eq!(v.len(), self.n_heads * hd);
        let slot = self.pos % KV_PAGE_POS;
        let base = layer * self.n_heads;
        for head in 0..self.n_heads {
            let idx = base + head;
            if slot == 0 {
                self.key_pages[idx].push(self.slab.take());
                self.val_pages[idx].push(self.slab.take());
            } else {
                Self::fork_shared_tail(&mut self.key_pages[idx], slot * hd, &self.slab);
                Self::fork_shared_tail(&mut self.val_pages[idx], slot * hd, &self.slab);
            }
            let seg = &k[head * hd..(head + 1) * hd];
            self.key_pages[idx].last_mut().unwrap().store_row(slot, hd, seg);
            let seg = &v[head * hd..(head + 1) * hd];
            self.val_pages[idx].last_mut().unwrap().store_row(slot, hd, seg);
        }
        // Writing into the last borrowed page claims it for this lane
        // (fork_shared_tail above made it unique, or the last outside
        // reference was already gone): account it once, at the first layer
        // — every layer's lists fork at the same position within one step.
        if layer == 0
            && slot != 0
            && self.borrowed_pages > 0
            && self.key_pages[0].len() == self.borrowed_pages
        {
            self.borrowed_pages -= 1;
        }
    }

    /// Map one full shared page per (list, K/V) onto the tail of this
    /// state — the prefix-cache admission path. The state must be
    /// page-aligned and fully borrowed so far (a fresh lane absorbing
    /// cached chunks front-to-back); `pos` advances by a whole page.
    pub(crate) fn borrow_prefix_chunk(&mut self, keys: &[Page], vals: &[Page]) {
        debug_assert_eq!(self.pos % KV_PAGE_POS, 0, "chunk borrow must be page-aligned");
        debug_assert_eq!(self.pos / KV_PAGE_POS, self.borrowed_pages);
        debug_assert_eq!(keys.len(), self.key_pages.len());
        debug_assert_eq!(vals.len(), self.val_pages.len());
        for (list, page) in self.key_pages.iter_mut().zip(keys) {
            list.push(page.clone_ref());
        }
        for (list, page) in self.val_pages.iter_mut().zip(vals) {
            list.push(page.clone_ref());
        }
        self.pos += KV_PAGE_POS;
        self.borrowed_pages += 1;
    }

    /// Clone the K/V page references at page index `page_idx` of every
    /// list — the donation path (a finished lane handing one prompt chunk
    /// to the prefix index). Refcount bumps only; no page data is copied.
    pub(crate) fn clone_prefix_chunk(&self, page_idx: usize) -> (Vec<Page>, Vec<Page>) {
        let keys = self.key_pages.iter().map(|l| l[page_idx].clone_ref()).collect();
        let vals = self.val_pages.iter().map(|l| l[page_idx].clone_ref()).collect();
        (keys, vals)
    }

    /// Share the first `positions` positions of `donor`'s cache into this
    /// (fresh) state by reference: the covering pages are mapped in and
    /// `pos` jumps past them. A non-page-aligned share leaves the tail
    /// page partially borrowed — the first append into it forks it
    /// (copy-on-write), never mutating the donor. Exposed for the COW
    /// tests; the scheduler shares page-aligned chunks via the prefix
    /// index instead.
    pub fn share_prefix_from(&mut self, donor: &DecodeState, positions: usize) {
        assert_eq!(self.pos, 0, "share target must be a fresh state");
        assert_eq!(self.dtype, donor.dtype, "shared pages must agree on dtype");
        assert_eq!(self.key_pages.len(), donor.key_pages.len());
        assert_eq!(self.head_dim, donor.head_dim);
        let pages = positions.div_ceil(KV_PAGE_POS);
        for (dst, src) in self
            .key_pages
            .iter_mut()
            .zip(&donor.key_pages)
            .chain(self.val_pages.iter_mut().zip(&donor.val_pages))
        {
            debug_assert!(dst.is_empty());
            dst.extend(src[..pages].iter().map(Page::clone_ref));
        }
        self.pos = positions;
        self.borrowed_pages = pages;
    }

    #[inline]
    pub(crate) fn key_pages(&self, layer: usize, head: usize) -> &[Page] {
        &self.key_pages[layer * self.n_heads + head]
    }

    #[inline]
    pub(crate) fn val_pages(&self, layer: usize, head: usize) -> &[Page] {
        &self.val_pages[layer * self.n_heads + head]
    }

    /// Clear for reuse: every *uniquely owned* page returns to the slab
    /// (the per-list `Vec`s keep their capacity, so a recycled lane
    /// re-pages without allocating). Shared pages — donated to the prefix
    /// index, or still borrowed by another lane — just drop this state's
    /// reference; pooling them would hand out writable aliases.
    pub fn reset(&mut self) {
        let mut free = self.slab.free.lock().unwrap();
        for list in self.key_pages.iter_mut().chain(self.val_pages.iter_mut()) {
            for mut page in list.drain(..) {
                if page.is_unique() {
                    free.push(page);
                }
            }
        }
        drop(free);
        self.pos = 0;
        self.borrowed_pages = 0;
    }

    /// Clear for reuse, **dropping** the pages back to the system allocator
    /// instead of pooling them. This is the memory-governance release: a
    /// preempted lane must actually shrink the resident KV footprint
    /// (pooled pages still count as allocated), so its pages deallocate.
    /// (Shared pages only drop this reference and deallocate when the last
    /// holder lets go.)
    pub fn reset_discarding(&mut self) {
        for list in self.key_pages.iter_mut().chain(self.val_pages.iter_mut()) {
            list.clear();
        }
        self.pos = 0;
        self.borrowed_pages = 0;
    }

    fn rebind(&mut self, slab: Arc<PageSlab>) {
        debug_assert_eq!(slab.page_elems, KV_PAGE_POS * self.head_dim);
        debug_assert_eq!(slab.dtype, self.dtype);
        self.slab = slab;
    }
}

/// Pool of KV caches for the batched serve path, now page-granular:
/// releasing an evicted lane returns its *pages* to a shared slab
/// (plus the state shell, so the per-head list `Vec`s keep their capacity),
/// and any growing lane pulls those pages back out — continuous batching
/// splices requests in and out with zero steady-state allocator traffic.
pub struct KvArena {
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    dtype: KvDtype,
    slab: Arc<PageSlab>,
    free: Vec<DecodeState>,
}

impl KvArena {
    /// An arena of exact-f32 caches; see [`KvArena::with_dtype`] for the
    /// f16 serving opt-in.
    pub fn new(n_layers: usize, n_heads: usize, head_dim: usize) -> Self {
        Self::with_dtype(n_layers, n_heads, head_dim, KvDtype::F32)
    }

    /// [`KvArena::new`] at an explicit KV storage dtype: every lane this
    /// arena hands out pages at `dtype`.
    pub fn with_dtype(n_layers: usize, n_heads: usize, head_dim: usize, dtype: KvDtype) -> Self {
        KvArena {
            n_layers,
            n_heads,
            head_dim,
            dtype,
            slab: Arc::new(PageSlab::new(head_dim, dtype)),
            free: Vec::new(),
        }
    }

    /// Storage dtype of the lanes this arena hands out.
    pub fn kv_dtype(&self) -> KvDtype {
        self.dtype
    }

    /// A fresh (pos = 0) state wired to the arena's shared page slab.
    pub fn acquire(&mut self) -> DecodeState {
        self.free.pop().unwrap_or_else(|| {
            let slab = Arc::clone(&self.slab);
            DecodeState::with_slab(self.n_layers, self.n_heads, self.head_dim, slab)
        })
    }

    pub fn release(&mut self, mut state: DecodeState) {
        debug_assert_eq!(state.n_layers(), self.n_layers);
        debug_assert_eq!(state.head_dim, self.head_dim);
        // A state of a different dtype cannot share this slab (its pages
        // are the wrong storage); just drop it.
        if state.dtype != self.dtype {
            return;
        }
        // A foreign state (built via `DecodeState::new`) adopts this
        // arena's slab so its pages land here rather than being stranded.
        state.rebind(Arc::clone(&self.slab));
        state.reset();
        self.free.push(state);
    }

    /// Number of state shells currently pooled.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// Number of KV pages currently pooled in the shared slab.
    pub fn pooled_pages(&self) -> usize {
        self.slab.pooled()
    }

    /// Bytes of page storage sitting in the shared slab (the allocated-but
    /// -idle part of the serving KV footprint).
    pub fn pooled_page_bytes(&self) -> usize {
        self.slab.pooled() * self.slab.page_elems * self.dtype.bytes()
    }

    /// Pre-allocate slab pages so decode-time page grabs never hit the
    /// system allocator (e.g. before latency-sensitive serving). Callers
    /// under a KV budget should go through
    /// [`KvArena::reserve_pages_capped`] so pre-warm respects the same
    /// ceiling admission enforces.
    pub fn reserve_pages(&self, pages: usize) {
        self.slab.reserve(pages);
    }

    /// [`KvArena::reserve_pages`], clamped so the pooled pre-warm can
    /// never allocate past `budget_bytes` of page storage (0 = no budget).
    /// Pooled pages count against `kv_allocated_bytes`, so an ungoverned
    /// pre-warm could exceed the budget the admission path enforces.
    pub fn reserve_pages_capped(&self, pages: usize, budget_bytes: usize) {
        let pages = if budget_bytes == 0 {
            pages
        } else {
            pages.min(budget_bytes / self.page_bytes().max(1))
        };
        self.slab.reserve(pages);
    }

    /// Bytes of one KV page at this arena's geometry and dtype.
    pub fn page_bytes(&self) -> usize {
        KV_PAGE_POS * self.head_dim * self.dtype.bytes()
    }

    /// Worst-case KV pages a request occupying `total_pos` positions
    /// (prompt length + `max_tokens`) will hold: one K and one V page per
    /// `(layer, head)` for every started 64-position page.
    pub fn request_cost_pages(&self, total_pos: usize) -> usize {
        total_pos.div_ceil(KV_PAGE_POS) * 2 * self.n_layers * self.n_heads
    }

    /// Worst-case KV bytes for a request of `total_pos` positions — the
    /// admission-time cost estimate the memory governor budgets against.
    pub fn request_cost_bytes(&self, total_pos: usize) -> usize {
        self.request_cost_pages(total_pos) * self.page_bytes()
    }

    /// [`KvArena::request_cost_bytes`] for a request whose first
    /// `cached_pos` positions (page-aligned) are borrowed from the prefix
    /// cache: the covering pages are already charged once — to the cache —
    /// so admission must not charge them again.
    pub fn request_cost_bytes_shared(&self, total_pos: usize, cached_pos: usize) -> usize {
        debug_assert_eq!(cached_pos % KV_PAGE_POS, 0, "prefix shares are page-aligned");
        let cached = (cached_pos / KV_PAGE_POS) * 2 * self.n_layers * self.n_heads;
        self.request_cost_pages(total_pos).saturating_sub(cached) * self.page_bytes()
    }

    /// Release a preempted lane's state with its pages **deallocated**
    /// rather than pooled (see [`DecodeState::reset_discarding`]); the
    /// shell is still recycled.
    pub fn discard(&mut self, mut state: DecodeState) {
        debug_assert_eq!(state.n_layers(), self.n_layers);
        debug_assert_eq!(state.head_dim, self.head_dim);
        if state.dtype != self.dtype {
            return;
        }
        state.rebind(Arc::clone(&self.slab));
        state.reset_discarding();
        self.free.push(state);
    }

    /// Drop pooled slab pages until at most `max_bytes` of page storage
    /// remains idle in the pool. Governance uses this to shed resident
    /// memory that recycling would otherwise hold forever.
    pub fn trim_pooled_to(&self, max_bytes: usize) {
        let page = self.page_bytes().max(1);
        let mut free = self.slab.free.lock().unwrap();
        while free.len() * page > max_bytes {
            free.pop();
        }
    }
}

/// Read access to a lane's KV cache. Implemented for owned states, `&mut`,
/// and `&` references so the batched step accepts either a contiguous state
/// slab (`&mut [DecodeState]`, the scheduler's zero-allocation path) or a
/// gathered `&mut [&mut DecodeState]` (tests, prefill subsets) without
/// repacking.
pub trait KvLane: Sync {
    fn kv(&self) -> &DecodeState;
}

/// Mutable access on top of [`KvLane`] (the batched step appends K/V and
/// advances `pos`).
pub trait KvLaneMut: KvLane + Send {
    fn kv_mut(&mut self) -> &mut DecodeState;
}

impl KvLane for DecodeState {
    fn kv(&self) -> &DecodeState {
        self
    }
}

impl KvLaneMut for DecodeState {
    fn kv_mut(&mut self) -> &mut DecodeState {
        self
    }
}

impl KvLane for &mut DecodeState {
    fn kv(&self) -> &DecodeState {
        self
    }
}

impl KvLaneMut for &mut DecodeState {
    fn kv_mut(&mut self) -> &mut DecodeState {
        self
    }
}

impl KvLane for &DecodeState {
    fn kv(&self) -> &DecodeState {
        self
    }
}

/// Minimum total multiply-accumulates (summed over lanes and heads) before
/// a batch attention call fans out on the worker pool.
const ATTN_MIN_WORK: usize = 1 << 16;

thread_local! {
    /// Per-worker score scratch: grows to the longest context this thread
    /// has attended over and is reused forever after — the zero-allocation
    /// steady state of the token loop.
    static SCORES: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Softmax attention for one (lane, head) work item over its paged cache.
///
/// Accumulation order is exactly the pre-paging kernel's: scores in
/// ascending position order (8-way unrolled [`dot`]), single max, exp/sum
/// in position order, then the context axpy in position order — only the
/// *addresses* changed (contiguous pages instead of `d_model`-strided
/// rows), so results are bit-identical to the historical layout. The max
/// is taken over the filled score buffer ([`simd::max`]): f32 max over
/// finite scores is order-independent, so hoisting it out of the score
/// loop changes nothing. f16 pages widen on read (exactly), so the f16
/// path has the same bit-stability across SIMD levels and thread counts.
#[allow(clippy::too_many_arguments)]
fn head_attention(
    qh: &[f32],
    key_pages: &[Page],
    val_pages: &[Page],
    n_pos: usize,
    hd: usize,
    scale: f32,
    scores: &mut Vec<f32>,
    ctx_h: &mut [f32],
) {
    scores.clear();
    let mut p = 0;
    'score: for page in key_pages {
        debug_assert_eq!(page.len() % hd, 0);
        match page {
            Page::F32(rows) => {
                for kh in rows.chunks_exact(hd) {
                    if p == n_pos {
                        break 'score;
                    }
                    scores.push(dot(qh, kh) * scale);
                    p += 1;
                }
            }
            Page::F16(rows) => {
                for kh in rows.chunks_exact(hd) {
                    if p == n_pos {
                        break 'score;
                    }
                    scores.push(simd::dot_f16(qh, kh) * scale);
                    p += 1;
                }
            }
        }
    }
    debug_assert_eq!(scores.len(), n_pos, "page list shorter than n_pos");
    let max_s = simd::max(scores);
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - max_s).exp();
        denom += *s;
    }
    ctx_h.fill(0.0);
    let mut p = 0;
    'ctx: for page in val_pages {
        match page {
            Page::F32(rows) => {
                for vh in rows.chunks_exact(hd) {
                    if p == n_pos {
                        break 'ctx;
                    }
                    axpy(ctx_h, scores[p] / denom, vh);
                    p += 1;
                }
            }
            Page::F16(rows) => {
                for vh in rows.chunks_exact(hd) {
                    if p == n_pos {
                        break 'ctx;
                    }
                    simd::axpy_f16(ctx_h, scores[p] / denom, vh);
                    p += 1;
                }
            }
        }
    }
}

/// One (lane, head) item of a flattened batch: item `i` is lane `i / h`,
/// head `i % h`, and owns context chunk `i` (the `hd`-float slices of the
/// context matrix in row-major order are exactly the items in order).
#[inline]
#[allow(clippy::too_many_arguments)]
fn item_attention<S: KvLane>(
    layer: usize,
    h: usize,
    hd: usize,
    scale: f32,
    qdata: &[f32],
    states: &[S],
    item: usize,
    scores: &mut Vec<f32>,
    ctx_h: &mut [f32],
) {
    let lane = item / h;
    let head = item % h;
    let st = states[lane].kv();
    let n_pos = st.pos + 1;
    let d = h * hd;
    let qh = &qdata[lane * d + head * hd..lane * d + (head + 1) * hd];
    head_attention(
        qh,
        st.key_pages(layer, head),
        st.val_pages(layer, head),
        n_pos,
        hd,
        scale,
        scores,
        ctx_h,
    );
}

#[allow(clippy::too_many_arguments)]
fn attention_impl<S: KvLane>(
    layer: usize,
    h: usize,
    hd: usize,
    scale: f32,
    qdata: &[f32],
    states: &[S],
    ctxdata: &mut [f32],
    threads: usize,
) {
    let b = states.len();
    debug_assert_eq!(qdata.len(), b * h * hd);
    debug_assert_eq!(ctxdata.len(), b * h * hd);
    let items = b * h;
    if items == 0 {
        return;
    }
    let threads = threads.clamp(1, items);
    if threads <= 1 {
        SCORES.with(|s| {
            let scores = &mut *s.borrow_mut();
            for (item, ctx_h) in ctxdata.chunks_mut(hd).enumerate() {
                item_attention(layer, h, hd, scale, qdata, states, item, scores, ctx_h);
            }
        });
        return;
    }
    // Fan contiguous (lane, head) ranges out as indexed scatter items on
    // the pool: item t's range is computed from t, and its slice of the
    // context buffer is carved from a shared handle — so a warm batched
    // attention step submits with zero heap allocations. Per-item
    // arithmetic is the serial path's, so partitioning never changes
    // values — only which worker computes which head.
    let per = items.div_ceil(threads);
    let n_jobs = items.div_ceil(per);
    let ctx = crate::coordinator::Scatter::new(ctxdata);
    crate::coordinator::run_indexed(n_jobs, n_jobs, &|t| {
        let start = t * per;
        let take = per.min(items - start);
        // SAFETY: item t writes head slices [start, start + take) — ranges
        // of distinct items are disjoint and in bounds.
        let part = unsafe { ctx.slice_mut(start * hd, take * hd) };
        SCORES.with(|s| {
            let scores = &mut *s.borrow_mut();
            for (j, ctx_h) in part.chunks_mut(hd).enumerate() {
                item_attention(layer, h, hd, scale, qdata, states, start + j, scores, ctx_h);
            }
        });
    });
}

/// Attention for a batch decode step: lane `r` of `q`/`ctx` attends over
/// `states[r]`'s cached positions for `layer` (the current token's K/V must
/// already be appended; `pos` not yet advanced). Fans (lane, head) items
/// across the worker pool above a work threshold, serial below it —
/// bit-identical either way.
pub fn attention_batch<S: KvLane>(
    layer: usize,
    n_heads: usize,
    head_dim: usize,
    scale: f32,
    q: &Mat,
    states: &[S],
    ctx: &mut Mat,
) {
    let total_pos: usize = states.iter().map(|s| s.kv().pos + 1).sum();
    let work = total_pos * n_heads * head_dim * 2;
    let threads = if work < ATTN_MIN_WORK {
        1
    } else {
        num_threads().min(states.len() * n_heads)
    };
    attention_batch_with(layer, n_heads, head_dim, scale, q, states, ctx, threads);
}

/// [`attention_batch`] with an explicit worker count (1 = serial). Exposed
/// for the bit-identity tests and the serial-vs-pool bench rows.
#[allow(clippy::too_many_arguments)]
pub fn attention_batch_with<S: KvLane>(
    layer: usize,
    n_heads: usize,
    head_dim: usize,
    scale: f32,
    q: &Mat,
    states: &[S],
    ctx: &mut Mat,
    threads: usize,
) {
    debug_assert_eq!(q.rows, states.len());
    debug_assert_eq!(ctx.rows, states.len());
    debug_assert_eq!(q.cols, n_heads * head_dim);
    debug_assert_eq!(ctx.cols, n_heads * head_dim);
    attention_impl(layer, n_heads, head_dim, scale, &q.data, states, &mut ctx.data, threads);
}

/// Single-lane attention (the scalar [`NativeModel::step`] path): same
/// kernel, heads fanned across the pool only when one lane's context is
/// long enough to clear the work threshold.
///
/// [`NativeModel::step`]: super::NativeModel::step
pub fn attention_single(
    layer: usize,
    n_heads: usize,
    head_dim: usize,
    scale: f32,
    q: &[f32],
    state: &DecodeState,
    ctx: &mut [f32],
) {
    let work = (state.pos + 1) * n_heads * head_dim * 2;
    let threads = if work < ATTN_MIN_WORK { 1 } else { num_threads().min(n_heads) };
    attention_impl(layer, n_heads, head_dim, scale, q, &[state][..], ctx, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reference attention over an interleaved `[pos][d_model]` cache —
    /// the exact pre-paging kernel, kept as the bitwise oracle.
    fn reference_attention(
        q: &[f32],
        keys: &[f32],
        vals: &[f32],
        h: usize,
        hd: usize,
        n_pos: usize,
        scale: f32,
    ) -> Vec<f32> {
        let d = h * hd;
        let mut ctx = vec![0.0f32; d];
        for head in 0..h {
            let qh = &q[head * hd..(head + 1) * hd];
            let mut scores = Vec::with_capacity(n_pos);
            let mut max_s = f32::NEG_INFINITY;
            for p in 0..n_pos {
                let kh = &keys[p * d + head * hd..p * d + (head + 1) * hd];
                let s = dot(qh, kh) * scale;
                max_s = max_s.max(s);
                scores.push(s);
            }
            let mut denom = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - max_s).exp();
                denom += *s;
            }
            let ctx_h = &mut ctx[head * hd..(head + 1) * hd];
            for p in 0..n_pos {
                let w = scores[p] / denom;
                let vh = &vals[p * d + head * hd..p * d + (head + 1) * hd];
                for (c, &vv) in ctx_h.iter_mut().zip(vh) {
                    *c += w * vv;
                }
            }
        }
        ctx
    }

    /// Fill `state` with `n_pos` random positions for every layer and set
    /// `pos` so the next attention call sees exactly `n_pos` positions
    /// (mirrors a step: current token appended, pos not yet advanced).
    /// Returns the interleaved per-layer (keys, vals) the old layout held.
    fn fill_state(
        state: &mut DecodeState,
        n_layers: usize,
        d: usize,
        n_pos: usize,
        rng: &mut Rng,
    ) -> Vec<(Vec<f32>, Vec<f32>)> {
        let mut flat = vec![(Vec::new(), Vec::new()); n_layers];
        for p in 0..n_pos {
            for (l, fl) in flat.iter_mut().enumerate() {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                state.append_kv(l, &k, &v);
                fl.0.extend_from_slice(&k);
                fl.1.extend_from_slice(&v);
            }
            if p + 1 < n_pos {
                state.pos += 1;
            }
        }
        flat
    }

    #[test]
    fn paged_layout_matches_interleaved_reference_bitwise() {
        // Crosses a page boundary (n_pos > KV_PAGE_POS) and uses 2 layers.
        let (h, hd, n_layers) = (4usize, 8usize, 2usize);
        let d = h * hd;
        let n_pos = KV_PAGE_POS + 9;
        let mut rng = Rng::new(3);
        let mut state = DecodeState::new(n_layers, h, hd);
        let flat = fill_state(&mut state, n_layers, d, n_pos, &mut rng);
        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..n_layers {
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let want = reference_attention(&q, &flat[l].0, &flat[l].1, h, hd, n_pos, scale);
            let mut ctx = vec![0.0f32; d];
            attention_single(l, h, hd, scale, &q, &state, &mut ctx);
            assert_eq!(ctx, want, "layer {l}");
        }
    }

    #[test]
    fn batch_attention_is_bit_identical_at_any_thread_count() {
        // Mixed lane positions, one lane past a page boundary.
        let (h, hd) = (4usize, 8usize);
        let d = h * hd;
        let mut rng = Rng::new(7);
        let positions = [3usize, KV_PAGE_POS + 5, 17];
        let mut states: Vec<DecodeState> = Vec::new();
        for &n_pos in &positions {
            let mut st = DecodeState::new(1, h, hd);
            fill_state(&mut st, 1, d, n_pos, &mut rng);
            states.push(st);
        }
        let q = Mat::randn(states.len(), d, 1.0, &mut rng);
        let refs: Vec<&DecodeState> = states.iter().collect();
        let scale = 1.0 / (hd as f32).sqrt();
        let mut want = Mat::zeros(states.len(), d);
        attention_batch_with(0, h, hd, scale, &q, &refs, &mut want, 1);
        for threads in [2usize, 3, 4, 7, 12] {
            let mut got = Mat::zeros(states.len(), d);
            attention_batch_with(0, h, hd, scale, &q, &refs, &mut got, threads);
            assert_eq!(got.data, want.data, "threads={threads}");
        }
        // The auto driver (threshold + pool width) agrees too.
        let mut auto = Mat::zeros(states.len(), d);
        attention_batch(0, h, hd, scale, &q, &refs, &mut auto);
        assert_eq!(auto.data, want.data);
    }

    #[test]
    fn pages_allocate_lazily_and_kv_bytes_stays_linear() {
        let (h, hd) = (2usize, 8usize);
        let d = h * hd;
        let mut st = DecodeState::new(1, h, hd);
        assert_eq!(st.kv_bytes(), 0);
        assert_eq!(st.kv_allocated_bytes(), 0);
        let k = vec![1.0f32; d];
        let v = vec![2.0f32; d];
        let mut per_pos = 0;
        for p in 0..KV_PAGE_POS + 3 {
            st.append_kv(0, &k, &v);
            st.pos += 1;
            if p == 0 {
                per_pos = st.kv_bytes();
                assert!(per_pos > 0);
            }
            assert_eq!(st.kv_bytes(), per_pos * (p + 1), "pos {p}");
        }
        // One page per (layer=1, head=2) K and V list for the first 64
        // positions, then a second page each after the boundary.
        assert_eq!(st.kv_allocated_bytes(), 2 * 2 * 2 * KV_PAGE_POS * hd * 4);
    }

    #[test]
    fn eviction_returns_pages_to_the_arena_slab() {
        let (n_layers, h, hd) = (2usize, 2usize, 8usize);
        let d = h * hd;
        let mut arena = KvArena::new(n_layers, h, hd);
        let mut st = arena.acquire();
        let row = vec![0.5f32; d];
        for _ in 0..KV_PAGE_POS + 1 {
            for l in 0..n_layers {
                st.append_kv(l, &row, &row);
            }
            st.pos += 1;
        }
        // 2 pages per (layer, head) per K/V list: 2 layers * 2 heads * 2
        // lists * 2 pages.
        let held = 2 * n_layers * h * 2;
        assert_eq!(st.kv_allocated_bytes(), held * KV_PAGE_POS * hd * 4);
        assert_eq!(arena.pooled_pages(), 0);
        arena.release(st);
        assert_eq!(arena.pooled(), 1);
        assert_eq!(arena.pooled_pages(), held, "eviction must return whole pages");
        // A recycled lane re-pages from the slab instead of allocating.
        let mut st2 = arena.acquire();
        assert_eq!(st2.pos, 0);
        assert_eq!(st2.kv_bytes(), 0);
        for l in 0..n_layers {
            st2.append_kv(l, &row, &row);
        }
        st2.pos += 1;
        assert_eq!(arena.pooled_pages(), held - n_layers * h * 2);
    }

    #[test]
    fn request_cost_matches_actual_page_growth() {
        let (n_layers, h, hd) = (2usize, 2usize, 8usize);
        let d = h * hd;
        let mut arena = KvArena::new(n_layers, h, hd);
        assert_eq!(arena.page_bytes(), KV_PAGE_POS * hd * 4);
        // The estimate is exact for any position count: run a lane to
        // `total_pos` and compare against what it actually holds.
        for total_pos in [1usize, KV_PAGE_POS, KV_PAGE_POS + 1, 3 * KV_PAGE_POS] {
            let mut st = arena.acquire();
            let row = vec![0.5f32; d];
            for _ in 0..total_pos {
                for l in 0..n_layers {
                    st.append_kv(l, &row, &row);
                }
                st.pos += 1;
            }
            assert_eq!(
                st.kv_allocated_bytes(),
                arena.request_cost_bytes(total_pos),
                "cost estimate must match actual allocation at pos {total_pos}"
            );
            arena.release(st);
        }
        assert_eq!(arena.request_cost_bytes(0), 0);
    }

    #[test]
    fn discard_drops_pages_but_recycles_the_shell() {
        let (n_layers, h, hd) = (1usize, 2usize, 8usize);
        let d = h * hd;
        let mut arena = KvArena::new(n_layers, h, hd);
        let mut st = arena.acquire();
        let row = vec![0.5f32; d];
        st.append_kv(0, &row, &row);
        st.pos += 1;
        arena.discard(st);
        assert_eq!(arena.pooled(), 1, "shell must recycle");
        assert_eq!(arena.pooled_pages(), 0, "pages must deallocate, not pool");
    }

    #[test]
    fn trim_pooled_drops_idle_pages_to_the_cap() {
        let arena = KvArena::new(1, 2, 8);
        arena.reserve_pages(10);
        let page = arena.page_bytes();
        arena.trim_pooled_to(4 * page);
        assert_eq!(arena.pooled_pages(), 4);
        arena.trim_pooled_to(0);
        assert_eq!(arena.pooled_pages(), 0);
    }

    #[test]
    fn reserve_pages_prefills_the_slab() {
        let arena = KvArena::new(1, 2, 8);
        arena.reserve_pages(10);
        assert_eq!(arena.pooled_pages(), 10);
        // Reserving less than pooled is a no-op.
        arena.reserve_pages(4);
        assert_eq!(arena.pooled_pages(), 10);
    }

    #[test]
    fn foreign_state_release_adopts_the_arena_slab() {
        let mut arena = KvArena::new(1, 2, 8);
        let mut st = DecodeState::new(1, 2, 8);
        let row = vec![1.0f32; 16];
        st.append_kv(0, &row, &row);
        st.pos += 1;
        arena.release(st);
        assert_eq!(arena.pooled_pages(), 4, "foreign pages must land in the arena");
    }

    #[test]
    fn f16_kv_halves_stored_and_allocated_bytes() {
        let (h, hd) = (2usize, 8usize);
        let d = h * hd;
        let mut f32_st = DecodeState::new(1, h, hd);
        let mut f16_st = DecodeState::with_dtype(1, h, hd, KvDtype::F16);
        assert_eq!(f16_st.kv_dtype(), KvDtype::F16);
        let k = vec![0.5f32; d];
        let v = vec![-1.25f32; d];
        for _ in 0..KV_PAGE_POS + 2 {
            f32_st.append_kv(0, &k, &v);
            f16_st.append_kv(0, &k, &v);
            f32_st.pos += 1;
            f16_st.pos += 1;
        }
        assert_eq!(f16_st.kv_bytes() * 2, f32_st.kv_bytes());
        assert_eq!(f16_st.kv_allocated_bytes() * 2, f32_st.kv_allocated_bytes());
    }

    #[test]
    fn f16_kv_attention_is_ulp_close_to_f32() {
        // Same random K/V stream stored at both dtypes: outputs must agree
        // to within the f16 rounding budget. One narrowing step is ~2^-11
        // relative (~2^12 f32 ulps); the softmax mixes many such rounded
        // terms, so allow a small multiple.
        let (h, hd, n_layers) = (4usize, 8usize, 2usize);
        let d = h * hd;
        let n_pos = KV_PAGE_POS + 9;
        let mut rng = Rng::new(29);
        let mut f32_st = DecodeState::new(n_layers, h, hd);
        let mut f16_st = DecodeState::with_dtype(n_layers, h, hd, KvDtype::F16);
        for p in 0..n_pos {
            for l in 0..n_layers {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                f32_st.append_kv(l, &k, &v);
                f16_st.append_kv(l, &k, &v);
            }
            if p + 1 < n_pos {
                f32_st.pos += 1;
                f16_st.pos += 1;
            }
        }
        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..n_layers {
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let mut want = vec![0.0f32; d];
            attention_single(l, h, hd, scale, &q, &f32_st, &mut want);
            let mut got = vec![0.0f32; d];
            attention_single(l, h, hd, scale, &q, &f16_st, &mut got);
            crate::testing::assert_close_ulp(&got, &want, 1 << 15, 5e-3).unwrap();
            assert_ne!(got, want, "f16 storage should actually round something");
        }
    }

    #[test]
    fn f16_kv_attention_is_bit_identical_across_simd_levels_and_threads() {
        // Widening is exact, so the f16 read path must be just as
        // deterministic as f32: same bits at any SIMD level, thread count.
        let (h, hd) = (4usize, 8usize);
        let d = h * hd;
        let mut rng = Rng::new(31);
        let mut st = DecodeState::with_dtype(1, h, hd, KvDtype::F16);
        for p in 0..KV_PAGE_POS + 5 {
            let k: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            st.append_kv(0, &k, &v);
            if p + 1 < KV_PAGE_POS + 5 {
                st.pos += 1;
            }
        }
        let q = Mat::randn(1, d, 1.0, &mut rng);
        let scale = 1.0 / (hd as f32).sqrt();
        let run = |threads: usize| {
            let mut ctx = Mat::zeros(1, d);
            attention_batch_with(0, h, hd, scale, &q, &[&st][..], &mut ctx, threads);
            ctx
        };
        simd::force(Some(false));
        let scalar = run(1);
        simd::force(Some(true));
        let vector = run(1);
        let pooled = run(4);
        simd::force(None);
        assert_eq!(scalar.data, vector.data, "SIMD level must not change f16 reads");
        assert_eq!(scalar.data, pooled.data, "thread count must not change f16 reads");
    }

    /// Bit-exact snapshot of one page's storage (f32 bits widened to u32,
    /// f16 bits zero-extended) — the donor-never-mutated oracle.
    fn page_bits(page: &Page) -> Vec<u32> {
        match page {
            Page::F32(p) => p.iter().map(|v| v.to_bits()).collect(),
            Page::F16(p) => p.iter().map(|&v| v as u32).collect(),
        }
    }

    /// Snapshot every page of every list, in list order.
    fn state_bits(st: &DecodeState) -> Vec<Vec<u32>> {
        st.key_pages
            .iter()
            .chain(&st.val_pages)
            .flat_map(|l| l.iter().map(page_bits))
            .collect()
    }

    /// Deterministic per-position row (distinct across positions and the
    /// k/v halves) so forked copies are distinguishable bitwise.
    fn row(tag: f32, p: usize, d: usize) -> Vec<f32> {
        (0..d).map(|i| tag + p as f32 + i as f32 * 0.125).collect()
    }

    /// Append positions `[st.pos, until)` with the deterministic rows.
    fn extend_state(st: &mut DecodeState, n_layers: usize, d: usize, until: usize) {
        while st.pos < until {
            let p = st.pos;
            for l in 0..n_layers {
                st.append_kv(l, &row(1.0, p, d), &row(-2.0, p, d));
            }
            st.pos += 1;
        }
    }

    fn cow_fork_on_partial_page_case(dtype: KvDtype) {
        let (n_layers, h, hd) = (2usize, 2usize, 8usize);
        let d = h * hd;
        let mut donor = DecodeState::with_dtype(n_layers, h, hd, dtype);
        extend_state(&mut donor, n_layers, d, 10);
        let donor_before = state_bits(&donor);

        let mut lane = DecodeState::with_dtype(n_layers, h, hd, dtype);
        lane.share_prefix_from(&donor, 5);
        assert_eq!(lane.pos, 5);
        assert_eq!(lane.borrowed_prefix_pages(), 1);
        assert_eq!(lane.kv_owned_bytes(), 0, "a fully borrowed lane owns nothing");
        assert!(lane.kv_allocated_bytes() > 0);

        // First append lands mid-page: the shared tail must fork, and the
        // write must land in the lane's copy only.
        extend_state(&mut lane, n_layers, d, 9);
        assert_eq!(state_bits(&donor), donor_before, "donor pages were mutated");
        assert_eq!(lane.borrowed_prefix_pages(), 0, "forked tail is owned now");
        assert!(lane.kv_owned_bytes() > 0);

        // The lane must be indistinguishable from one built from scratch
        // with the same rows: attention over both is bit-identical.
        let mut scratch = DecodeState::with_dtype(n_layers, h, hd, dtype);
        extend_state(&mut scratch, n_layers, d, 9);
        lane.pos -= 1; // attention reads pos + 1 rows
        scratch.pos -= 1;
        let scale = 1.0 / (hd as f32).sqrt();
        let q = row(0.5, 3, d);
        for l in 0..n_layers {
            let mut got = vec![0.0f32; d];
            let mut want = vec![0.0f32; d];
            attention_single(l, h, hd, scale, &q, &lane, &mut got);
            attention_single(l, h, hd, scale, &q, &scratch, &mut want);
            assert_eq!(got, want, "layer {l}: forked lane diverged from scratch build");
        }
    }

    #[test]
    fn cow_fork_on_partial_page_never_mutates_donor() {
        cow_fork_on_partial_page_case(KvDtype::F32);
    }

    #[test]
    fn cow_fork_on_partial_page_never_mutates_donor_f16() {
        cow_fork_on_partial_page_case(KvDtype::F16);
    }

    #[test]
    fn cow_share_at_exact_page_edge_opens_fresh_page_without_forking() {
        let (n_layers, h, hd) = (1usize, 2usize, 8usize);
        let d = h * hd;
        let mut donor = DecodeState::new(n_layers, h, hd);
        extend_state(&mut donor, n_layers, d, KV_PAGE_POS);
        let donor_before = state_bits(&donor);

        let mut lane = DecodeState::new(n_layers, h, hd);
        lane.share_prefix_from(&donor, KV_PAGE_POS);
        assert_eq!(lane.borrowed_prefix_pages(), 1);
        extend_state(&mut lane, n_layers, d, KV_PAGE_POS + 3);
        // A page-aligned append opens a fresh page: the borrowed full page
        // stays borrowed (and shared) forever.
        assert_eq!(lane.borrowed_prefix_pages(), 1);
        assert_eq!(state_bits(&donor), donor_before, "donor pages were mutated");
        // Owned = one fresh K and V page per list; borrowed page excluded.
        let lists = n_layers * h;
        assert_eq!(lane.kv_owned_bytes(), 2 * lists * KV_PAGE_POS * hd * 4);
        assert_eq!(lane.kv_allocated_bytes(), 2 * 2 * lists * KV_PAGE_POS * hd * 4);
    }

    #[test]
    fn cow_two_lanes_fork_the_same_shared_page_independently() {
        let (n_layers, h, hd) = (1usize, 2usize, 8usize);
        let d = h * hd;
        let mut donor = DecodeState::new(n_layers, h, hd);
        extend_state(&mut donor, n_layers, d, 10);
        let donor_before = state_bits(&donor);

        let mut lane_a = DecodeState::new(n_layers, h, hd);
        let mut lane_b = DecodeState::new(n_layers, h, hd);
        lane_a.share_prefix_from(&donor, 6);
        lane_b.share_prefix_from(&donor, 6);
        // Divergent continuations off the same shared page.
        while lane_a.pos < 8 {
            let p = lane_a.pos;
            lane_a.append_kv(0, &row(10.0, p, d), &row(-10.0, p, d));
            lane_a.pos += 1;
        }
        while lane_b.pos < 8 {
            let p = lane_b.pos;
            lane_b.append_kv(0, &row(20.0, p, d), &row(-20.0, p, d));
            lane_b.pos += 1;
        }
        assert_eq!(state_bits(&donor), donor_before, "donor pages were mutated");
        let bits_a = state_bits(&lane_a);
        let bits_b = state_bits(&lane_b);
        assert_ne!(bits_a, bits_b, "each lane must own its fork");
        // Both forks kept the shared first 6 rows bitwise.
        for (list, donor_list) in
            lane_a.key_pages.iter().chain(&lane_a.val_pages).zip(
                donor.key_pages.iter().chain(&donor.val_pages),
            )
        {
            let got = page_bits(&list[0]);
            let want = page_bits(&donor_list[0]);
            assert_eq!(&got[..6 * hd], &want[..6 * hd], "shared prefix rows must survive");
        }
    }

    #[test]
    fn shared_pages_are_never_pooled_by_reset() {
        let (n_layers, h, hd) = (1usize, 2usize, 8usize);
        let d = h * hd;
        let mut arena = KvArena::new(n_layers, h, hd);
        let mut donor = arena.acquire();
        extend_state(&mut donor, n_layers, d, KV_PAGE_POS + 2);
        let mut lane = arena.acquire();
        lane.share_prefix_from(&donor, KV_PAGE_POS);
        // Donor holds 2 pages per list (K and V); the first page of each
        // list is shared with `lane`, so release must pool only the
        // unique second pages.
        let lists = n_layers * h;
        arena.release(donor);
        assert_eq!(arena.pooled_pages(), 2 * lists, "only unique pages may pool");
        // Once the lane lets go too, the pages are unique again and pool.
        arena.release(lane);
        assert_eq!(arena.pooled_pages(), 2 * lists + 2 * lists);
    }

    #[test]
    fn reserve_pages_capped_respects_the_byte_budget() {
        let arena = KvArena::new(1, 2, 8);
        let page = arena.page_bytes();
        arena.reserve_pages_capped(100, 5 * page);
        assert_eq!(arena.pooled_pages(), 5, "pre-warm must clamp to the budget");
        // No budget: the full reservation goes through.
        arena.reserve_pages_capped(8, 0);
        assert_eq!(arena.pooled_pages(), 8);
    }

    #[test]
    fn f16_arena_pools_and_reports_dtype() {
        let mut arena = KvArena::with_dtype(1, 2, 8, KvDtype::F16);
        assert_eq!(arena.kv_dtype(), KvDtype::F16);
        let mut st = arena.acquire();
        assert_eq!(st.kv_dtype(), KvDtype::F16);
        let row = vec![1.0f32; 16];
        st.append_kv(0, &row, &row);
        st.pos += 1;
        arena.release(st);
        assert_eq!(arena.pooled_pages(), 4);
        assert_eq!(arena.pooled_page_bytes(), 4 * KV_PAGE_POS * 8 * 2);
        // A foreign f32 state is dropped, not adopted: its pages cannot
        // back f16 lanes.
        let mut foreign = DecodeState::new(1, 2, 8);
        foreign.append_kv(0, &row, &row);
        foreign.pos += 1;
        arena.release(foreign);
        assert_eq!(arena.pooled(), 1, "wrong-dtype shell must not pool");
        assert_eq!(arena.pooled_pages(), 4, "wrong-dtype pages must not pool");
    }
}
