//! MiniLlama model substrate on the Rust side: the named parameter store
//! (interchange with the HLO artifacts) and a native f32 reference forward
//! (full-sequence and incremental-decode with KV cache). The native forward
//! cross-validates the artifact path and powers the serving engine.

pub mod forward;
pub mod params;

pub use forward::{BatchScratch, DecodeState, KvArena, NativeModel};
pub use params::ParamStore;
