//! MiniLlama model substrate on the Rust side: the named parameter store
//! (interchange with the HLO artifacts), a native f32 reference forward
//! (full-sequence and incremental-decode), and the decode-time attention
//! subsystem — a head-major paged KV cache ([`attention::KV_PAGE_POS`]
//! pages recycled through [`KvArena`]) with lane×head-parallel kernels.
//! The native forward cross-validates the artifact path and powers the
//! serving engine.

pub mod attention;
pub mod forward;
pub mod params;

pub use attention::{DecodeState, KvArena, KvLane, KvLaneMut, KV_PAGE_POS};
pub use forward::{BatchScratch, NativeModel};
pub use params::ParamStore;
