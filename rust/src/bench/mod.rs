//! Micro-bench harness (criterion is unavailable offline): warmup + timed
//! repetitions with mean/stddev reporting, used by `rust/benches/*.rs`
//! (`harness = false` targets run by `cargo bench`).

use crate::util::{mean, stddev};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub reps: usize,
}

impl BenchResult {
    pub fn per_sec(&self, units: f64) -> f64 {
        units / self.mean_secs.max(1e-12)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms ± {:>7.3} ms  (n={})",
            self.name,
            self.mean_secs * 1e3,
            self.std_secs * 1e3,
            self.reps
        )
    }
}

/// Time `f` with `warmup` + `reps` runs; prints and returns the result.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    let res = BenchResult {
        name: name.to_string(),
        mean_secs: mean(&times),
        std_secs: stddev(&times),
        reps: times.len(),
    };
    println!("{res}");
    res
}

/// Scale down bench workloads under `GQ_BENCH_FAST=1` (CI smoke runs).
pub fn fast_mode() -> bool {
    std::env::var("GQ_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let r = bench("noop-ish", 1, 3, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean_secs >= 0.0);
        assert_eq!(r.reps, 3);
        assert!(r.per_sec(1000.0) > 0.0);
    }
}
