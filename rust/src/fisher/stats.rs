//! Accumulation of calibration statistics across batches (Algorithm 1,
//! lines 2–4, with the heavy lifting inside the calib_stats artifact whose
//! Hessian reduction is the L1 Pallas xtsx kernel).

use anyhow::{bail, Result};

use crate::data::Batcher;
use crate::model::ParamStore;
use crate::runtime::{Runtime, Value};
use crate::tensor::Mat;

/// Per-linear accumulated statistics.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    /// hs[0] = plain H = Σ X^T X; hs[1..=g] = GuidedQuant H̄_k sums.
    pub hs: Vec<Mat>,
    /// SqueezeLLM diagonal Fisher sum (d_in × d_out).
    pub diagf: Mat,
}

impl LayerStats {
    /// Hessians for a guided run with `g` groups (g ≤ available groups):
    /// re-averages the stored group Hessians into `g` groups.
    pub fn guided_hessians(&self, g: usize) -> Vec<Mat> {
        let have = self.hs.len() - 1;
        assert!(g >= 1 && g <= have, "requested g={g}, cached g={have}");
        if g == have {
            return self.hs[1..].to_vec();
        }
        // Merge consecutive cached groups (equal-sized channel ranges merge
        // exactly because saliencies are averaged over equal channel sets).
        let per = have / g;
        let mut out = Vec::with_capacity(g);
        for k in 0..g {
            let mut acc = self.hs[1 + k * per].clone();
            for t in 1..per {
                acc.axpy(&self.hs[1 + k * per + t], 1.0);
            }
            acc.scale(1.0 / per as f32);
            out.push(acc);
        }
        out
    }

    /// The plain layer-wise Hessian H = X^T X (objective of Eq. 1).
    pub fn plain_hessian(&self) -> &Mat {
        &self.hs[0]
    }

    pub fn storage_bytes(&self) -> usize {
        self.hs.iter().map(|h| h.data.len() * 4).sum::<usize>() + self.diagf.data.len() * 4
    }
}

/// Full calibration statistics for a model.
#[derive(Debug, Clone)]
pub struct CalibStats {
    pub groups: usize,
    pub batches: usize,
    pub tokens: usize,
    pub loss_sum: f64,
    pub layers: Vec<LayerStats>,
}

impl CalibStats {
    pub fn layer(&self, name: &str) -> Option<&LayerStats> {
        self.layers.iter().find(|l| l.name == name)
    }

    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.storage_bytes()).sum()
    }

    /// Mean calibration loss per token (sanity signal for the pipeline).
    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.tokens.max(1) as f64
    }
}

/// Run the calib_stats artifact over `n_batches` and accumulate.
pub fn collect_stats(
    rt: &Runtime,
    ps: &ParamStore,
    batcher: &mut Batcher,
    n_batches: usize,
) -> Result<CalibStats> {
    let artifact = rt.artifact("calib_stats")?;
    let bc = rt.manifest.batch;
    let groups = rt.manifest.groups;
    let lspecs = ps.cfg.linear_specs();
    let n_lin = lspecs.len();
    let param_args = rt.param_args(ps);

    let mut layers: Vec<LayerStats> = lspecs
        .iter()
        .map(|s| LayerStats {
            name: s.name.clone(),
            hs: (0..=groups).map(|_| Mat::zeros(s.d_in, s.d_in)).collect(),
            diagf: Mat::zeros(s.d_in, s.d_out),
        })
        .collect();
    let mut loss_sum = 0.0f64;
    let mut batches = 0usize;

    for _ in 0..n_batches {
        let Some(toks) = batcher.next_batch() else {
            break;
        };
        let mut args = param_args.clone();
        args.push(Value::tokens(bc.batch, bc.seq, &toks));
        let outs = artifact.execute(&args)?;
        if outs.len() != 1 + 2 * n_lin {
            bail!("calib_stats returned {} outputs, expected {}", outs.len(), 1 + 2 * n_lin);
        }
        loss_sum += outs[0].scalar_f32()? as f64;
        for (li, spec) in lspecs.iter().enumerate() {
            // hs value: (groups+1, d_in, d_in)
            let hs_val = &outs[1 + 2 * li];
            let dims = hs_val.dims().to_vec();
            if dims != [groups + 1, spec.d_in, spec.d_in] {
                bail!("{}: hs dims {dims:?}", spec.name);
            }
            let data = hs_val.as_f32()?;
            let block = spec.d_in * spec.d_in;
            for k in 0..=groups {
                let dst = &mut layers[li].hs[k];
                for (d, &s) in dst.data.iter_mut().zip(&data[k * block..(k + 1) * block]) {
                    *d += s;
                }
            }
            let df = &outs[2 + 2 * li];
            let df_data = df.as_f32()?;
            for (d, &s) in layers[li].diagf.data.iter_mut().zip(df_data) {
                *d += s;
            }
        }
        batches += 1;
    }
    anyhow::ensure!(batches > 0, "no calibration batches were available");
    Ok(CalibStats {
        groups,
        batches,
        tokens: batches * bc.tokens(),
        loss_sum,
        layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats(g: usize, d: usize) -> LayerStats {
        LayerStats {
            name: "l".into(),
            hs: (0..=g).map(|k| Mat::from_fn(d, d, |i, j| (k * 100 + i * d + j) as f32)).collect(),
            diagf: Mat::zeros(d, d),
        }
    }

    #[test]
    fn guided_hessians_full_group_passthrough() {
        let ls = fake_stats(4, 3);
        let hs = ls.guided_hessians(4);
        assert_eq!(hs.len(), 4);
        assert_eq!(hs[0], ls.hs[1]);
        assert_eq!(hs[3], ls.hs[4]);
    }

    #[test]
    fn guided_hessians_merge_averages() {
        let ls = fake_stats(4, 2);
        let hs = ls.guided_hessians(2);
        assert_eq!(hs.len(), 2);
        // Group 0 = mean of cached groups 1, 2.
        for i in 0..2 {
            for j in 0..2 {
                let want = 0.5 * (ls.hs[1].at(i, j) + ls.hs[2].at(i, j));
                assert!((hs[0].at(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic(expected = "requested g=8")]
    fn guided_hessians_rejects_upscaling() {
        fake_stats(4, 2).guided_hessians(8);
    }

    #[test]
    fn storage_accounting() {
        let ls = fake_stats(2, 4);
        // 3 Hessians of 16 floats + diagf 16 floats = 64 floats = 256 B.
        assert_eq!(ls.storage_bytes(), 256);
    }
}
