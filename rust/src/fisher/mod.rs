//! Calibration statistics: drives the `calib_stats` artifact over batches,
//! accumulates GuidedQuant's grouped Hessians H̄_k + SqueezeLLM diagonal
//! Fisher, persists them in the Hessian disk cache, and implements the
//! Fisher-structure analysis behind Figures 3/4.

pub mod cache;
pub mod stats;
pub mod structure;

pub use cache::HessianCache;
pub use stats::{collect_stats, CalibStats, LayerStats};
