//! Hessian disk cache (paper Table 9's "Hessian caching" phase).
//!
//! Calibration statistics are expensive to produce (forward+backward over
//! the calibration set) but reusable across bit-widths and configurations —
//! the paper amortizes them the same way. Stored via the GQTB tensor
//! container, one file per model, with an index entry per (layer, matrix).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::io::TensorFile;
use crate::tensor::Mat;

use super::stats::{CalibStats, LayerStats};

pub struct HessianCache {
    pub dir: PathBuf,
}

impl HessianCache {
    pub fn new(dir: impl AsRef<Path>) -> Self {
        HessianCache { dir: dir.as_ref().to_path_buf() }
    }

    fn path(&self, model: &str) -> PathBuf {
        self.dir.join(format!("hessians_{model}.gqtb"))
    }

    pub fn exists(&self, model: &str) -> bool {
        self.path(model).exists()
    }

    pub fn save(&self, model: &str, stats: &CalibStats) -> Result<u64> {
        let mut tf = TensorFile::new();
        tf.insert(
            "__meta",
            Mat::from_vec(
                1,
                4,
                vec![
                    stats.groups as f32,
                    stats.batches as f32,
                    stats.tokens as f32,
                    stats.loss_sum as f32,
                ],
            ),
        );
        for layer in &stats.layers {
            for (k, h) in layer.hs.iter().enumerate() {
                tf.insert(format!("hs.{}.{k}", layer.name), h.clone());
            }
            tf.insert(format!("diagf.{}", layer.name), layer.diagf.clone());
        }
        let path = self.path(model);
        tf.save(&path)?;
        Ok(std::fs::metadata(&path)?.len())
    }

    pub fn load(&self, model: &str) -> Result<CalibStats> {
        let path = self.path(model);
        let tf = TensorFile::load(&path).with_context(|| format!("hessian cache {path:?}"))?;
        let meta = tf.get("__meta").context("cache missing __meta")?;
        let groups = meta.data[0] as usize;
        let batches = meta.data[1] as usize;
        let tokens = meta.data[2] as usize;
        let loss_sum = meta.data[3] as f64;
        // Reconstruct layers from the key space.
        let mut names: Vec<String> = tf
            .entries
            .keys()
            .filter_map(|k| k.strip_prefix("diagf.").map(|s| s.to_string()))
            .collect();
        if names.is_empty() {
            bail!("cache {path:?} holds no layers");
        }
        // Preserve layer order (layers.N.kind sorts badly at N >= 10).
        names.sort_by_key(|n| {
            let layer: usize = n
                .strip_prefix("layers.")
                .and_then(|r| r.split('.').next())
                .and_then(|v| v.parse().ok())
                .unwrap_or(usize::MAX);
            let kind = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"]
                .iter()
                .position(|k| n.ends_with(k))
                .unwrap_or(99);
            layer * 16 + kind
        });
        let mut layers = Vec::new();
        for name in names {
            let mut hs = Vec::new();
            for k in 0..=groups {
                let h = tf
                    .get(&format!("hs.{name}.{k}"))
                    .with_context(|| format!("cache missing hs.{name}.{k}"))?;
                hs.push(h.clone());
            }
            let diagf = tf.get(&format!("diagf.{name}")).unwrap().clone();
            layers.push(LayerStats { name, hs, diagf });
        }
        Ok(CalibStats { groups, batches, tokens, loss_sum, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_stats() -> CalibStats {
        let mut rng = Rng::new(0);
        let layers = (0..3)
            .map(|l| LayerStats {
                name: format!("layers.{l}.wq"),
                hs: (0..3).map(|_| Mat::randn(4, 4, 1.0, &mut rng)).collect(),
                diagf: Mat::randn(4, 6, 1.0, &mut rng),
            })
            .collect();
        CalibStats { groups: 2, batches: 5, tokens: 640, loss_sum: 123.5, layers }
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("gq_hcache_{}", std::process::id()));
        let cache = HessianCache::new(&dir);
        let stats = sample_stats();
        let bytes = cache.save("testmodel", &stats).unwrap();
        assert!(bytes > 0);
        assert!(cache.exists("testmodel"));
        let back = cache.load("testmodel").unwrap();
        assert_eq!(back.groups, 2);
        assert_eq!(back.batches, 5);
        assert_eq!(back.layers.len(), 3);
        assert_eq!(back.layers[0].name, "layers.0.wq");
        assert_eq!(back.layers[1].hs[1], stats.layers[1].hs[1]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_cache_is_error() {
        let cache = HessianCache::new("/nonexistent_dir_gq");
        assert!(!cache.exists("m"));
        assert!(cache.load("m").is_err());
    }
}
