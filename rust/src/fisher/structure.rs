//! Fisher-structure analysis (paper Figures 3/4 and Appendix D.11).
//!
//! From raw per-linear activations X and output gradients G (the grad_taps
//! artifact), we build the *exact* per-channel Fisher blocks
//!   F_j = X^T·Diag(g_j²)·X
//! and compare two equal-storage approximations of the full (within-two-
//! channels) Fisher submatrix:
//!   * WoodFisher-style: keep B×B blocks along the diagonal, zero the rest,
//!   * GuidedQuant: replace each channel's block with the group-average.
//! The bench prints relative Frobenius errors — the quantitative version of
//! the figures' visual comparison.

use crate::tensor::Mat;

/// Exact channel Fisher block F_j = X^T Diag(g[:, j]^2) X.
pub fn channel_fisher(x: &Mat, g: &Mat, j: usize) -> Mat {
    assert_eq!(x.rows, g.rows);
    let d = x.cols;
    let mut out = Mat::zeros(d, d);
    for i in 0..x.rows {
        let w = g.at(i, j) * g.at(i, j);
        if w == 0.0 {
            continue;
        }
        let row = x.row(i);
        for a in 0..d {
            let wa = w * row[a];
            if wa == 0.0 {
                continue;
            }
            let dst = &mut out.data[a * d..(a + 1) * d];
            for (o, &xb) in dst.iter_mut().zip(row) {
                *o += wa * xb;
            }
        }
    }
    out
}

/// The 2-channel Fisher submatrix [[F_1, C], [C^T, F_2]] where
/// C = X^T Diag(g_1 g_2) X (the cross-channel interaction the figures show
/// is weak relative to the within-channel blocks).
pub fn two_channel_fisher(x: &Mat, g: &Mat, j1: usize, j2: usize) -> Mat {
    let d = x.cols;
    let f1 = channel_fisher(x, g, j1);
    let f2 = channel_fisher(x, g, j2);
    let mut cross = Mat::zeros(d, d);
    for i in 0..x.rows {
        let w = g.at(i, j1) * g.at(i, j2);
        if w == 0.0 {
            continue;
        }
        let row = x.row(i);
        for a in 0..d {
            let wa = w * row[a];
            let dst = &mut cross.data[a * d..(a + 1) * d];
            for (o, &xb) in dst.iter_mut().zip(row) {
                *o += wa * xb;
            }
        }
    }
    let n = 2 * d;
    let mut out = Mat::zeros(n, n);
    for i in 0..d {
        for j in 0..d {
            *out.at_mut(i, j) = f1.at(i, j);
            *out.at_mut(d + i, d + j) = f2.at(i, j);
            *out.at_mut(i, d + j) = cross.at(i, j);
            *out.at_mut(d + i, j) = cross.at(j, i);
        }
    }
    out
}

/// WoodFisher-style approximation: zero everything outside B×B diagonal
/// blocks.
pub fn block_diag_approx(f: &Mat, b: usize) -> Mat {
    assert_eq!(f.rows, f.cols);
    let mut out = Mat::zeros(f.rows, f.cols);
    let b = b.max(1);
    for i in 0..f.rows {
        let blk = i / b;
        for j in (blk * b)..((blk + 1) * b).min(f.cols) {
            *out.at_mut(i, j) = f.at(i, j);
        }
    }
    out
}

/// GuidedQuant approximation of the 2-channel Fisher: both channels share
/// the averaged block (they belong to the same group), cross terms dropped.
pub fn guided_approx_two_channel(f: &Mat) -> Mat {
    let d = f.rows / 2;
    let mut avg = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            *avg.at_mut(i, j) = 0.5 * (f.at(i, j) + f.at(d + i, d + j));
        }
    }
    let mut out = Mat::zeros(f.rows, f.cols);
    for i in 0..d {
        for j in 0..d {
            *out.at_mut(i, j) = avg.at(i, j);
            *out.at_mut(d + i, d + j) = avg.at(i, j);
        }
    }
    out
}

/// Relative Frobenius approximation error ‖F − F̂‖ / ‖F‖.
pub fn rel_error(f: &Mat, approx: &Mat) -> f64 {
    let num = f.sub(approx).frob_norm_sq().sqrt();
    let den = f.frob_norm_sq().sqrt().max(1e-30);
    num / den
}

/// Fraction of the Fisher mass carried by the within-channel diagonal
/// blocks (the figures' "prominent block-diagonal structure").
pub fn block_mass_fraction(f: &Mat, d: usize) -> f64 {
    let mut inside = 0.0f64;
    let total = f.frob_norm_sq();
    for bi in 0..(f.rows / d) {
        for i in 0..d {
            for j in 0..d {
                let v = f.at(bi * d + i, bi * d + j) as f64;
                inside += v * v;
            }
        }
    }
    inside / total.max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn xg(rng: &mut Rng, n: usize, d: usize, c: usize) -> (Mat, Mat) {
        (Mat::randn(n, d, 1.0, rng), Mat::randn(n, c, 0.5, rng))
    }

    #[test]
    fn channel_fisher_matches_outer_product_sum() {
        let mut rng = Rng::new(0);
        let (x, g) = xg(&mut rng, 12, 4, 2);
        let f = channel_fisher(&x, &g, 1);
        // Manual: Σ_i (g_i1 x_i)(g_i1 x_i)^T
        let mut want = Mat::zeros(4, 4);
        for i in 0..12 {
            for a in 0..4 {
                for b in 0..4 {
                    *want.at_mut(a, b) +=
                        g.at(i, 1) * x.at(i, a) * g.at(i, 1) * x.at(i, b);
                }
            }
        }
        crate::testing::assert_close(&f.data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn two_channel_fisher_is_symmetric_psd_structured() {
        let mut rng = Rng::new(1);
        let (x, g) = xg(&mut rng, 24, 6, 3);
        let f = two_channel_fisher(&x, &g, 0, 2);
        assert_eq!(f.rows, 12);
        for i in 0..12 {
            for j in 0..12 {
                assert!((f.at(i, j) - f.at(j, i)).abs() < 1e-3, "asym at ({i},{j})");
            }
        }
        // Diagonal blocks match channel_fisher.
        let f0 = channel_fisher(&x, &g, 0);
        assert!((f.at(0, 0) - f0.at(0, 0)).abs() < 1e-4);
    }

    #[test]
    fn block_diag_keeps_only_blocks() {
        let f = Mat::from_fn(4, 4, |i, j| (i * 4 + j + 1) as f32);
        let a = block_diag_approx(&f, 2);
        assert_eq!(a.at(0, 1), f.at(0, 1));
        assert_eq!(a.at(0, 2), 0.0);
        assert_eq!(a.at(2, 3), f.at(2, 3));
        assert_eq!(a.at(3, 0), 0.0);
    }

    #[test]
    fn guided_beats_small_block_woodfisher_on_blocky_fisher() {
        // When the true Fisher is strongly within-channel-block structured
        // (as the paper's figures show), the guided approximation at equal
        // storage beats a tiny-B WoodFisher cut.
        let mut rng = Rng::new(2);
        let (x, g) = xg(&mut rng, 64, 8, 2);
        let f = two_channel_fisher(&x, &g, 0, 1);
        let guided = guided_approx_two_channel(&f);
        // Equal storage: guided stores d*d floats (one shared block);
        // WoodFisher with B = d/2 stores 4 * (d/2)^2 = d^2 as well.
        let wf = block_diag_approx(&f, 4);
        let eg = rel_error(&f, &guided);
        let ew = rel_error(&f, &wf);
        assert!(eg < ew, "guided {eg} !< woodfisher {ew}");
    }

    #[test]
    fn block_mass_dominates_for_uncorrelated_grads() {
        let mut rng = Rng::new(3);
        let (x, g) = xg(&mut rng, 128, 6, 2);
        let f = two_channel_fisher(&x, &g, 0, 1);
        let frac = block_mass_fraction(&f, 6);
        assert!(frac > 0.5, "block mass {frac}");
    }
}
