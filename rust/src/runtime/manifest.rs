//! Parser for `artifacts/<model>/manifest.txt` (written by aot.py) and the
//! cross-check against the Rust presets — any drift between the Python and
//! Rust model definitions fails here, before any HLO is executed.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cfg::{preset, BatchConfig, ModelConfig};

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    /// (name, dtype, dims) — dims may contain the "..." placeholder for the
    /// flattened parameter list.
    pub inputs: Vec<(String, String, Vec<String>)>,
    pub outputs: Vec<(String, String, Vec<String>)>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelConfig,
    pub batch: BatchConfig,
    pub groups: usize,
    pub grad_scale: f64,
    pub lr: f64,
    pub params: Vec<(String, Vec<usize>)>,
    pub linears: Vec<(String, usize, usize)>,
    pub artifacts: Vec<ArtifactSig>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut model_name = None;
        let mut fields = std::collections::BTreeMap::new();
        let mut params = Vec::new();
        let mut linears = Vec::new();
        let mut artifacts: Vec<ArtifactSig> = Vec::new();
        for (no, raw) in text.lines().enumerate() {
            let line = raw.trim_end();
            if line.is_empty() {
                continue;
            }
            let indented = line.starts_with("  ");
            let parts: Vec<&str> = line.split_whitespace().collect();
            match (indented, parts[0]) {
                (false, "model") => model_name = Some(parts[1].to_string()),
                (false, k @ ("vocab" | "d_model" | "n_layers" | "n_heads" | "d_ff" | "batch" | "seq" | "groups")) => {
                    fields.insert(k.to_string(), parts[1].parse::<usize>().context(k.to_string())?);
                }
                (false, "grad_scale") | (false, "lr") => {
                    fields.insert(parts[0].to_string(), 0);
                    // stored separately below
                }
                (false, "param") => {
                    let dims = parts[2..].iter().map(|p| p.parse().unwrap()).collect();
                    params.push((parts[1].to_string(), dims));
                }
                (false, "linear") => {
                    linears.push((parts[1].to_string(), parts[2].parse()?, parts[3].parse()?));
                }
                (false, "artifact") => {
                    artifacts.push(ArtifactSig { name: parts[1].to_string(), inputs: vec![], outputs: vec![] });
                }
                (true, "in") | (true, "out") => {
                    let Some(a) = artifacts.last_mut() else {
                        bail!("line {}: io outside artifact", no + 1);
                    };
                    let entry = (
                        parts[1].to_string(),
                        parts[2].to_string(),
                        parts[3..].iter().map(|s| s.to_string()).collect(),
                    );
                    if parts[0] == "in" {
                        a.inputs.push(entry);
                    } else {
                        a.outputs.push(entry);
                    }
                }
                _ => bail!("line {}: cannot parse `{line}`", no + 1),
            }
        }
        let name = model_name.context("manifest missing model name")?;
        let grad_scale: f64 = text
            .lines()
            .find(|l| l.starts_with("grad_scale"))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0e3);
        let lr: f64 = text
            .lines()
            .find(|l| l.starts_with("lr "))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(1e-3);
        let get = |k: &str| -> Result<usize> {
            fields.get(k).copied().with_context(|| format!("manifest missing `{k}`"))
        };
        let model = ModelConfig {
            name: name.clone(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            rope_theta: 10000.0,
        };
        let batch = BatchConfig { batch: get("batch")?, seq: get("seq")? };
        let m = Manifest {
            model,
            batch,
            groups: get("groups")?,
            grad_scale,
            lr,
            params,
            linears,
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    /// Cross-check against the Rust preset of the same name.
    fn validate(&self) -> Result<()> {
        let (cfg, _) = preset(&self.model.name);
        if cfg != self.model {
            bail!(
                "manifest model config differs from Rust preset `{}`:\n  manifest: {:?}\n  preset:   {:?}",
                self.model.name,
                self.model,
                cfg
            );
        }
        let specs = cfg.param_specs();
        if specs.len() != self.params.len() {
            bail!("param count mismatch: manifest {} vs preset {}", self.params.len(), specs.len());
        }
        for (spec, (name, dims)) in specs.iter().zip(&self.params) {
            let want: Vec<usize> = if spec.cols == 1 && !spec.name.contains('w') {
                vec![spec.rows]
            } else {
                vec![spec.rows, spec.cols]
            };
            if &spec.name != name || dims != &want {
                bail!("param mismatch: manifest {name} {dims:?} vs preset {} {want:?}", spec.name);
            }
        }
        let lspecs = cfg.linear_specs();
        if lspecs.len() != self.linears.len() {
            bail!("linear count mismatch");
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSig> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        p.join("manifest.txt").exists().then_some(p)
    }

    #[test]
    fn parses_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.name, "tiny");
        assert_eq!(m.model.d_model, 128);
        assert_eq!(m.params.len(), 21);
        assert_eq!(m.linears.len(), 14);
        assert!(m.artifact("fwd_loss").is_some());
        assert!(m.artifact("calib_stats").is_some());
        assert!((m.grad_scale - 1000.0).abs() < 1e-9);
        let cs = m.artifact("calib_stats").unwrap();
        assert_eq!(cs.outputs.len(), 1 + 2 * 14);
    }

    #[test]
    fn rejects_mismatched_config() {
        let text = "model tiny\nvocab 999\nd_model 128\nn_layers 2\nn_heads 4\nd_ff 256\nbatch 2\nseq 64\ngroups 4\n";
        assert!(Manifest::parse(text).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("nonsense line here\n").is_err());
        assert!(Manifest::parse("").is_err());
    }
}
