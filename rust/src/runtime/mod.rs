//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! and executes them on the CPU PJRT client (`xla` crate). This is the only
//! bridge between the Rust coordinator and the L2/L1 graphs — Python never
//! runs at serving/quantization time.

pub mod artifact;
pub mod manifest;

pub use artifact::{Artifact, Runtime, Value};
pub use manifest::Manifest;
