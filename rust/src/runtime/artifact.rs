//! Artifact registry: compile-once, execute-many wrappers over the `xla`
//! crate's PJRT CPU client.
//!
//! HLO *text* is the interchange format — xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids (see /opt/xla-example/README.md). All artifacts are
//! lowered with `return_tuple=True`, so outputs arrive as one tuple literal.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Mat;

use super::manifest::Manifest;

/// An argument/result value crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum Value {
    /// f32 tensor with explicit dims (row-major).
    F32(Vec<f32>, Vec<usize>),
    /// i32 tensor with explicit dims (tokens).
    I32(Vec<i32>, Vec<usize>),
    /// f32 scalar.
    Scalar(f32),
}

impl Value {
    pub fn from_mat(m: &Mat) -> Value {
        Value::F32(m.data.clone(), vec![m.rows, m.cols])
    }

    /// Norm-style vectors are rank-1 in the artifacts.
    pub fn from_mat_vec(m: &Mat) -> Value {
        if m.cols == 1 {
            Value::F32(m.data.clone(), vec![m.rows])
        } else {
            Self::from_mat(m)
        }
    }

    pub fn tokens(batch: usize, seq: usize, toks: &[i32]) -> Value {
        assert_eq!(toks.len(), batch * seq);
        Value::I32(toks.to_vec(), vec![batch, seq])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Value::F32(data, dims) => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Value::I32(data, dims) => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Value::Scalar(v) => xla::Literal::scalar(*v),
        })
    }

    /// Interpret a result literal as f32 data + dims.
    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Value::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(Value::I32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported result element type {other:?}"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            Value::Scalar(v) => Ok(*v),
            Value::F32(d, dims) if d.len() == 1 => {
                let _ = dims;
                Ok(d[0])
            }
            _ => bail!("expected scalar, got {self:?}"),
        }
    }

    /// View as a matrix with the last dim as cols and everything else rows.
    pub fn into_mat(self) -> Result<Mat> {
        match self {
            Value::F32(d, dims) => {
                let cols = *dims.last().unwrap_or(&1);
                let rows = d.len() / cols.max(1);
                Ok(Mat::from_vec(rows, cols, d))
            }
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32(_, d) | Value::I32(_, d) => d,
            Value::Scalar(_) => &[],
        }
    }
}

/// One compiled executable.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    pub fn execute(&self, args: &[Value]) -> Result<Vec<Value>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("building literals for {}", self.name))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let tuple = out.to_tuple().context("result tuple")?;
        tuple.iter().map(Value::from_literal).collect()
    }
}

/// The registry: PJRT client + lazily compiled artifacts for one model.
pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
    client: xla::PjRtClient,
    cache: std::sync::Mutex<HashMap<String, std::sync::Arc<Artifact>>>,
}

impl Runtime {
    /// `dir` is artifacts/<model>.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::log_info!(
            "runtime",
            "PJRT client up: platform={} devices={} model={}",
            client.platform_name(),
            client.device_count(),
            manifest.model.name
        );
        Ok(Runtime { manifest, dir, client, cache: std::sync::Mutex::new(HashMap::new()) })
    }

    /// Convenience: artifacts/<model> under a base dir.
    pub fn load_model(base: impl AsRef<Path>, model: &str) -> Result<Runtime> {
        Self::load(base.as_ref().join(model))
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<std::sync::Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(path.exists(), "artifact {path:?} missing (run `make artifacts`)");
        let t = crate::util::Timer::new();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        crate::log_info!("runtime", "compiled {name} in {}", crate::util::human_duration(t.elapsed()));
        let artifact = std::sync::Arc::new(Artifact { name: name.to_string(), exe });
        self.cache.lock().unwrap().insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Parameter-store values in artifact argument order.
    pub fn param_args(&self, ps: &crate::model::ParamStore) -> Vec<Value> {
        ps.cfg
            .param_specs()
            .iter()
            .map(|spec| {
                let m = ps.get(&spec.name);
                if spec.name.ends_with("norm") {
                    Value::from_mat_vec(m)
                } else {
                    Value::from_mat(m)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_literal_round_trip() {
        let v = Value::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit).unwrap();
        assert_eq!(back.dims(), &[2, 3]);
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn i32_values() {
        let v = Value::tokens(2, 2, &[1, 2, 3, 4]);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit).unwrap();
        match back {
            Value::I32(d, dims) => {
                assert_eq!(d, vec![1, 2, 3, 4]);
                assert_eq!(dims, vec![2, 2]);
            }
            other => panic!("wrong value {other:?}"),
        }
    }

    #[test]
    fn into_mat_flattens_leading_dims() {
        let v = Value::F32(vec![0.0; 24], vec![2, 3, 4]);
        let m = v.into_mat().unwrap();
        assert_eq!((m.rows, m.cols), (6, 4));
    }

    #[test]
    fn scalar_helpers() {
        assert_eq!(Value::Scalar(2.5).scalar_f32().unwrap(), 2.5);
        assert_eq!(Value::F32(vec![7.0], vec![]).scalar_f32().unwrap(), 7.0);
        assert!(Value::F32(vec![1.0, 2.0], vec![2]).scalar_f32().is_err());
    }
}
