//! Phase metrics: wall-time and byte counters surfaced in the pipeline
//! report (the Table 8/9 cost-accounting analogs).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<BTreeMap<String, f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, key: &str, value: f64) {
        *self.inner.lock().unwrap().entry(key.to_string()).or_default() += value;
    }

    pub fn set(&self, key: &str, value: f64) {
        self.inner.lock().unwrap().insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> f64 {
        self.inner.lock().unwrap().get(key).copied().unwrap_or(0.0)
    }

    /// Time a closure and accumulate under `key` (seconds).
    pub fn time<T>(&self, key: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(key, t.elapsed().as_secs_f64());
        out
    }

    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        self.inner.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("x", 1.5);
        m.add("x", 0.5);
        m.set("y", 7.0);
        assert_eq!(m.get("x"), 2.0);
        assert_eq!(m.get("y"), 7.0);
        assert_eq!(m.get("missing"), 0.0);
    }

    #[test]
    fn time_records_positive() {
        let m = Metrics::new();
        let v = m.time("t", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(m.get("t") >= 0.004);
        assert!(m.snapshot().contains_key("t"));
    }
}
