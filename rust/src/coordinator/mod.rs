//! L3 coordinator: the quantization pipeline orchestrator.
//!
//! Phases (each driven from Rust, Python never on the path):
//!   train → calib-stats (Hessian cache) → quantize (parallel
//!   (layer, group) jobs) → eval → serve.
//!
//! The worker pool is a persistent std::thread pool with parked workers
//! (no tokio offline) shared by every hot loop in the crate; metrics are
//! collected per phase and surfaced in the pipeline report (Tables 8/9
//! analogs).

pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use metrics::Metrics;
pub use pipeline::{Pipeline, PipelineReport, QuantizedLayer};
pub use pool::{global, run_indexed, run_jobs, run_unit_jobs, Scatter, WorkerPool};
