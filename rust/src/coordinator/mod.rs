//! L3 coordinator: the quantization pipeline orchestrator.
//!
//! Phases (each driven from Rust, Python never on the path):
//!   train → calib-stats (Hessian cache) → quantize (parallel
//!   (layer, group) jobs) → eval → serve.
//!
//! The worker pool is a std::thread job queue (no tokio offline); metrics
//! are collected per phase and surfaced in the pipeline report (Tables 8/9
//! analogs).

pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use metrics::Metrics;
pub use pipeline::{Pipeline, PipelineReport, QuantizedLayer};
pub use pool::run_jobs;
