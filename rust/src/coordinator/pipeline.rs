//! The end-to-end pipeline: train → calib-stats → quantize → eval.
//!
//! Everything executes from Rust: training steps and calibration
//! forward/backward run through AOT HLO artifacts on PJRT; the quantization
//! solvers run natively on the worker pool, one job per (layer, group) —
//! the "embarrassingly parallel" structure the paper exploits (App. B.1).

use anyhow::{Context, Result};

use crate::cfg::{preset, PipelineConfig, QuantConfig, QuantMethod};
use crate::data::{Batcher, Corpus, CorpusConfig, Split};
use crate::fisher::{collect_stats, CalibStats, HessianCache};
use crate::model::ParamStore;
use crate::quant::cd::{CdConfig, CdStrategy};
use crate::quant::gptq::Gptq;
use crate::quant::gptvq::{Gptvq1d, GptvqVq};
use crate::quant::grid::rtn_quantize;
use crate::quant::guided::group_ranges;
use crate::quant::lnq::Lnq;
use crate::quant::sparse::{split_outliers, SparseOverlay, SPARSE_OUTLIER_BITS};
use crate::quant::squeezellm::{squeezellm_quantize, SqueezeLlm};
use crate::quant::trellis::Trellis;
use crate::quant::{LayerQuantizer, QuantResult};
use crate::runtime::{Runtime, Value};
use crate::tensor::Mat;

use super::metrics::Metrics;
use super::pool::run_jobs;

/// One quantized linear (decoded weights + coding metadata).
pub struct QuantizedLayer {
    pub name: String,
    pub result: QuantResult,
}

pub struct Pipeline {
    pub cfg: PipelineConfig,
    pub rt: Runtime,
    pub corpus: Corpus,
    pub metrics: Metrics,
    pub cache: HessianCache,
}

#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub train_losses: Vec<f32>,
    pub calib_mean_loss: f64,
    pub ppl_fp_eval: f64,
    pub ppl_fp_shift: f64,
    pub ppl_q_eval: f64,
    pub ppl_q_shift: f64,
    pub avg_bits: f64,
    pub hessian_bytes: u64,
    pub phase_seconds: std::collections::BTreeMap<String, f64>,
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Result<Pipeline> {
        let rt = Runtime::load_model(&cfg.artifacts_dir, &cfg.model)?;
        let corpus = Corpus::new(CorpusConfig::for_vocab(rt.manifest.model.vocab, cfg.seed));
        let cache = HessianCache::new(std::path::Path::new(&cfg.out_dir).join("hessians"));
        Ok(Pipeline { cfg, rt, corpus, metrics: Metrics::new(), cache })
    }

    pub fn init_params(&self) -> ParamStore {
        let (model_cfg, _) = preset(&self.cfg.model);
        ParamStore::init_seeded(&model_cfg, self.cfg.seed)
    }

    fn values_to_params(&self, ps: &ParamStore, vals: &[Value]) -> Result<Vec<Mat>> {
        let specs = ps.cfg.param_specs();
        anyhow::ensure!(vals.len() == specs.len(), "param value count mismatch");
        specs
            .iter()
            .zip(vals)
            .map(|(spec, v)| {
                let data = v.as_f32()?.to_vec();
                anyhow::ensure!(
                    data.len() == spec.rows * spec.cols,
                    "param {} size mismatch",
                    spec.name
                );
                Ok(Mat::from_vec(spec.rows, spec.cols, data))
            })
            .collect()
    }

    /// Drive Adam training through the train_step artifact; returns the
    /// loss curve (the end-to-end driver logs this).
    pub fn train(&self, ps: &mut ParamStore, steps: usize, log_every: usize) -> Result<Vec<f32>> {
        let artifact = self.rt.artifact("train_step")?;
        let bc = self.rt.manifest.batch;
        let mut batcher = Batcher::new(&self.corpus, Split::Train, bc, steps);
        let n_p = ps.cfg.param_specs().len();
        let mut m: Vec<Value> = ps
            .cfg
            .param_specs()
            .iter()
            .map(|s| {
                if s.cols == 1 && s.name.ends_with("norm") {
                    Value::F32(vec![0.0; s.rows], vec![s.rows])
                } else {
                    Value::F32(vec![0.0; s.rows * s.cols], vec![s.rows, s.cols])
                }
            })
            .collect();
        let mut v = m.clone();
        let mut step = Value::Scalar(0.0);
        let mut losses = Vec::with_capacity(steps);
        for it in 0..steps {
            let Some(toks) = batcher.next_batch() else {
                break;
            };
            let mut args = self.rt.param_args(ps);
            args.extend(m.iter().cloned());
            args.extend(v.iter().cloned());
            args.push(step.clone());
            args.push(Value::tokens(bc.batch, bc.seq, &toks));
            let outs = artifact.execute(&args)?;
            anyhow::ensure!(outs.len() == 1 + 3 * n_p + 1, "train_step output arity");
            let loss = outs[0].scalar_f32()?;
            losses.push(loss);
            let new_params = self.values_to_params(ps, &outs[1..1 + n_p])?;
            ps.set_flat(new_params);
            m = outs[1 + n_p..1 + 2 * n_p].to_vec();
            v = outs[1 + 2 * n_p..1 + 3 * n_p].to_vec();
            step = Value::Scalar(outs[1 + 3 * n_p].scalar_f32()?);
            if log_every > 0 && (it % log_every == 0 || it + 1 == steps) {
                crate::log_info!("train", "step {it:4}  loss {loss:.4}");
            }
        }
        Ok(losses)
    }

    /// Calibration statistics, via the disk cache when available.
    pub fn calib(&self, ps: &ParamStore, force: bool) -> Result<CalibStats> {
        let key = format!("{}_{}", self.cfg.model, self.cfg.seed);
        if !force && self.cache.exists(&key) {
            crate::log_info!("calib", "loading Hessian cache for {key}");
            return self.cache.load(&key);
        }
        let bc = self.rt.manifest.batch;
        let mut batcher =
            Batcher::new(&self.corpus, Split::Calib, bc, self.cfg.calib_batches);
        let stats = self.metrics.time("calib_secs", || {
            collect_stats(&self.rt, ps, &mut batcher, self.cfg.calib_batches)
        })?;
        let bytes = self.cache.save(&key, &stats)?;
        self.metrics.set("hessian_cache_bytes", bytes as f64);
        crate::log_info!(
            "calib",
            "{} batches, mean loss {:.4}, cache {}",
            stats.batches,
            stats.mean_loss(),
            crate::util::human_bytes(bytes)
        );
        Ok(stats)
    }

    /// Quantize every linear with the configured method. Jobs are
    /// (layer, group)-granular and run on the worker pool.
    pub fn quantize(
        &self,
        ps: &ParamStore,
        stats: &CalibStats,
        qcfg: &QuantConfig,
    ) -> Result<Vec<QuantizedLayer>> {
        let specs = ps.cfg.linear_specs();
        self.metrics.time("quantize_secs", || {
            // Methods that ignore H quantize per linear in one job.
            match qcfg.method {
                QuantMethod::Rtn => {
                    let jobs: Vec<_> = specs
                        .iter()
                        .map(|spec| {
                            let w = ps.get(&spec.name).clone();
                            let bits = qcfg.bits;
                            let name = spec.name.clone();
                            move || QuantizedLayer { name, result: rtn_quantize(&w, bits) }
                        })
                        .collect();
                    return Ok(run_jobs(jobs, self.cfg.workers));
                }
                QuantMethod::SqueezeLlm => {
                    let sq = SqueezeLlm { bits: qcfg.bits, iters: 50, seed: qcfg.seed };
                    let jobs: Vec<_> = specs
                        .iter()
                        .map(|spec| {
                            let w = ps.get(&spec.name).clone();
                            let diagf = stats
                                .layer(&spec.name)
                                .map(|l| l.diagf.clone())
                                .unwrap_or_else(|| Mat::from_fn(w.rows, w.cols, |_, _| 1.0));
                            let sq = sq.clone();
                            let name = spec.name.clone();
                            move || QuantizedLayer {
                                name,
                                result: squeezellm_quantize(&w, &diagf, &sq).expect("squeezellm"),
                            }
                        })
                        .collect();
                    return Ok(run_jobs(jobs, self.cfg.workers));
                }
                _ => {}
            }

            // Layer-wise output-based methods: (layer, group) jobs.
            let g = if qcfg.groups == 0 { 1 } else { qcfg.groups.min(stats.groups) };
            struct GroupJobOut {
                li: usize,
                #[allow(dead_code)]
                k: usize,
                lo: usize,
                hi: usize,
                res: QuantResult,
            }
            let mut jobs: Vec<Box<dyn FnOnce() -> Result<GroupJobOut> + Send>> = Vec::new();
            for (li, spec) in specs.iter().enumerate() {
                let layer_stats = stats
                    .layer(&spec.name)
                    .with_context(|| format!("no calib stats for {}", spec.name))?;
                let hessians: Vec<Mat> = if qcfg.groups == 0 {
                    vec![layer_stats.plain_hessian().clone()]
                } else {
                    layer_stats.guided_hessians(g)
                };
                let w = ps.get(&spec.name);
                for (k, &(lo, hi)) in group_ranges(spec.d_out, hessians.len()).iter().enumerate() {
                    let h = hessians[k].clone();
                    let wg = w.slice_cols(lo, hi);
                    let qcfg = qcfg.clone();
                    jobs.push(Box::new(move || {
                        let q = build_quantizer(&qcfg)?;
                        let (dense, overlay) = if qcfg.sparse_frac > 0.0 {
                            split_outliers(&wg, None, qcfg.sparse_frac)
                        } else {
                            (wg.clone(), SparseOverlay::default())
                        };
                        let mut res = q.quantize(&h, &dense)?;
                        if !overlay.is_empty() {
                            overlay.apply(&mut res.w_hat);
                            res.avg_bits += overlay.len() as f64 * SPARSE_OUTLIER_BITS
                                / (wg.rows * wg.cols) as f64;
                        }
                        Ok(GroupJobOut { li, k: k + 1, lo, hi, res })
                    }));
                }
            }
            let outs = run_jobs(jobs, self.cfg.workers);
            // Assemble per linear.
            let mut per_linear: Vec<Option<QuantizedLayer>> = specs
                .iter()
                .map(|s| {
                    Some(QuantizedLayer {
                        name: s.name.clone(),
                        result: QuantResult {
                            w_hat: Mat::zeros(s.d_in, s.d_out),
                            codes: None,
                            codebooks: None,
                            avg_bits: 0.0,
                        },
                    })
                })
                .collect();
            let mut any_missing_codes = vec![false; specs.len()];
            for out in outs {
                let GroupJobOut { li, k: _, lo, hi, res } = out?;
                let spec = &specs[li];
                let slot = per_linear[li].as_mut().unwrap();
                slot.result.w_hat.paste_cols(lo, &res.w_hat);
                slot.result.avg_bits += res.avg_bits * (hi - lo) as f64 / spec.d_out as f64;
                match (res.codes, res.codebooks) {
                    // Only scalar-coded results (one code per weight) are
                    // reassembled; VQ/trellis codes use different layouts
                    // and are served through their own builders instead.
                    (Some(gc), Some(gcb))
                        if !any_missing_codes[li] && gc.len() == spec.d_in * (hi - lo) =>
                    {
                        let codes = slot
                            .result
                            .codes
                            .get_or_insert_with(|| vec![0u16; spec.d_in * spec.d_out]);
                        for i in 0..spec.d_in {
                            for (jj, j) in (lo..hi).enumerate() {
                                codes[i * spec.d_out + j] = gc[i * (hi - lo) + jj];
                            }
                        }
                        let cbs = slot
                            .result
                            .codebooks
                            .get_or_insert_with(|| Mat::zeros(spec.d_out, gcb.cols));
                        if cbs.cols == gcb.cols {
                            for (jj, j) in (lo..hi).enumerate() {
                                cbs.row_mut(j).copy_from_slice(gcb.row(jj));
                            }
                        } else {
                            any_missing_codes[li] = true;
                        }
                    }
                    _ => any_missing_codes[li] = true,
                }
            }
            let mut result = Vec::with_capacity(specs.len());
            for (li, slot) in per_linear.into_iter().enumerate() {
                let mut ql = slot.unwrap();
                if any_missing_codes[li] {
                    ql.result.codes = None;
                    ql.result.codebooks = None;
                }
                result.push(ql);
            }
            Ok(result)
        })
    }

    /// Install quantized weights into a copy of the parameter store.
    pub fn apply_quantized(&self, ps: &ParamStore, layers: &[QuantizedLayer]) -> ParamStore {
        let mut out = ps.clone();
        for l in layers {
            out.set(&l.name, l.result.w_hat.clone());
        }
        out
    }

    /// Weighted average bits across quantized layers.
    pub fn avg_bits(&self, layers: &[QuantizedLayer]) -> f64 {
        let mut bits = 0.0f64;
        let mut weight = 0.0f64;
        for l in layers {
            let n = (l.result.w_hat.rows * l.result.w_hat.cols) as f64;
            bits += l.result.avg_bits * n;
            weight += n;
        }
        bits / weight.max(1.0)
    }

    /// Perplexity on a split through the given fwd artifact
    /// ("fwd_loss" or a fwd_loss_qa* W&A variant).
    pub fn perplexity(&self, ps: &ParamStore, split: Split, artifact: &str) -> Result<f64> {
        crate::eval::perplexity(&self.rt, ps, &self.corpus, split, self.cfg.eval_batches, artifact)
    }

    /// Full pipeline run (the end-to-end driver).
    pub fn run(&self) -> Result<PipelineReport> {
        let mut report = PipelineReport::default();
        let mut ps = self.init_params();
        report.train_losses = self.metrics.time("train_secs", || {
            self.train(&mut ps, self.cfg.train_steps, self.cfg.train_steps.max(10) / 10)
        })?;
        let stats = self.calib(&ps, false)?;
        report.calib_mean_loss = stats.mean_loss();
        report.hessian_bytes = self.metrics.get("hessian_cache_bytes") as u64;
        report.ppl_fp_eval =
            self.metrics.time("eval_secs", || self.perplexity(&ps, Split::Eval, "fwd_loss"))?;
        report.ppl_fp_shift = self.perplexity(&ps, Split::EvalShift, "fwd_loss")?;
        let layers = self.quantize(&ps, &stats, &self.cfg.quant)?;
        report.avg_bits = self.avg_bits(&layers);
        let qps = self.apply_quantized(&ps, &layers);
        report.ppl_q_eval = self.perplexity(&qps, Split::Eval, "fwd_loss")?;
        report.ppl_q_shift = self.perplexity(&qps, Split::EvalShift, "fwd_loss")?;
        report.phase_seconds = self.metrics.snapshot();
        Ok(report)
    }
}

/// Build the configured layer-wise quantizer.
pub fn build_quantizer(qcfg: &QuantConfig) -> Result<Box<dyn LayerQuantizer>> {
    let cd = CdConfig {
        cycles: qcfg.cd_cycles,
        strategy: CdStrategy::Lazy { block: qcfg.cd_block },
    };
    Ok(match qcfg.method {
        QuantMethod::Gptq => Box::new(Gptq { bits: qcfg.bits, block: qcfg.cd_block }),
        QuantMethod::Lnq => Box::new(Lnq {
            bits: qcfg.bits,
            t_iters: qcfg.lnq_iters,
            cd,
            sensitivity: None,
            seed: qcfg.seed,
        }),
        QuantMethod::Gptvq1d => Box::new(Gptvq1d { bits: qcfg.bits, t_iters: 2, gd_steps: 8, seed: qcfg.seed }),
        QuantMethod::Gptvq2d => Box::new(GptvqVq { bits: qcfg.bits, dim: qcfg.vq_dim, seed: qcfg.seed }),
        QuantMethod::Trellis => {
            let mut t = Trellis::new(qcfg.bits, qcfg.trellis_variant);
            t.seed = qcfg.seed;
            Box::new(t)
        }
        QuantMethod::Rtn | QuantMethod::SqueezeLlm => {
            anyhow::bail!("{:?} is not a layer-wise output-based method", qcfg.method)
        }
    })
}

impl PipelineReport {
    pub fn print(&self) {
        println!("== pipeline report ==");
        if let (Some(first), Some(last)) = (self.train_losses.first(), self.train_losses.last()) {
            println!(
                "train: {} steps, loss {first:.3} -> {last:.3}",
                self.train_losses.len()
            );
        }
        println!("calib mean loss: {:.4}", self.calib_mean_loss);
        println!(
            "ppl fp:    eval {:.3}  shift {:.3}",
            self.ppl_fp_eval, self.ppl_fp_shift
        );
        println!(
            "ppl quant: eval {:.3}  shift {:.3}  (avg bits {:.2})",
            self.ppl_q_eval, self.ppl_q_shift, self.avg_bits
        );
        for (k, v) in &self.phase_seconds {
            println!("  {k}: {v:.2}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_quantizer_dispatch() {
        for method in [
            QuantMethod::Gptq,
            QuantMethod::Lnq,
            QuantMethod::Gptvq1d,
            QuantMethod::Gptvq2d,
            QuantMethod::Trellis,
        ] {
            let q = build_quantizer(&QuantConfig::with(method, 2, 2)).unwrap();
            assert!(!q.name().is_empty());
        }
        assert!(build_quantizer(&QuantConfig::with(QuantMethod::Rtn, 2, 2)).is_err());
    }
}
