//! Worker pool: run an ordered list of independent jobs across threads.
//!
//! Jobs are claimed from a shared atomic cursor (work stealing without
//! queues); results land in their original slots, so output order is
//! deterministic regardless of scheduling. Panics in jobs propagate.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `jobs` on up to `workers` threads, preserving result order.
pub fn run_jobs<T, F>(jobs: Vec<F>, workers: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let cursor = AtomicUsize::new(0);
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                let out = job();
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|r| r.into_inner().unwrap().expect("job did not complete"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = run_jobs(jobs, 8);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        let out: Vec<i32> = run_jobs(Vec::<fn() -> i32>::new(), 4);
        assert!(out.is_empty());
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_jobs(jobs, 1), vec![0, 1, 2]);
    }

    #[test]
    fn actually_parallel() {
        // With 4 workers, 4 sleeping jobs should finish in ~1 sleep.
        let t = std::time::Instant::now();
        let jobs: Vec<_> = (0..4)
            .map(|_| move || std::thread::sleep(std::time::Duration::from_millis(50)))
            .collect();
        run_jobs(jobs, 4);
        assert!(t.elapsed().as_millis() < 180, "{:?}", t.elapsed());
    }

    #[test]
    #[should_panic]
    fn job_panic_propagates() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| 1), Box::new(|| panic!("boom"))];
        run_jobs(jobs, 2);
    }
}
