//! Persistent worker pool: long-lived parked threads + scoped job batches.
//!
//! The pool spawns its threads once (see [`global`]) and parks them on a
//! condvar; every hot loop in the system — the batched serve kernels, the
//! dense matmuls, the (layer, group) quantization jobs, prompt prefill —
//! submits work here instead of paying a thread spawn per call.
//!
//! [`run_jobs`] keeps its original contract: an ordered list of independent
//! jobs, results in their original slots, panics propagated. Jobs are
//! claimed from a shared atomic cursor (work stealing without queues), and
//! the *calling* thread always participates as one worker, so a pool of
//! `n - 1` threads yields `n`-wide parallelism and a zero-thread pool
//! degrades to serial execution.
//!
//! Jobs may borrow from the caller's stack even though the pool threads are
//! long-lived: helper tasks are lifetime-erased before entering the shared
//! queue, and `run_with` does not return until every helper has finished
//! (each one counts down a per-run latch on completion, panic included).
//! While waiting, the caller help-drains the shared queue — running either
//! its own not-yet-started helpers (no-ops once the cursor is exhausted) or
//! other runs' tasks — so nested `run_with` calls from inside pool jobs
//! cannot deadlock even when every pool thread is busy.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct TaskQueue {
    tasks: VecDeque<Task>,
    shutdown: bool,
}

struct PoolShared {
    queue: Mutex<TaskQueue>,
    available: Condvar,
}

/// Countdown latch: one count per helper task of a run.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), done: Condvar::new() }
    }

    fn count_down(&self) {
        let mut n = self.remaining.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait_timeout(&self, d: Duration) {
        let n = self.remaining.lock().unwrap();
        if *n > 0 {
            drop(self.done.wait_timeout(n, d).unwrap());
        }
    }
}

/// Decrements its latch on drop, so a panicking task still releases its run.
struct LatchGuard<'a>(&'a Latch);

impl Drop for LatchGuard<'_> {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break Some(t);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match task {
            // Tasks catch their own job panics; this outer catch only keeps
            // a stray panic from killing the worker thread.
            Some(t) => drop(catch_unwind(AssertUnwindSafe(t))),
            None => return,
        }
    }
}

/// A persistent pool of parked worker threads.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `threads` parked workers (0 is valid: every run
    /// executes serially on the calling thread).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(TaskQueue { tasks: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gq-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, threads, handles }
    }

    /// Number of pool threads (parallel width is `threads() + 1`: the
    /// caller always works too).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn try_pop(&self) -> Option<Task> {
        self.shared.queue.lock().unwrap().tasks.pop_front()
    }

    fn push_tasks(&self, tasks: Vec<Task>) {
        let mut q = self.shared.queue.lock().unwrap();
        for t in tasks {
            q.tasks.push_back(t);
        }
        drop(q);
        self.shared.available.notify_all();
    }

    /// Run `jobs` at the pool's full width, preserving result order.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        self.run_with(jobs, usize::MAX)
    }

    /// Run `jobs` with at most `workers` executing concurrently (the caller
    /// counts as one). Results land in their original slots regardless of
    /// scheduling; a panic in any job is re-raised here after the batch
    /// drains. Thin result-collecting layer over [`WorkerPool::run_units`];
    /// scatter-style kernels that write into pre-split buffers should call
    /// `run_units` directly and skip the per-job result slots.
    pub fn run_with<T, F>(&self, jobs: Vec<F>, workers: usize) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let units: Vec<_> = jobs
            .into_iter()
            .zip(&results)
            .map(|(job, slot)| {
                move || {
                    let out = job();
                    *slot.lock().unwrap() = Some(out);
                }
            })
            .collect();
        self.run_units(units, workers);
        results
            .into_iter()
            .map(|r| r.into_inner().unwrap().expect("job did not complete"))
            .collect()
    }

    /// Run result-less `jobs` with at most `workers` executing concurrently
    /// (the caller counts as one). The workhorse behind [`WorkerPool::run`]
    /// / [`WorkerPool::run_with`] and the scatter-style kernels (e.g. the
    /// lane×head attention fan-out) whose jobs write into disjoint caller
    /// buffers: no per-job result slot is allocated. A panic in any job is
    /// re-raised here after the batch drains.
    pub fn run_units<F>(&self, jobs: Vec<F>, workers: usize)
    where
        F: FnOnce() + Send,
    {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let workers = workers.clamp(1, n).min(self.threads + 1);
        if workers <= 1 {
            for j in jobs {
                j();
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
        let panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let drive = || loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let job = jobs[i].lock().unwrap().take().expect("job claimed twice");
            if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
                let mut slot = panic_slot.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
        };
        let helpers = workers - 1;
        let latch = Latch::new(helpers);
        {
            let mut tasks: Vec<Task> = Vec::with_capacity(helpers);
            for _ in 0..helpers {
                let drive_ref = &drive;
                let latch_ref = &latch;
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let _guard = LatchGuard(latch_ref);
                    drive_ref();
                });
                // SAFETY: the task borrows only from this stack frame
                // (drive's captures and the latch). The frame is not left
                // until the latch confirms every helper finished — the
                // LatchGuard counts down even on panic — so no borrow
                // outlives its referent.
                tasks.push(unsafe { erase_task(task) });
            }
            self.push_tasks(tasks);
            drive();
            // Help-drain while waiting: a popped task is either one of our
            // own helpers (instant no-op now the cursor is exhausted) or
            // another run's work — running either guarantees progress even
            // when every pool thread is blocked inside a nested run.
            while !latch.is_done() {
                match self.try_pop() {
                    // Same panic shield as worker_loop: a panicking foreign
                    // task must not unwind out of this frame before our own
                    // latch is done — queued helpers still borrow it.
                    Some(t) => drop(catch_unwind(AssertUnwindSafe(t))),
                    None => latch.wait_timeout(Duration::from_millis(1)),
                }
            }
        }
        // Every helper has finished (latch), so nothing borrows `drive` or
        // the job slots any more.
        drop(drive);
        if let Some(p) = panic_slot.into_inner().unwrap() {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Lifetime-erase a borrowing task so it can sit in the `'static` queue.
///
/// # Safety
/// The caller must keep every borrow in `t` alive until the task has
/// finished executing. `run_units` guarantees this by waiting on the
/// per-run latch before leaving the frame the task borrows from.
unsafe fn erase_task<'a>(t: Box<dyn FnOnce() + Send + 'a>) -> Task {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Task>(t)
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool, created on first use with
/// `tensor::ops::num_threads() - 1` workers (caller participation makes the
/// effective width `num_threads()`; `GQ_THREADS=1` therefore forces fully
/// serial execution).
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| WorkerPool::new(crate::tensor::ops::num_threads().saturating_sub(1)))
}

/// Run `jobs` on up to `workers` threads of the shared pool, preserving
/// result order. Thin wrapper over [`WorkerPool::run_with`] on [`global`];
/// kept as the crate-wide entry point so callers never pay thread-spawn
/// cost per call.
///
/// Unlike the old spawn-per-call implementation, concurrency is capped at
/// the pool width (`num_threads()`, i.e. the `GQ_THREADS` override or
/// `available_parallelism`) — asking for more workers than the machine has
/// no longer oversubscribes it.
pub fn run_jobs<T, F>(jobs: Vec<F>, workers: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    global().run_with(jobs, workers)
}

/// Run result-less `jobs` on up to `workers` threads of the shared pool.
/// Scatter entry point ([`WorkerPool::run_units`] on [`global`]): jobs that
/// write into disjoint caller-owned buffers skip the per-job result slots
/// `run_jobs` would allocate — the steady-state path of the lane×head
/// attention fan-out.
pub fn run_unit_jobs<F>(jobs: Vec<F>, workers: usize)
where
    F: FnOnce() + Send,
{
    global().run_units(jobs, workers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = run_jobs(jobs, 8);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        let out: Vec<i32> = run_jobs(Vec::<fn() -> i32>::new(), 4);
        assert!(out.is_empty());
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_jobs(jobs, 1), vec![0, 1, 2]);
    }

    #[test]
    fn actually_parallel() {
        // On a dedicated 4-thread pool, 4 sleeping jobs (caller + 3
        // helpers at minimum) should finish in ~1 sleep.
        let pool = WorkerPool::new(4);
        let t = std::time::Instant::now();
        let jobs: Vec<_> = (0..4)
            .map(|_| move || std::thread::sleep(Duration::from_millis(50)))
            .collect();
        pool.run(jobs);
        assert!(t.elapsed().as_millis() < 180, "{:?}", t.elapsed());
    }

    #[test]
    fn pool_threads_persist_across_runs() {
        let pool = WorkerPool::new(2);
        for round in 0..10 {
            let jobs: Vec<_> = (0..8).map(|i| move || i + round).collect();
            let out = pool.run(jobs);
            assert_eq!(out, (0..8).map(|i| i + round).collect::<Vec<_>>());
        }
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn zero_thread_pool_runs_serially() {
        let pool = WorkerPool::new(0);
        let jobs: Vec<_> = (0..5).map(|i| move || i * i).collect();
        assert_eq!(pool.run(jobs), vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn nested_runs_do_not_deadlock() {
        // Every outer job itself fans out on the same (tiny) pool: inner
        // runs must complete even with all pool threads busy in outer jobs.
        let pool = WorkerPool::new(2);
        let jobs: Vec<_> = (0..6)
            .map(|i| {
                let pool = &pool;
                move || {
                    let inner: Vec<_> = (0..4).map(|j| move || i * 10 + j).collect();
                    pool.run(inner).iter().sum::<i32>()
                }
            })
            .collect();
        let out = pool.run(jobs);
        let want: Vec<i32> = (0..6).map(|i| (0..4).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn borrowed_state_is_safe() {
        let data: Vec<u64> = (0..100).collect();
        let jobs: Vec<_> = data
            .chunks(7)
            .map(|chunk| move || chunk.iter().sum::<u64>())
            .collect();
        let total: u64 = run_jobs(jobs, 4).iter().sum();
        assert_eq!(total, data.iter().sum::<u64>());
    }

    #[test]
    fn unit_jobs_write_disjoint_buffers() {
        // The scatter path: jobs mutate pre-split chunks of one buffer.
        let mut data = vec![0u64; 40];
        let jobs: Vec<_> = data
            .chunks_mut(7)
            .enumerate()
            .map(|(i, chunk)| {
                move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 100 + j) as u64;
                    }
                }
            })
            .collect();
        run_unit_jobs(jobs, 4);
        for (i, chunk) in data.chunks(7).enumerate() {
            for (j, &v) in chunk.iter().enumerate() {
                assert_eq!(v, (i * 100 + j) as u64);
            }
        }
    }

    #[test]
    #[should_panic(expected = "unit boom")]
    fn unit_job_panic_propagates() {
        let jobs: Vec<Box<dyn FnOnce() + Send>> =
            vec![Box::new(|| {}), Box::new(|| panic!("unit boom"))];
        run_unit_jobs(jobs, 2);
    }

    #[test]
    #[should_panic]
    fn job_panic_propagates() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| 1), Box::new(|| panic!("boom"))];
        run_jobs(jobs, 2);
    }

    #[test]
    fn panic_does_not_poison_the_pool() {
        let pool = WorkerPool::new(2);
        let bad: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| 2)];
        assert!(catch_unwind(AssertUnwindSafe(|| pool.run(bad))).is_err());
        // The pool stays usable after a panicking batch.
        let jobs: Vec<_> = (0..4).map(|i| move || i).collect();
        assert_eq!(pool.run(jobs), vec![0, 1, 2, 3]);
    }
}
