//! Persistent worker pool: long-lived parked threads + scoped job batches.
//!
//! The pool spawns its threads once (see [`global`]) and parks them on a
//! condvar; every hot loop in the system — the batched serve kernels, the
//! dense matmuls, the (layer, group) quantization jobs, prompt prefill —
//! submits work here instead of paying a thread spawn per call.
//!
//! ## Indexed scatter, allocation-free
//!
//! The primitive is [`WorkerPool::run_indexed`]: run `f(0..n)` with the
//! items claimed from a shared atomic cursor (work stealing without
//! queues). The calling thread always participates as one worker, so a
//! pool of `n - 1` threads yields `n`-wide parallelism and a zero-thread
//! pool degrades to serial execution. Submission enqueues only small
//! plain-data helper stubs (lifetime-erased pointers to the run's shared
//! drive closure and completion latch) into the pool's reusable queue —
//! a warm indexed run performs **zero heap allocations** on the
//! submitting thread, which is what lets the column-sharded batched decode
//! step stay allocation-free in the serve steady state.
//!
//! [`run_jobs`] / [`run_unit_jobs`] keep their original contracts (an
//! ordered list of independent one-shot jobs, results in their original
//! slots, panics propagated) as thin layers over `run_indexed`.
//!
//! Jobs may borrow from the caller's stack even though the pool threads
//! are long-lived: the queued helper stubs point into the submitting
//! frame, and `run_indexed` does not return until every stub has finished
//! (each one counts down a per-run latch on completion, panic included).
//! While waiting, the caller help-drains the shared queue — running either
//! its own not-yet-started helpers (no-ops once the cursor is exhausted)
//! or other runs' stubs — so nested runs from inside pool jobs cannot
//! deadlock even when every pool thread is busy.

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// Lifetime-erased pointer to an in-flight run's shared drive closure.
type DrivePtr = *const (dyn Fn() + Sync + 'static);

/// A queued helper stub for an in-flight indexed run: plain data, so
/// enqueueing helpers never allocates (the queue's buffer is reused across
/// runs). Both pointers target the submitting stack frame, which stays
/// alive until the run's latch confirms every stub has finished.
#[derive(Clone, Copy)]
struct Helper {
    drive: DrivePtr,
    latch: *const Latch,
}

// SAFETY: the pointees are Sync (`dyn Fn() + Sync`, `Latch`), and the
// submitting frame outlives every queued copy (latch protocol below).
unsafe impl Send for Helper {}

struct TaskQueue {
    tasks: std::collections::VecDeque<Helper>,
    shutdown: bool,
}

struct PoolShared {
    queue: Mutex<TaskQueue>,
    available: Condvar,
}

/// Countdown latch: one count per helper stub of a run.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), done: Condvar::new() }
    }

    fn count_down(&self) {
        let mut n = self.remaining.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait_timeout(&self, d: Duration) {
        let n = self.remaining.lock().unwrap();
        if *n > 0 {
            drop(self.done.wait_timeout(n, d).unwrap());
        }
    }
}

/// Decrements its latch on drop, so a panicking task still releases its run.
struct LatchGuard<'a>(&'a Latch);

impl Drop for LatchGuard<'_> {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// Execute one queued helper stub: join the run it points at.
fn run_helper(h: Helper) {
    // SAFETY: the submitting frame of `run_indexed` keeps the drive
    // closure and latch alive until the latch reaches zero, and the guard
    // counts down even if the drive panics — so both derefs are live.
    let latch = unsafe { &*h.latch };
    let _guard = LatchGuard(latch);
    let drive = unsafe { &*h.drive };
    // The drive catches per-item panics itself; this outer catch only
    // keeps a stray panic from unwinding into pool machinery.
    drop(catch_unwind(AssertUnwindSafe(drive)));
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break Some(t);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match task {
            Some(h) => run_helper(h),
            None => return,
        }
    }
}

/// A persistent pool of parked worker threads.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `threads` parked workers (0 is valid: every run
    /// executes serially on the calling thread).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(TaskQueue {
                // Generous stub capacity up front (stubs are ~3 words):
                // enqueueing helpers must not realloc mid-serve — the
                // zero-allocation steady state of the sharded decode step
                // depends on it even with many concurrent indexed runs.
                tasks: std::collections::VecDeque::with_capacity(256),
                shutdown: false,
            }),
            available: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gq-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, threads, handles }
    }

    /// Number of pool threads (parallel width is `threads() + 1`: the
    /// caller always works too).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn try_pop(&self) -> Option<Helper> {
        self.shared.queue.lock().unwrap().tasks.pop_front()
    }

    fn push_helpers(&self, h: Helper, count: usize) {
        let mut q = self.shared.queue.lock().unwrap();
        for _ in 0..count {
            q.tasks.push_back(h);
        }
        drop(q);
        self.shared.available.notify_all();
    }

    /// Run `f(i)` for every `i in 0..n` with at most `workers` executing
    /// concurrently (the caller counts as one and always participates).
    /// Items are claimed from a shared cursor, so each index runs exactly
    /// once; a panic in any item is re-raised here after the batch drains.
    ///
    /// This is the pool's scatter workhorse: `f` is shared by all workers
    /// (`Sync`), items write into disjoint caller-owned buffers (see
    /// [`Scatter`]), and a warm call performs no heap allocation on the
    /// submitting thread.
    pub fn run_indexed(&self, n: usize, workers: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        let workers = workers.clamp(1, n).min(self.threads + 1);
        if workers <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let drive = || loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = panic_slot.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
        };
        let helpers = workers - 1;
        let latch = Latch::new(helpers);
        {
            // SAFETY: the queued stubs borrow only this stack frame (the
            // drive closure's captures and the latch). The frame is not
            // left until the latch confirms every stub finished — the
            // LatchGuard counts down even on panic — so no borrow outlives
            // its referent.
            let h = Helper { drive: unsafe { erase_drive(&drive) }, latch: &latch };
            self.push_helpers(h, helpers);
            drive();
            // Help-drain while waiting: a popped stub is either one of our
            // own helpers (instant no-op now the cursor is exhausted) or
            // another run's work — running either guarantees progress even
            // when every pool thread is blocked inside a nested run.
            while !latch.is_done() {
                match self.try_pop() {
                    Some(t) => run_helper(t),
                    None => latch.wait_timeout(Duration::from_millis(1)),
                }
            }
        }
        drop(drive);
        if let Some(p) = panic_slot.into_inner().unwrap() {
            resume_unwind(p);
        }
    }

    /// Run `jobs` at the pool's full width, preserving result order.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        self.run_with(jobs, usize::MAX)
    }

    /// Run `jobs` with at most `workers` executing concurrently (the caller
    /// counts as one). Results land in their original slots regardless of
    /// scheduling; a panic in any job is re-raised here after the batch
    /// drains. Thin result-collecting layer over [`WorkerPool::run_units`];
    /// scatter-style kernels that write into pre-split buffers should use
    /// `run_units` or [`WorkerPool::run_indexed`] and skip the per-job
    /// result slots.
    pub fn run_with<T, F>(&self, jobs: Vec<F>, workers: usize) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let units: Vec<_> = jobs
            .into_iter()
            .zip(&results)
            .map(|(job, slot)| {
                move || {
                    let out = job();
                    *slot.lock().unwrap() = Some(out);
                }
            })
            .collect();
        self.run_units(units, workers);
        results
            .into_iter()
            .map(|r| r.into_inner().unwrap().expect("job did not complete"))
            .collect()
    }

    /// Run result-less one-shot `jobs` with at most `workers` executing
    /// concurrently. Layer over [`WorkerPool::run_indexed`]: the cursor
    /// claims each slot exactly once, so every `FnOnce` runs exactly once.
    /// (This path allocates per-job slots; kernels on the zero-allocation
    /// steady state use `run_indexed` directly.)
    pub fn run_units<F>(&self, jobs: Vec<F>, workers: usize)
    where
        F: FnOnce() + Send,
    {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let slots: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
        self.run_indexed(n, workers, &|i| {
            let job = slots[i].lock().unwrap().take().expect("job claimed twice");
            job();
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Lifetime-erase a borrowed drive closure so its stub can sit in the
/// `'static` queue.
///
/// # Safety
/// The caller must keep the closure alive (and unmoved) until every queued
/// stub pointing at it has finished executing. `run_indexed` guarantees
/// this by waiting on the per-run latch before leaving the frame.
unsafe fn erase_drive<'a>(d: &'a (dyn Fn() + Sync + 'a)) -> DrivePtr {
    std::mem::transmute::<&'a (dyn Fn() + Sync + 'a), &'static (dyn Fn() + Sync + 'static)>(d)
}

/// Shared handle over a `&mut [T]` for indexed scatter jobs
/// ([`WorkerPool::run_indexed`]) that write DISJOINT ranges concurrently.
/// The exclusive borrow is parked in the handle for `'a`; jobs carve it
/// back into non-overlapping `&mut` slices.
pub struct Scatter<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: PhantomData<&'a mut [T]>,
}

// SAFETY: the handle only hands out slices through the unsafe, disjointness-
// contracted `slice_mut`; T: Send makes cross-thread writes sound.
unsafe impl<T: Send> Sync for Scatter<'_, T> {}
unsafe impl<T: Send> Send for Scatter<'_, T> {}

impl<'a, T> Scatter<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        Scatter { ptr: data.as_mut_ptr(), len: data.len(), _life: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The underlying base pointer (for strided window views that cannot be
    /// expressed as one contiguous slice — e.g. column windows).
    pub fn as_mut_ptr(&self) -> *mut T {
        self.ptr
    }

    /// Elements `[off, off + n)` as an exclusive slice.
    ///
    /// # Safety
    /// The ranges handed out to concurrently live slices must be pairwise
    /// disjoint and in bounds, and the caller must not touch the original
    /// slice for `'a`.
    #[allow(clippy::mut_from_ref)] // scatter handle: disjointness is the contract
    pub unsafe fn slice_mut(&self, off: usize, n: usize) -> &'a mut [T] {
        debug_assert!(off + n <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(off), n)
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool, created on first use with
/// `tensor::ops::num_threads() - 1` workers (caller participation makes the
/// effective width `num_threads()`; `GQ_THREADS=1` therefore forces fully
/// serial execution).
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| WorkerPool::new(crate::tensor::ops::num_threads().saturating_sub(1)))
}

/// Run `jobs` on up to `workers` threads of the shared pool, preserving
/// result order. Thin wrapper over [`WorkerPool::run_with`] on [`global`];
/// kept as the crate-wide entry point so callers never pay thread-spawn
/// cost per call.
///
/// Concurrency is capped at the pool width (`num_threads()`, i.e. the
/// `GQ_THREADS` override or `available_parallelism`) — asking for more
/// workers than the machine has does not oversubscribe it.
pub fn run_jobs<T, F>(jobs: Vec<F>, workers: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    global().run_with(jobs, workers)
}

/// Run result-less `jobs` on up to `workers` threads of the shared pool
/// ([`WorkerPool::run_units`] on [`global`]).
pub fn run_unit_jobs<F>(jobs: Vec<F>, workers: usize)
where
    F: FnOnce() + Send,
{
    global().run_units(jobs, workers)
}

/// Run `f(0..n)` on up to `workers` threads of the shared pool
/// ([`WorkerPool::run_indexed`] on [`global`]): the allocation-free scatter
/// entry point for kernels whose items are computable from their index and
/// write disjoint regions (column-sharded decode, lane×head attention).
pub fn run_indexed(n: usize, workers: usize, f: &(dyn Fn(usize) + Sync)) {
    global().run_indexed(n, workers, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = run_jobs(jobs, 8);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        let out: Vec<i32> = run_jobs(Vec::<fn() -> i32>::new(), 4);
        assert!(out.is_empty());
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_jobs(jobs, 1), vec![0, 1, 2]);
    }

    #[test]
    fn actually_parallel() {
        // On a dedicated 4-thread pool, 4 sleeping jobs (caller + 3
        // helpers at minimum) should finish in ~1 sleep.
        let pool = WorkerPool::new(4);
        let t = std::time::Instant::now();
        let jobs: Vec<_> = (0..4)
            .map(|_| move || std::thread::sleep(Duration::from_millis(50)))
            .collect();
        pool.run(jobs);
        assert!(t.elapsed().as_millis() < 180, "{:?}", t.elapsed());
    }

    #[test]
    fn pool_threads_persist_across_runs() {
        let pool = WorkerPool::new(2);
        for round in 0..10 {
            let jobs: Vec<_> = (0..8).map(|i| move || i + round).collect();
            let out = pool.run(jobs);
            assert_eq!(out, (0..8).map(|i| i + round).collect::<Vec<_>>());
        }
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn zero_thread_pool_runs_serially() {
        let pool = WorkerPool::new(0);
        let jobs: Vec<_> = (0..5).map(|i| move || i * i).collect();
        assert_eq!(pool.run(jobs), vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn nested_runs_do_not_deadlock() {
        // Every outer job itself fans out on the same (tiny) pool: inner
        // runs must complete even with all pool threads busy in outer jobs.
        let pool = WorkerPool::new(2);
        let jobs: Vec<_> = (0..6)
            .map(|i| {
                let pool = &pool;
                move || {
                    let inner: Vec<_> = (0..4).map(|j| move || i * 10 + j).collect();
                    pool.run(inner).iter().sum::<i32>()
                }
            })
            .collect();
        let out = pool.run(jobs);
        let want: Vec<i32> = (0..6).map(|i| (0..4).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn borrowed_state_is_safe() {
        let data: Vec<u64> = (0..100).collect();
        let jobs: Vec<_> = data
            .chunks(7)
            .map(|chunk| move || chunk.iter().sum::<u64>())
            .collect();
        let total: u64 = run_jobs(jobs, 4).iter().sum();
        assert_eq!(total, data.iter().sum::<u64>());
    }

    #[test]
    fn unit_jobs_write_disjoint_buffers() {
        // The scatter path: jobs mutate pre-split chunks of one buffer.
        let mut data = vec![0u64; 40];
        let jobs: Vec<_> = data
            .chunks_mut(7)
            .enumerate()
            .map(|(i, chunk)| {
                move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 100 + j) as u64;
                    }
                }
            })
            .collect();
        run_unit_jobs(jobs, 4);
        for (i, chunk) in data.chunks(7).enumerate() {
            for (j, &v) in chunk.iter().enumerate() {
                assert_eq!(v, (i * 100 + j) as u64);
            }
        }
    }

    #[test]
    fn indexed_runs_each_item_exactly_once() {
        let counts: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        run_indexed(97, 8, &|i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn indexed_scatter_writes_disjoint_ranges() {
        let mut data = vec![0u32; 50];
        let scatter = Scatter::new(&mut data);
        run_indexed(10, 4, &|t| {
            // SAFETY: item t writes [t*5, t*5+5) — disjoint across items.
            let chunk = unsafe { scatter.slice_mut(t * 5, 5) };
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (t * 10 + j) as u32;
            }
        });
        for (t, chunk) in data.chunks(5).enumerate() {
            for (j, &v) in chunk.iter().enumerate() {
                assert_eq!(v, (t * 10 + j) as u32);
            }
        }
    }

    #[test]
    fn warm_indexed_run_is_allocation_free_on_the_submitting_thread() {
        use crate::testing::alloc_count::count_allocs;
        // A dedicated pool keeps the probe deterministic: no other test's
        // stubs share this queue.
        let pool = WorkerPool::new(3);
        let mut data = vec![0.0f32; 64];
        for _ in 0..3 {
            let scatter = Scatter::new(&mut data);
            pool.run_indexed(8, 8, &|t| {
                let chunk = unsafe { scatter.slice_mut(t * 8, 8) };
                chunk.fill(t as f32);
            });
        }
        let scatter = Scatter::new(&mut data);
        let ((), n) = count_allocs(|| {
            pool.run_indexed(8, 8, &|t| {
                let chunk = unsafe { scatter.slice_mut(t * 8, 8) };
                chunk.fill(t as f32 + 1.0);
            });
        });
        assert_eq!(n, 0, "indexed submission must not allocate when warm");
        assert_eq!(data[63], 8.0);
    }

    #[test]
    fn nested_indexed_runs_do_not_deadlock() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        pool.run_indexed(6, 6, &|i| {
            pool.run_indexed(4, 4, &|j| {
                total.fetch_add(i * 10 + j, Ordering::Relaxed);
            });
        });
        let want: usize = (0..6).map(|i| (0..4).map(|j| i * 10 + j).sum::<usize>()).sum();
        assert_eq!(total.load(Ordering::Relaxed), want);
    }

    #[test]
    #[should_panic(expected = "indexed boom")]
    fn indexed_panic_propagates() {
        run_indexed(4, 4, &|i| {
            if i == 2 {
                panic!("indexed boom");
            }
        });
    }

    #[test]
    #[should_panic(expected = "unit boom")]
    fn unit_job_panic_propagates() {
        let jobs: Vec<Box<dyn FnOnce() + Send>> =
            vec![Box::new(|| {}), Box::new(|| panic!("unit boom"))];
        run_unit_jobs(jobs, 2);
    }

    #[test]
    #[should_panic]
    fn job_panic_propagates() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| 1), Box::new(|| panic!("boom"))];
        run_jobs(jobs, 2);
    }

    #[test]
    fn panic_does_not_poison_the_pool() {
        let pool = WorkerPool::new(2);
        let bad: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| 2)];
        assert!(catch_unwind(AssertUnwindSafe(|| pool.run(bad))).is_err());
        // The pool stays usable after a panicking batch.
        let jobs: Vec<_> = (0..4).map(|i| move || i).collect();
        assert_eq!(pool.run(jobs), vec![0, 1, 2, 3]);
    }

    #[test]
    fn indexed_panic_drains_the_batch_and_leaves_the_pool_usable() {
        // A mid-run panic must not strand the latch or leave stale stubs
        // in the queue: the run re-raises only after every item has been
        // claimed, and the next run on the same pool completes normally.
        let pool = WorkerPool::new(3);
        for round in 0..5 {
            let counts: Vec<AtomicUsize> = (0..32).map(|_| AtomicUsize::new(0)).collect();
            let err = catch_unwind(AssertUnwindSafe(|| {
                pool.run_indexed(32, 4, &|i| {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                    if i == 7 {
                        panic!("indexed boom round {round}");
                    }
                });
            }));
            assert!(err.is_err(), "round {round}: panic must propagate");
            // Every item was still claimed exactly once — the cursor
            // drains the batch even with one item panicking.
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "round {round}, item {i}");
            }
        }
        // Fresh clean run on the recovered pool.
        let total = AtomicUsize::new(0);
        pool.run_indexed(16, 4, &|i| {
            total.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), (1..=16).sum::<usize>());
    }

    #[test]
    fn unit_job_panic_leaves_the_pool_usable() {
        let pool = WorkerPool::new(2);
        let ran = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..8)
            .map(|i| {
                let ran = &ran;
                move || {
                    ran.fetch_add(1, Ordering::Relaxed);
                    if i == 3 {
                        panic!("unit boom");
                    }
                }
            })
            .collect();
        assert!(catch_unwind(AssertUnwindSafe(|| pool.run_units(jobs, 3))).is_err());
        assert_eq!(ran.load(Ordering::Relaxed), 8, "all unit jobs still ran");
        // The `job claimed twice` expect inside run_units would fire here
        // if the panicking batch had left a stub replaying stale slots.
        let mut data = vec![0usize; 6];
        let jobs: Vec<_> = data
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| move || *slot = i * 3)
            .collect();
        pool.run_units(jobs, 3);
        assert_eq!(data, vec![0, 3, 6, 9, 12, 15]);
    }

    #[test]
    fn nested_fan_out_survives_inner_panics() {
        // Outer items help-drain the shared queue while their inner runs
        // complete; an inner panic unwinds through the outer item (both
        // levels drain their latches) and the pool keeps serving nested
        // rounds afterwards.
        let pool = WorkerPool::new(2);
        for _ in 0..3 {
            let err = catch_unwind(AssertUnwindSafe(|| {
                pool.run_indexed(4, 4, &|i| {
                    pool.run_indexed(3, 3, &|j| {
                        if i == 2 && j == 1 {
                            panic!("nested boom");
                        }
                    });
                });
            }));
            assert!(err.is_err(), "nested panic must propagate to the outer run");
            // Recovery probe: a full nested fan-out still completes.
            let total = AtomicUsize::new(0);
            pool.run_indexed(4, 4, &|i| {
                pool.run_indexed(3, 3, &|j| {
                    total.fetch_add(i * 10 + j, Ordering::Relaxed);
                });
            });
            let want: usize = (0..4).map(|i| (0..3).map(|j| i * 10 + j).sum::<usize>()).sum();
            assert_eq!(total.load(Ordering::Relaxed), want);
        }
    }
}
