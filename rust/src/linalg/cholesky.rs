//! f64 Cholesky factorization H = L·L^T with forward/backward solves.
//!
//! Used by LNQ (Algorithm 2, line 1) and the GPTQ/LDLQ error-feedback
//! ordering. Inputs are f32 `Mat`s (symmetric positive semi-definite Gram
//! matrices); we factorize in f64 and auto-escalate the diagonal damping
//! until the factorization succeeds, mirroring the paper's "add a small
//! constant to the diagonal" guard.

use crate::tensor::Mat;
use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct Cholesky {
    pub n: usize,
    /// Lower-triangular factor, row-major f64, dense n×n (upper part zero).
    pub l: Vec<f64>,
    /// Damping that was actually applied to the diagonal.
    pub damp: f64,
}

impl Cholesky {
    /// Factor `h` (+ damp·mean(diag)·I), escalating damp ×10 up to 8 times.
    pub fn factor(h: &Mat, base_damp: f64) -> Result<Cholesky> {
        assert_eq!(h.rows, h.cols, "cholesky needs square input");
        let n = h.rows;
        let mean_diag: f64 = (0..n).map(|i| h.at(i, i) as f64).sum::<f64>() / n.max(1) as f64;
        let scale = if mean_diag > 0.0 { mean_diag } else { 1.0 };
        let mut damp = base_damp;
        for _ in 0..9 {
            if let Some(l) = try_factor(h, damp * scale) {
                return Ok(Cholesky { n, l, damp: damp * scale });
            }
            damp = (damp * 10.0).max(1e-12);
        }
        bail!("cholesky failed even with damping {damp:e} (n={n})")
    }

    /// Solve L·y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            let row = &self.l[i * n..i * n + i];
            for (j, lij) in row.iter().enumerate() {
                s -= lij * y[j];
            }
            y[i] = s / self.l[i * n + i];
        }
        y
    }

    /// Solve L^T·x = y (backward substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(y.len(), n);
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for j in (i + 1)..n {
                s -= self.l[j * n + i] * x[j];
            }
            x[i] = s / self.l[i * n + i];
        }
        x
    }

    /// Solve (L·L^T)·x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// The factor as an f32 Mat (lower triangular).
    pub fn l_mat(&self) -> Mat {
        let n = self.n;
        Mat::from_fn(n, n, |i, j| self.l[i * n + j] as f32)
    }

    /// log(det(H)) = 2·Σ log(L_ii). Useful diagnostics for tests.
    pub fn log_det(&self) -> f64 {
        (0..self.n).map(|i| self.l[i * self.n + i].ln()).sum::<f64>() * 2.0
    }
}

fn try_factor(h: &Mat, damp: f64) -> Option<Vec<f64>> {
    let n = h.rows;
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = h.at(i, j) as f64;
            if i == j {
                s += damp;
            }
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_tn;
    use crate::testing;
    use crate::util::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        // X^T X with more rows than cols is SPD almost surely.
        let x = Mat::randn(n + 8, n, 1.0, rng);
        matmul_tn(&x, &x)
    }

    #[test]
    fn factor_reconstructs_spd() {
        testing::check("cholesky-reconstruct", 15, |rng| {
            let n = 2 + rng.below(24);
            let h = random_spd(n, rng);
            let ch = Cholesky::factor(&h, 1e-10).map_err(|e| e.to_string())?;
            // L L^T ≈ H
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0f64;
                    for k in 0..n {
                        s += ch.l[i * n + k] * ch.l[j * n + k];
                    }
                    let want = h.at(i, j) as f64;
                    let tol = 1e-3 * (1.0 + want.abs());
                    testing::ensure(
                        (s - want).abs() < tol,
                        format!("({i},{j}): {s} vs {want}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_matches_known_system() {
        // H = [[4,2],[2,3]], b = [2, 5] -> x = [-0.5, 2]
        let h = Mat::from_vec(2, 2, vec![4.0, 2.0, 2.0, 3.0]);
        let ch = Cholesky::factor(&h, 0.0).unwrap();
        let x = ch.solve(&[2.0, 5.0]);
        assert!((x[0] + 0.5).abs() < 1e-9, "{x:?}");
        assert!((x[1] - 2.0).abs() < 1e-9, "{x:?}");
    }

    #[test]
    fn solve_inverts_multiplication_property() {
        testing::check("cholesky-solve", 15, |rng| {
            let n = 1 + rng.below(30);
            let h = random_spd(n, rng);
            let ch = Cholesky::factor(&h, 1e-10).map_err(|e| e.to_string())?;
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            // b = H x
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| h.at(i, j) as f64 * x_true[j]).sum())
                .collect();
            let x = ch.solve(&b);
            for i in 0..n {
                testing::ensure(
                    (x[i] - x_true[i]).abs() < 1e-3 * (1.0 + x_true[i].abs()),
                    format!("x[{i}] {} vs {}", x[i], x_true[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn damping_escalates_for_singular_input() {
        // Rank-1 matrix: plain Cholesky fails, damped must succeed.
        let h = Mat::from_fn(6, 6, |i, j| ((i + 1) * (j + 1)) as f32);
        let ch = Cholesky::factor(&h, 1e-7).unwrap();
        assert!(ch.damp > 0.0);
    }

    #[test]
    fn rejects_nan() {
        let mut h = Mat::eye(3);
        h.data[4] = f32::NAN;
        assert!(Cholesky::factor(&h, 1e-7).is_err());
    }
}
