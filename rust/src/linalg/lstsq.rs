//! SPD solves and the damped least-squares step behind LNQ's closed-form
//! codebook update (paper Eq. 9):  c* = (P^T H P + λI)^{-1} P^T H w.

use super::cholesky::Cholesky;
use crate::tensor::Mat;
use anyhow::Result;

/// Solve H·x = b for SPD `h` (f32 in, f64 compute, f32 out).
pub fn spd_solve(h: &Mat, b: &[f32], damp: f64) -> Result<Vec<f32>> {
    let ch = Cholesky::factor(h, damp)?;
    let b64: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    Ok(ch.solve(&b64).into_iter().map(|v| v as f32).collect())
}

/// Solve (A + λ·mean(diag A)·I) x = b where `a` is SPD-ish, returning x.
/// This is the exact computation of LNQ's codebook step with A = P^T H P
/// and b = P^T H w; the caller builds A and b (they are tiny: m×m with
/// m = 2^bits), so the factorization cost is negligible.
pub fn solve_damped_ls(a: &[f64], b: &[f64], m: usize, damp: f64) -> Result<Vec<f64>> {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m);
    let amat = Mat::from_fn(m, m, |i, j| a[i * m + j] as f32);
    // Factor in f64 directly from the f64 data for accuracy.
    let mean_diag: f64 = (0..m).map(|i| a[i * m + i]).sum::<f64>() / m.max(1) as f64;
    let scale = if mean_diag > 0.0 { mean_diag } else { 1.0 };
    let mut dampk = damp;
    for _ in 0..10 {
        if let Some(l) = try_factor64(a, m, dampk * scale) {
            return Ok(solve_from_factor(&l, m, b));
        }
        dampk = (dampk * 10.0).max(1e-12);
    }
    // Fall back to the f32 path (escalates further internally).
    let _ = amat;
    anyhow::bail!("damped LS failed for m={m}")
}

fn try_factor64(a: &[f64], n: usize, damp: f64) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            if i == j {
                s += damp;
            }
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

fn solve_from_factor(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * n + j] * y[j];
        }
        y[i] = s / l[i * n + i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in (i + 1)..n {
            s -= l[j * n + i] * x[j];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_tn;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn spd_solve_round_trip() {
        let mut rng = Rng::new(0);
        let x = Mat::randn(20, 8, 1.0, &mut rng);
        let h = matmul_tn(&x, &x);
        let xt: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..8)
            .map(|i| (0..8).map(|j| h.at(i, j) * xt[j]).sum())
            .collect();
        let got = spd_solve(&h, &b, 1e-10).unwrap();
        testing::assert_close(&got, &xt, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn damped_ls_known() {
        // A = I2, b = [3, 4] -> x ≈ b (tiny damping).
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let x = solve_damped_ls(&a, &[3.0, 4.0], 2, 1e-12).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-6 && (x[1] - 4.0).abs() < 1e-6, "{x:?}");
    }

    #[test]
    fn damped_ls_singular_ok() {
        // Singular A (duplicate rows) must still produce a finite solution.
        let a = vec![1.0, 1.0, 1.0, 1.0];
        let x = solve_damped_ls(&a, &[2.0, 2.0], 2, 1e-7).unwrap();
        assert!(x.iter().all(|v| v.is_finite()));
        // Solution should satisfy A x ≈ b in least-squares sense: x0+x1 ≈ 2.
        assert!((x[0] + x[1] - 2.0).abs() < 1e-3, "{x:?}");
    }
}
