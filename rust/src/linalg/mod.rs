//! Dense linear algebra for the quantization solvers: Cholesky, triangular
//! solves, SPD solves and damped least squares. Factorizations run in f64
//! for stability (the paper's LNQ codebook step inverts P^T·H·P which is
//! often near-singular; we add λ=1e-7 damping exactly as §4.2 prescribes).

pub mod cholesky;
pub mod lstsq;

pub use cholesky::Cholesky;
pub use lstsq::{solve_damped_ls, spd_solve};

/// Default diagonal damping from the paper (§4.2).
pub const DEFAULT_DAMP: f64 = 1e-7;
