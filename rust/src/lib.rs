//! # GuidedQuant — Rust coordinator (L3)
//!
//! Reproduction of *GuidedQuant: Large Language Model Quantization via
//! Exploiting End Loss Guidance* (ICML 2025) as a three-layer
//! Rust + JAX + Pallas system. This crate is the runtime: it loads the
//! AOT-compiled HLO artifacts produced by `python/compile/aot.py` (PJRT CPU
//! via the `xla` crate), drives training + calibration, runs every
//! quantization algorithm natively, and serves the quantized model.
//! Python never executes on the request path.
//!
//! Module map (see DESIGN.md for the full inventory):
//! * substrates: [`util`], [`testing`], [`cli`], [`cfg`], [`tensor`],
//!   [`linalg`], [`data`], [`model`]
//! * runtime: [`runtime`] (PJRT artifact registry), [`fisher`] (calibration
//!   statistics + Hessian cache)
//! * the paper: [`quant`] (GuidedQuant, LNQ, CD, GPTQ, SqueezeLLM, GPTVQ,
//!   VQ, trellis/QTIP, SpinQuant-style rotations, dense-and-sparse, formats)
//! * system: [`coordinator`] (pipeline phases + worker pool), [`serve`]
//!   (batched decode engine), [`eval`] (perplexity + tasks), [`report`],
//!   [`bench`]

pub mod bench;
pub mod cfg;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fisher;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};

/// The unit-test harness runs under a counting allocator so the
/// zero-allocation steady-state guarantees of the serve token loop are
/// enforced by tests, not just claimed (see `testing::alloc_count`).
#[cfg(test)]
#[global_allocator]
static TEST_ALLOC: testing::alloc_count::CountingAllocator =
    testing::alloc_count::CountingAllocator;
