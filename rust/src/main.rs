//! `gq` — the GuidedQuant launcher (L3 coordinator CLI).
//!
//! Subcommands:
//!   pipeline  end-to-end: train → calib → quantize → eval (+ report)
//!   train     train a model via the train_step artifact, save checkpoint
//!   quantize  quantize a checkpoint with any method/bits/groups
//!   eval      perplexity of a checkpoint through the fwd artifacts
//!   serve     batched generation benchmark — or, with --http, an HTTP
//!             serving front-end — over a quantized serving format
//!   fisher    export Fisher-structure data (Figures 3/4) as CSV matrices
//!   info      print model/artifact/manifest information
//!
//! Examples:
//!   gq pipeline --model small --method lnq --bits 2 --groups 4
//!   gq serve --model tiny --format nonuniform --bits 4 --requests 8
//!   gq serve --model tiny --format nonuniform --bits 4 --http 127.0.0.1:8080
//!   gq info --model small

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use guidedquant::cfg::{
    preset, KvDtype, PipelineConfig, PRESET_NAMES, QuantConfig, QuantMethod, RestartPolicy,
    TomlDoc,
};
use guidedquant::cli::Args;
use guidedquant::coordinator::Pipeline;
use guidedquant::data::Split;
use guidedquant::model::ParamStore;
use guidedquant::serve::{
    build_serving_set, generate_per_sequence, generate_scheduled_streaming, HttpServer,
    ServeFormat,
};

const USAGE: &str = "usage: gq <pipeline|train|quantize|eval|serve|fisher|info> [flags]
  common flags: --model tiny|small|base  --artifacts DIR  --out DIR --config FILE
  quant flags:  --method rtn|gptq|squeezellm|gptvq1d|gptvq2d|lnq|trellis
                --bits N --groups G --sparse-frac F --seed S
  pipeline:     --train-steps N --calib-batches N --eval-batches N --workers N
  serve:        --format fp32|uniform|nonuniform|vector|trellis|anyprec
                --requests N --gen-tokens N --prompt-len N
                --max-batch N --max-queued N
                --kv-dtype f32|f16 (f16 halves KV cache bytes; greedy
                tokens are validated ULP-close to f32, not bit-equal)
                --http ADDR (HTTP front-end: POST /v1/completions,
                GET /v1/capabilities, GET /metrics, GET /healthz —
                instead of the stdout benchmark; port 0 picks a free
                port, e.g. 127.0.0.1:0)
                --precision N (default decode precision; 0 = native.
                anyprec serves every precision 2..=bits from ONE stored
                bit-plane artifact; requests pick theirs per call with
                the body's "precision" field)
                --precision-floor N (load-adaptive downshift: above the
                KV low watermark, admissions that did not pin a
                precision decode at this floor before any brownout or
                429; 0 = off)
                --per-seq (thread-per-sequence baseline instead of the
                continuous-batching scheduler)
                --scalar-prefill (per-lane scalar prefill instead of
                chunked batched prefill)
                --stream (print tokens per request as each engine step
                generates them instead of waiting for completion)
                --request-timeout MS (default deadline per request;
                0 = none; a request's own timeout_ms overrides)
                --queue-timeout MS (max admission wait before a queued
                request fails with finish_reason timeout; 0 = none)
                --restart-policy fail-fast|requeue (what happens to
                in-flight requests when an engine fault forces a
                scheduler restart)
                --max-engine-restarts N (restart budget before the
                engine is declared dead and /healthz turns 503)
                --kv-budget-mb MB (KV memory governance budget; 0 = off.
                Admission is cost-aware under the budget: cached prefix
                pages shed first, brownout above the low watermark,
                preempt-youngest above the high one, 429 with a computed
                Retry-After as the last resort)
                --prefix-cache on|off (copy-on-write prefix-sharing KV
                cache: finished lanes donate page-aligned prompt prefixes
                and later requests skip prefill over cached positions;
                greedy tokens are bit-identical either way. Default on)
  env:          GQ_THREADS=N caps the shared worker pool (1 = serial)
  train:        --steps N --save FILE
  eval/quantize: --load FILE [--save FILE] --artifact fwd_loss|fwd_loss_qa4kv4|...";

fn pipeline_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => PipelineConfig::from_toml(&TomlDoc::load(path)?)?,
        None => PipelineConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    cfg.artifacts_dir = args.get_or("artifacts", &cfg.artifacts_dir).to_string();
    cfg.out_dir = args.get_or("out", &cfg.out_dir).to_string();
    cfg.train_steps = args.get_usize("train-steps", cfg.train_steps)?;
    cfg.calib_batches = args.get_usize("calib-batches", cfg.calib_batches)?;
    cfg.eval_batches = args.get_usize("eval-batches", cfg.eval_batches)?;
    cfg.workers = args.get_usize("workers", cfg.workers)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.serve.max_batch = args.get_usize_at_least("max-batch", cfg.serve.max_batch, 1)?;
    cfg.serve.max_queued = args.get_usize_at_least("max-queued", cfg.serve.max_queued, 1)?;
    if args.has("workers") {
        // An explicit --workers drives the serve engine too.
        cfg.serve.workers = cfg.workers;
    }
    if args.switch("scalar-prefill") {
        cfg.serve.scalar_prefill = true;
    }
    if let Some(v) = args.get("kv-dtype") {
        cfg.serve.kv_dtype = KvDtype::parse(v)?;
    }
    cfg.serve.request_timeout_ms = args.get_u64("request-timeout", cfg.serve.request_timeout_ms)?;
    cfg.serve.queue_timeout_ms = args.get_u64("queue-timeout", cfg.serve.queue_timeout_ms)?;
    if let Some(v) = args.get("restart-policy") {
        cfg.serve.restart_policy = RestartPolicy::parse(v)?;
    }
    cfg.serve.max_engine_restarts =
        args.get_usize("max-engine-restarts", cfg.serve.max_engine_restarts)?;
    if args.has("kv-budget-mb") {
        cfg.serve.kv_budget_bytes = args.get_usize("kv-budget-mb", 0)? * 1024 * 1024;
    }
    if let Some(v) = args.get("prefix-cache") {
        cfg.serve.prefix_cache = match v {
            "on" => true,
            "off" => false,
            other => bail!("--prefix-cache expects on|off, got `{other}`"),
        };
    }
    cfg.serve.default_precision =
        args.get_usize("precision", cfg.serve.default_precision as usize)? as u8;
    cfg.serve.precision_floor =
        args.get_usize("precision-floor", cfg.serve.precision_floor as usize)? as u8;
    if cfg.serve.precision_floor != 0
        && cfg.serve.default_precision != 0
        && cfg.serve.precision_floor > cfg.serve.default_precision
    {
        bail!(
            "--precision-floor {} exceeds the default --precision {}",
            cfg.serve.precision_floor,
            cfg.serve.default_precision
        );
    }
    cfg.quant = quant_config(args, cfg.quant)?;
    Ok(cfg)
}

fn quant_config(args: &Args, mut q: QuantConfig) -> Result<QuantConfig> {
    if let Some(m) = args.get("method") {
        q.method = QuantMethod::parse(m)?;
    }
    q.bits = args.get_usize("bits", q.bits as usize)? as u32;
    q.groups = args.get_usize("groups", q.groups)?;
    q.sparse_frac = args.get_f64("sparse-frac", q.sparse_frac as f64)? as f32;
    q.seed = args.get_u64("seed", q.seed)?;
    Ok(q)
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        eprintln!("{USAGE}");
        bail!("missing subcommand");
    };
    match cmd {
        "pipeline" => cmd_pipeline(&args),
        "train" => cmd_train(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "fisher" => cmd_fisher(&args),
        "info" => cmd_info(&args),
        other => {
            eprintln!("{USAGE}");
            bail!("unknown subcommand `{other}`")
        }
    }
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let cfg = pipeline_config(args)?;
    println!(
        "pipeline: model={} method={} bits={} groups={} steps={}",
        cfg.model,
        cfg.quant.method.name(),
        cfg.quant.bits,
        cfg.quant.groups,
        cfg.train_steps
    );
    let pipeline = Pipeline::new(cfg)?;
    let report = pipeline.run()?;
    report.print();
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = pipeline_config(args)?;
    let steps = args.get_usize("steps", cfg.train_steps)?;
    let pipeline = Pipeline::new(cfg)?;
    let mut ps = pipeline.init_params();
    let losses = pipeline.train(&mut ps, steps, (steps / 20).max(1))?;
    if let Some(path) = args.get("save") {
        ps.save(path)?;
        println!("saved checkpoint to {path}");
    }
    println!(
        "trained {} steps: loss {:.4} -> {:.4}",
        losses.len(),
        losses.first().copied().unwrap_or(0.0),
        losses.last().copied().unwrap_or(0.0)
    );
    Ok(())
}

/// Load `--load FILE`, or fresh-init from the preset via the canonical
/// `ParamStore::init_seeded` derivation — one code path shared by every
/// subcommand that materializes params, artifact-backed or not.
fn load_or_init(model: &str, seed: u64, args: &Args) -> Result<ParamStore> {
    let (model_cfg, _) = preset(model);
    match args.get("load") {
        Some(path) => ParamStore::load(&model_cfg, path)
            .with_context(|| format!("loading checkpoint {path}")),
        None => Ok(ParamStore::init_seeded(&model_cfg, seed)),
    }
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let cfg = pipeline_config(args)?;
    let pipeline = Pipeline::new(cfg)?;
    let ps = load_or_init(&pipeline.cfg.model, pipeline.cfg.seed, args)?;
    let stats = pipeline.calib(&ps, args.switch("recalib"))?;
    let layers = pipeline.quantize(&ps, &stats, &pipeline.cfg.quant)?;
    let qps = pipeline.apply_quantized(&ps, &layers);
    println!(
        "quantized {} linears, avg bits {:.3}",
        layers.len(),
        pipeline.avg_bits(&layers)
    );
    if let Some(path) = args.get("save") {
        qps.save(path)?;
        println!("saved quantized checkpoint to {path}");
    }
    let ppl = pipeline.perplexity(&qps, Split::Eval, "fwd_loss")?;
    println!("quantized ppl (eval split): {ppl:.3}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = pipeline_config(args)?;
    let pipeline = Pipeline::new(cfg)?;
    let ps = load_or_init(&pipeline.cfg.model, pipeline.cfg.seed, args)?;
    let artifact = args.get_or("artifact", "fwd_loss");
    let eval = pipeline.perplexity(&ps, Split::Eval, artifact)?;
    let shift = pipeline.perplexity(&ps, Split::EvalShift, artifact)?;
    println!("ppl[{artifact}]  eval {eval:.3}  shift {shift:.3}");
    Ok(())
}

/// Flags `gq serve` accepts: the shared pipeline/config/quant flags its
/// config loader reads, plus the serve-specific knobs. Anything else is a
/// usage error instead of a silently ignored typo.
const SERVE_FLAGS: &str = "config model artifacts out train-steps calib-batches eval-batches \
    workers seed max-batch max-queued scalar-prefill kv-dtype method bits groups sparse-frac \
    format requests gen-tokens prompt-len per-seq stream http load request-timeout \
    queue-timeout restart-policy max-engine-restarts kv-budget-mb prefix-cache \
    precision precision-floor";

fn cmd_serve(args: &Args) -> Result<()> {
    let allowed: Vec<&str> = SERVE_FLAGS.split_whitespace().collect();
    args.ensure_known("gq serve", &allowed)?;
    let cfg = pipeline_config(args)?;
    let format = ServeFormat::parse(args.get_or("format", "nonuniform"))?;
    let bits = args.get_usize("bits", 4)? as u32;
    let requests = args.get_usize("requests", 4)?;
    let gen_tokens = args.get_usize("gen-tokens", 32)?;
    let prompt_len = args.get_usize("prompt-len", 16)?;
    // --http (or [serve] http in the config file) switches from the stdout
    // benchmark to the network front-end. A bare `--http` (no address, or
    // followed by another --flag) parses as a switch — error out BEFORE the
    // expensive model build rather than silently running the benchmark
    // mode the user didn't ask for.
    if args.switch("http") {
        bail!("--http needs an address, e.g. --http 127.0.0.1:8080 (port 0 picks a free port)");
    }
    let http_addr = args.get("http").map(str::to_string).or_else(|| cfg.serve.http_addr.clone());
    if http_addr.is_some() {
        // Benchmark-mode flags do nothing under --http; reject them so the
        // user isn't left believing they took effect.
        for flag in ["per-seq", "stream", "requests", "gen-tokens", "prompt-len"] {
            if args.has(flag) {
                bail!("--{flag} is benchmark-mode only and has no effect with --http");
            }
        }
    }
    // The serving model is built straight from the preset (the canonical
    // ParamStore::init_seeded derivation shared with Pipeline::init_params),
    // not through the artifact runtime: serving never executes Python-side
    // artifacts, and the HTTP front-end — plus CI's serve-e2e job — must
    // boot from a bare checkout.
    if !PRESET_NAMES.contains(&cfg.model.as_str()) {
        bail!("unknown model preset `{}` (expected one of {PRESET_NAMES:?})", cfg.model);
    }
    let ps = load_or_init(&cfg.model, cfg.seed, args)?;
    println!("building {} serving model at {bits} bits ...", format.name());
    let set = Arc::new(build_serving_set(&ps, None, format, bits)?);

    if let Some(addr) = http_addr {
        let precisions = set.precisions();
        let default_prec = set.resolve(cfg.serve.default_precision)?;
        let server = HttpServer::bind(set, cfg.serve.clone(), &addr)?;
        println!("http: listening on {}", server.local_addr());
        println!(
            "http: format={} precisions={:?} default={} floor={}",
            format.name(),
            precisions,
            default_prec,
            cfg.serve.precision_floor
        );
        println!(
            "http: POST /v1/completions | GET /v1/capabilities | GET /metrics | GET /healthz (Ctrl-C stops)"
        );
        server.join();
        return Ok(());
    }

    // Benchmark mode measures the native (highest-precision) entry.
    let model = set.native_model();
    let prompts = guidedquant::serve::random_prompts(model.cfg.vocab, requests, prompt_len, 7);
    let stream = args.switch("stream");
    let (_, stats) = if args.switch("per-seq") {
        generate_per_sequence(model, &prompts, gen_tokens, cfg.workers)?
    } else {
        generate_scheduled_streaming(
            model,
            &prompts,
            gen_tokens,
            cfg.workers,
            cfg.serve.clone(),
            |id, tok| {
                if stream {
                    println!("stream req={id} token={tok}");
                }
            },
        )?
    };
    println!(
        "format={} bits={} requests={requests} gen={gen_tokens}: {:.1} tok/s  p50 {:.2} ms  p99 {:.2} ms  ttft_p50 {:.2} ms  queue {:.2} ms  batch {:.1}  weights {}",
        format.name(),
        bits,
        stats.tok_per_sec,
        stats.p50_ms,
        stats.p99_ms,
        stats.ttft_p50_ms,
        stats.queue_wait_ms,
        stats.batch_occupancy,
        guidedquant::util::human_bytes(stats.weight_bytes as u64)
    );
    Ok(())
}

/// Export exact two-channel Fisher submatrices + approximations as dense
/// CSV matrices (external plotting of Figures 3/4). One file per linear of
/// the first block, under --out (default target/fisher).
fn cmd_fisher(args: &Args) -> Result<()> {
    use guidedquant::data::{Batcher, Split};
    use guidedquant::fisher::structure as fs;
    use guidedquant::runtime::Value;

    let cfg = pipeline_config(args)?;
    let out_dir = std::path::PathBuf::from(args.get_or("fisher-out", "target/fisher"));
    std::fs::create_dir_all(&out_dir)?;
    let pipeline = Pipeline::new(cfg)?;
    let ps = load_or_init(&pipeline.cfg.model, pipeline.cfg.seed, args)?;
    let rt = &pipeline.rt;
    let bc = rt.manifest.batch;
    let mut batcher = Batcher::new(&pipeline.corpus, Split::Calib, bc, 1);
    let toks = batcher.next_batch().context("no calibration batch")?;
    let mut a = rt.param_args(&ps);
    a.push(Value::tokens(bc.batch, bc.seq, &toks));
    let outs = rt.artifact("grad_taps")?.execute(&a)?;

    let write_mat = |path: &std::path::Path, m: &guidedquant::tensor::Mat| -> Result<()> {
        let mut text = String::new();
        for i in 0..m.rows {
            let row: Vec<String> = m.row(i).iter().map(|v| format!("{v:.6e}")).collect();
            text.push_str(&row.join(","));
            text.push('\n');
        }
        std::fs::write(path, text)?;
        Ok(())
    };

    for (li, spec) in ps.cfg.linear_specs().iter().take(7).enumerate() {
        let x = outs[1 + 2 * li].clone().into_mat()?;
        let g = outs[2 + 2 * li].clone().into_mat()?;
        let fisher = fs::two_channel_fisher(&x, &g, 0, 1);
        let wf = fs::block_diag_approx(&fisher, spec.d_in / 2);
        let gq = fs::guided_approx_two_channel(&fisher);
        let base = spec.name.replace('.', "_");
        write_mat(&out_dir.join(format!("{base}_exact.csv")), &fisher)?;
        write_mat(&out_dir.join(format!("{base}_woodfisher.csv")), &wf)?;
        write_mat(&out_dir.join(format!("{base}_guidedquant.csv")), &gq)?;
        println!(
            "{}: block mass {:.3}, err WF {:.4}, err GQ {:.4} -> {}/",
            spec.name,
            fs::block_mass_fraction(&fisher, spec.d_in),
            fs::rel_error(&fisher, &wf),
            fs::rel_error(&fisher, &gq),
            out_dir.display()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = pipeline_config(args)?;
    let pipeline = Pipeline::new(cfg)?;
    let m = &pipeline.rt.manifest;
    println!(
        "model {} (vocab {}, d_model {}, layers {}, heads {}, d_ff {})",
        m.model.name, m.model.vocab, m.model.d_model, m.model.n_layers, m.model.n_heads, m.model.d_ff
    );
    let (model_cfg, bc) = preset(&m.model.name);
    println!(
        "params: {} ({} quantizable linear weights)",
        guidedquant::util::human_count(model_cfg.n_params() as u64),
        guidedquant::util::human_count(model_cfg.n_linear_params() as u64)
    );
    println!("batch {}x{}, calib groups g={}", bc.batch, bc.seq, m.groups);
    println!("artifacts:");
    for a in &m.artifacts {
        println!("  {} ({} inputs, {} outputs)", a.name, a.inputs.len(), a.outputs.len());
    }
    Ok(())
}
