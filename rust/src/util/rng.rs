//! Deterministic PRNG: SplitMix64 seeding + Xoshiro256** core.
//!
//! Every stochastic component in the crate (data generation, k-means++
//! seeding, rotation search, property tests) draws from this generator so
//! that runs are reproducible from a single `u64` seed.

/// Xoshiro256** PRNG (public-domain reference algorithm by Blackman/Vigna),
/// seeded via SplitMix64 so any `u64` seed gives a well-mixed state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53).
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Sample an index proportionally to non-negative `weights`.
    /// Falls back to uniform if all weights are zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..2_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..8_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((2.0..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
