//! Minimal leveled logger (no `log` crate offline). Controlled by the
//! `GQ_LOG` env var (`debug` | `info` | `warn` | `quiet`; default `info`).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Quiet = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != 255 {
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let lvl = match std::env::var("GQ_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("quiet") => Level::Quiet,
        _ => Level::Info,
    };
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

/// Override the log level programmatically (tests, benches).
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl >= level()
}

pub fn log(lvl: Level, tag: &str, msg: std::fmt::Arguments) {
    if enabled(lvl) {
        eprintln!("[gq:{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! log_debug {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $tag, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $tag, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $tag, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Quiet);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
