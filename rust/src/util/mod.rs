//! Small shared substrates: PRNG, timing, stats, logging, formatting, JSON.
//!
//! The offline environment has no `rand`/`log`/`humantime` crates, so these
//! are built in-repo (DESIGN.md §1, offline constraints table).

pub mod fault;
pub mod fmt;
pub mod half;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use fmt::{human_bytes, human_count, human_duration};
pub use rng::Rng;
pub use stats::{mean, percentile, stddev};
pub use timer::Timer;
