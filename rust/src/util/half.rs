//! Software IEEE 754 binary16 ("f16") codec — bit-twiddling converts with
//! no external crates (the offline environment has no `half`).
//!
//! The serving engine stores cold data (KV cache pages, opt-in quantized
//! code tables) as `u16` half floats to halve memory traffic, widening on
//! read. Two properties the callers rely on:
//!
//! * **Widening is exact**: every f16 value is representable in f32, so
//!   [`f16_to_f32`] never rounds. Kernels that only *read* f16 data are
//!   therefore bit-identical across scalar/SIMD paths.
//! * **Narrowing rounds to nearest, ties to even** ([`f32_to_f16`]) — the
//!   IEEE default — including gradual underflow to subnormals. Values past
//!   ±65504 (f16 max) round to ±inf; NaNs stay NaNs.

/// Exact widening conversion (f16 ⊂ f32: never rounds).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: value = man · 2^-24. Renormalize into f32.
            let mut e: u32 = 113; // f32 biased exponent of 2^-14
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // ±inf / NaN (payload widened)
    } else {
        // Normal: rebias 15 -> 127.
        sign | ((exp as u32 + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Narrowing conversion with round-to-nearest-even (IEEE default mode).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays inf; NaN keeps a non-zero mantissa (quiet bit forced so
        // a payload living entirely in the dropped bits cannot turn a NaN
        // into inf).
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 | ((man >> 13) as u16 & 0x01ff) };
    }
    let e = exp - 127; // unbiased
    if e >= 16 {
        return sign | 0x7c00; // above f16 range: round to inf
    }
    if e < -25 {
        return sign; // below half the smallest subnormal: rounds to ±0
    }
    let man = man | 0x0080_0000; // implicit leading 1 (f32 subnormals hit e < -25)
    // Normals drop 13 mantissa bits; subnormals (e in [-25, -15]) drop more
    // as the value denormalizes. Ties-to-even via the shifted-out remainder;
    // the rounding carry may legitimately overflow the mantissa into the
    // exponent field (subnormal -> smallest normal, largest normal -> inf).
    let shift = if e < -14 { (13 - 14 - e) as u32 } else { 13 };
    let base = man >> shift;
    let rem = man & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut h = if e < -14 {
        base as u16 // subnormal: exponent field 0
    } else {
        (((e + 15) as u32) << 10 | (base & 0x03ff)) as u16
    };
    if rem > half || (rem == half && h & 1 == 1) {
        h += 1;
    }
    sign | h
}

/// Widen a packed f16 slice into f32 (exact, elementwise).
#[inline]
pub fn widen_slice(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f16_to_f32(s);
    }
}

/// Narrow an f32 slice into packed f16 (round-to-nearest-even, elementwise).
#[inline]
pub fn narrow_slice(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f32_to_f16(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widen_then_narrow_roundtrips_every_f16() {
        // Exhaustive: all 65536 bit patterns. Non-NaN patterns round-trip
        // exactly; NaNs stay NaNs (payloads may canonicalize).
        for h in 0..=u16::MAX {
            let f = f16_to_f32(h);
            if f.is_nan() {
                assert_eq!(h & 0x7c00, 0x7c00);
                assert_ne!(h & 0x03ff, 0);
                assert!(f16_to_f32(f32_to_f16(f)).is_nan());
            } else {
                assert_eq!(f32_to_f16(f), h, "bits {h:#06x} -> {f} did not round-trip");
            }
        }
    }

    #[test]
    fn widening_known_values() {
        assert_eq!(f16_to_f32(0x0000), 0.0);
        assert!(f16_to_f32(0x8000) == 0.0 && f16_to_f32(0x8000).is_sign_negative());
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0xc000), -2.0);
        assert_eq!(f16_to_f32(0x7bff), 65504.0); // f16 max
        assert_eq!(f16_to_f32(0x0400), 2.0f32.powi(-14)); // smallest normal
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24)); // smallest subnormal
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xfc00), f32::NEG_INFINITY);
        assert!(f16_to_f32(0x7e00).is_nan());
    }

    #[test]
    fn narrowing_rounds_to_nearest_even() {
        // 1 + 2^-11 sits exactly between 1.0 (0x3c00, even) and the next
        // f16 (0x3c01, odd): ties go to the even mantissa.
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11)), 0x3c00);
        // 1 + 3·2^-11 sits between 0x3c01 and 0x3c02: ties to even -> 0x3c02.
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3c02);
        // Just above/below the tie rounds to the nearer neighbor.
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-18)), 0x3c01);
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11) - 2.0f32.powi(-18)), 0x3c00);
    }

    #[test]
    fn narrowing_overflow_and_underflow() {
        // 65520 is the midpoint between f16 max (65504) and 2^16: ties to
        // even rounds up, i.e. to infinity; anything below stays finite.
        assert_eq!(f32_to_f16(65520.0), 0x7c00);
        assert_eq!(f32_to_f16(65519.9), 0x7bff);
        assert_eq!(f32_to_f16(1e30), 0x7c00);
        assert_eq!(f32_to_f16(-1e30), 0xfc00);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        // 2^-25 is the midpoint between 0 and the smallest subnormal: ties
        // to even rounds to 0; the next representable f32 up rounds to the
        // subnormal.
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16(2.0f32.powi(-25) + 2.0f32.powi(-48)), 0x0001);
        assert_eq!(f32_to_f16(-2.0f32.powi(-25)), 0x8000);
        // Gradual underflow: 2^-24 · 3 is exactly representable.
        assert_eq!(f32_to_f16(3.0 * 2.0f32.powi(-24)), 0x0003);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // A NaN whose payload lives entirely in the dropped low bits must
        // not collapse to infinity.
        let sneaky = f32::from_bits(0x7f80_0001);
        assert!(sneaky.is_nan());
        assert!(f16_to_f32(f32_to_f16(sneaky)).is_nan());
    }

    #[test]
    fn slice_helpers_are_elementwise() {
        let xs = [0.0f32, 1.5, -2.25, 1e-8, 70000.0];
        let mut h = [0u16; 5];
        narrow_slice(&xs, &mut h);
        let mut back = [0f32; 5];
        widen_slice(&h, &mut back);
        for (i, (&x, &b)) in xs.iter().zip(&back).enumerate() {
            assert_eq!(f16_to_f32(f32_to_f16(x)), b, "elem {i}");
        }
        assert_eq!(back[0], 0.0);
        assert_eq!(back[1], 1.5); // exactly representable
        assert_eq!(back[2], -2.25);
        assert_eq!(back[4], f32::INFINITY);
    }
}
