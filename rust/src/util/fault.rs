//! Deterministic fault injection for chaos testing the serving stack.
//!
//! `GQ_FAULT=<site>:<nth>[,<site>:<nth>...]` arms named injection sites:
//! each site fires **exactly once**, on its `nth` (1-based) hit after
//! arming, then disarms itself. Because the engine is deterministic, the
//! nth decode step / socket write is the same step on every run, so a
//! chaos scenario reproduces bit-for-bit.
//!
//! Two arming scopes:
//!
//! * **Process-global** — parsed from `GQ_FAULT` at the first hit, or armed
//!   programmatically via [`arm_global`]. Reaches every thread (the engine
//!   thread, connection threads); used by `scripts/serve_chaos.sh` and the
//!   HTTP-level chaos integration tests (which serialize on a lock — the
//!   registry is shared process state).
//! * **Thread-local** — [`arm`] affects only the calling thread, so unit
//!   tests that drive a [`crate::serve::Scheduler`] or
//!   [`crate::serve::SupervisedEngine`] on the test thread can inject
//!   faults without perturbing other tests running in parallel.
//!
//! When nothing is armed, a hit is two relaxed atomic loads plus an empty
//! thread-local map probe — no locks, no allocation — so injection points
//! can sit on the zero-allocation steady-state decode path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Panic at the top of the batched decode step (`Scheduler::decode_phase`).
pub const STEP_PANIC: &str = "step-panic";
/// Panic at the top of the admission phase, while freshly admitted
/// requests are mid-prefill (`Scheduler::admit_phase`).
pub const PREFILL_PANIC: &str = "prefill-panic";
/// Overwrite lane 0's logits with NaN after the batched step — the
/// degenerate-output fault class (overflowed accumulation, corrupt codes).
pub const NAN_LOGITS: &str = "nan-logits";
/// Sleep inside the decode step: a transient engine stall, not a fault the
/// supervisor acts on — the server must simply absorb the latency spike.
pub const ENGINE_STALL: &str = "engine-stall";
/// Sleep before one SSE chunk write: slow/partial socket I/O on the
/// connection thread.
pub const SLOW_WRITE: &str = "slow-write";
/// Report the KV arena as exhausted at one admission-time budget check:
/// the request is refused with the kv-budget 429 even though pages are
/// actually available — the out-of-memory fault class without the OOM.
pub const KV_EXHAUST: &str = "kv-exhaust";
/// Sleep while reading one request body: a slow-upload (slowloris-style)
/// client stalling its connection thread mid-read.
pub const SLOW_READ: &str = "slow-read";
/// Force-clear the shared-prefix index at the top of one decode step,
/// dropping every cached page while dependent lanes are mid-decode — the
/// eviction-race fault class. Lanes must keep decoding bit-identically
/// (they hold their own refs on borrowed pages).
pub const PREFIX_EVICT: &str = "prefix-evict";

/// Every site name `GQ_FAULT` accepts.
pub const SITES: &[&str] = &[
    STEP_PANIC,
    PREFILL_PANIC,
    NAN_LOGITS,
    ENGINE_STALL,
    SLOW_WRITE,
    KV_EXHAUST,
    SLOW_READ,
    PREFIX_EVICT,
];

struct Site {
    nth: u64,
    hits: u64,
    fired: bool,
}

impl Site {
    /// Count a hit; true exactly when `hits` reaches `nth` the first time.
    fn hit(&mut self) -> bool {
        if self.fired {
            return false;
        }
        self.hits += 1;
        if self.hits >= self.nth {
            self.fired = true;
            return true;
        }
        false
    }
}

/// Fast-path gate: false until the global registry holds at least one
/// armed site (set on env parse or [`arm_global`], never cleared by
/// firing — a fired site just stops matching).
static GLOBAL_ARMED: AtomicBool = AtomicBool::new(false);
/// Whether the `GQ_FAULT` env var has been parsed into the registry yet.
static INITIALIZED: AtomicBool = AtomicBool::new(false);

static REGISTRY: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();

thread_local! {
    static LOCAL: RefCell<HashMap<&'static str, Site>> = RefCell::new(HashMap::new());
}

fn parse_one(part: &str) -> Result<(String, u64), String> {
    let (name, nth) = part
        .split_once(':')
        .ok_or_else(|| format!("expected <site>:<nth>, got `{part}`"))?;
    let name = name.trim();
    let nth: u64 = nth.trim().parse().map_err(|_| format!("bad nth in `{part}`"))?;
    if nth == 0 {
        return Err(format!("nth must be >= 1 in `{part}`"));
    }
    if !SITES.contains(&name) {
        return Err(format!("unknown fault site `{name}` (known: {SITES:?})"));
    }
    Ok((name.to_string(), nth))
}

fn registry() -> &'static Mutex<HashMap<String, Site>> {
    let reg = REGISTRY.get_or_init(|| Mutex::new(HashMap::new()));
    if !INITIALIZED.load(Ordering::Acquire) {
        let mut sites = reg.lock().unwrap();
        if !INITIALIZED.load(Ordering::Acquire) {
            if let Ok(spec) = std::env::var("GQ_FAULT") {
                for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
                    match parse_one(part) {
                        Ok((name, nth)) => {
                            crate::log_info!("fault", "armed `{name}` to fire on hit {nth}");
                            sites.insert(name, Site { nth, hits: 0, fired: false });
                        }
                        Err(e) => crate::log_warn!("fault", "ignoring GQ_FAULT entry: {e}"),
                    }
                }
            }
            if !sites.is_empty() {
                GLOBAL_ARMED.store(true, Ordering::Release);
            }
            INITIALIZED.store(true, Ordering::Release);
        }
    }
    reg
}

/// Count one hit of `site`; true exactly when an armed counter (thread-local
/// first, then process-global) reaches its `nth`. Near-free when disarmed.
pub fn hit(site: &str) -> bool {
    let local = LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        if l.is_empty() {
            return false;
        }
        l.get_mut(site).map(Site::hit).unwrap_or(false)
    });
    if local {
        return true;
    }
    if INITIALIZED.load(Ordering::Acquire) && !GLOBAL_ARMED.load(Ordering::Relaxed) {
        return false;
    }
    let mut reg = registry().lock().unwrap();
    reg.get_mut(site).map(Site::hit).unwrap_or(false)
}

/// Panic with an identifiable payload when `site` fires.
pub fn maybe_panic(site: &str) {
    if hit(site) {
        panic!("injected fault: {site}");
    }
}

/// Sleep `d` when `site` fires (stall/slow-I/O injection).
pub fn maybe_stall(site: &str, d: Duration) {
    if hit(site) {
        crate::log_warn!("fault", "injected stall at `{site}` for {d:?}");
        std::thread::sleep(d);
    }
}

/// Arm `site` **for the calling thread only**: fires once, on the `nth`
/// subsequent [`hit`] from this thread. Safe under parallel test execution.
pub fn arm(site: &'static str, nth: u64) {
    assert!(nth >= 1, "nth is 1-based");
    LOCAL.with(|l| {
        l.borrow_mut().insert(site, Site { nth, hits: 0, fired: false });
    });
}

/// Clear every thread-local arming on the calling thread.
pub fn disarm_all() {
    LOCAL.with(|l| l.borrow_mut().clear());
}

/// Arm `site` **process-wide** (reaches the engine/connection threads).
/// Counts from zero at arming. Callers that share a process (integration
/// tests) must serialize chaos scenarios around this.
pub fn arm_global(site: &str, nth: u64) {
    assert!(nth >= 1, "nth is 1-based");
    let mut reg = registry().lock().unwrap();
    reg.insert(site.to_string(), Site { nth, hits: 0, fired: false });
    GLOBAL_ARMED.store(true, Ordering::Release);
}

/// Clear every process-global arming (env-parsed and [`arm_global`]).
pub fn disarm_all_global() {
    let mut reg = registry().lock().unwrap();
    reg.clear();
    GLOBAL_ARMED.store(false, Ordering::Release);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_sites_and_rejects_garbage() {
        assert_eq!(parse_one("step-panic:3").unwrap(), ("step-panic".to_string(), 3));
        assert_eq!(parse_one(" nan-logits : 1 ").unwrap(), ("nan-logits".to_string(), 1));
        assert!(parse_one("step-panic").is_err(), "missing nth");
        assert!(parse_one("step-panic:0").is_err(), "nth is 1-based");
        assert!(parse_one("step-panic:x").is_err(), "non-numeric nth");
        assert!(parse_one("frobnicate:2").is_err(), "unknown site");
        assert_eq!(parse_one("kv-exhaust:1").unwrap(), ("kv-exhaust".to_string(), 1));
        assert_eq!(parse_one("slow-read:2").unwrap(), ("slow-read".to_string(), 2));
        assert_eq!(parse_one("prefix-evict:1").unwrap(), ("prefix-evict".to_string(), 1));
    }

    #[test]
    fn thread_local_arm_fires_exactly_once_on_nth_hit() {
        disarm_all();
        arm(STEP_PANIC, 3);
        assert!(!hit(STEP_PANIC));
        assert!(!hit(STEP_PANIC));
        assert!(hit(STEP_PANIC), "third hit must fire");
        assert!(!hit(STEP_PANIC), "a fired site stays quiet");
        assert!(!hit(NAN_LOGITS), "other sites unaffected");
        disarm_all();
    }

    #[test]
    fn disarmed_sites_never_fire() {
        disarm_all();
        for _ in 0..100 {
            assert!(!hit(ENGINE_STALL));
        }
    }

    #[test]
    fn thread_local_arming_is_invisible_to_other_threads() {
        disarm_all();
        arm(SLOW_WRITE, 1);
        let other = std::thread::spawn(|| hit(SLOW_WRITE));
        assert!(!other.join().unwrap(), "arming must not leak across threads");
        assert!(hit(SLOW_WRITE), "still armed on this thread");
        disarm_all();
    }
}
