//! Minimal JSON encoder/parser (serde is unavailable offline).
//!
//! The HTTP serving front-end ([`crate::serve::http`]) and the benchmark
//! artifact emitters need machine-readable wire formats, so this module
//! implements the subset of JSON the system uses: a [`Json`] value tree, a
//! strict recursive-descent parser (full string escapes including `\uXXXX`
//! surrogate pairs, depth-limited, rejects trailing garbage), and a compact
//! encoder. Object keys keep insertion order so encoded documents are
//! deterministic.

use std::fmt::Write as _;

use anyhow::{anyhow, bail, ensure, Result};

/// Nesting depth cap: a hand-rolled recursive parser must bound recursion
/// so a hostile `[[[[...` body cannot blow the connection thread's stack.
const MAX_DEPTH: usize = 128;

/// 2^53 — every integer with magnitude strictly below this is exactly
/// representable in f64, so integer round-trips are lossless under it.
const F64_EXACT_INT: f64 = 9_007_199_254_740_992.0;

/// A parsed JSON value. Numbers are f64 (JSON has no integer type); object
/// pairs preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Empty object, for builder-style construction with [`Json::with`].
    pub fn object() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a key/value pair (no-op on non-objects); returns self so
    /// documents read as a chain.
    pub fn with(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(pairs) = &mut self {
            pairs.push((key.to_string(), value.into()));
        }
        self
    }

    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Number as a non-negative integer; `None` for negatives, fractions,
    /// and values at or beyond 2^53 (f64's exact-integer range).
    pub fn as_u64(&self) -> Option<u64> {
        let v = self.as_f64()?;
        if v >= 0.0 && v.fract() == 0.0 && v < F64_EXACT_INT {
            Some(v as u64)
        } else {
            None
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Compact encoding (no whitespace). Non-finite numbers encode as
    /// `null` — JSON has no NaN/Infinity.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(*v, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Strict parse of a complete JSON document (trailing non-whitespace is
    /// an error, as are numbers that overflow f64).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value(0)?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "json: trailing data at byte {}", p.pos);
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<Vec<Json>> for Json {
    fn from(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
}

fn write_num(v: f64, out: &mut String) {
    if !v.is_finite() {
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < F64_EXACT_INT {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        ensure!(depth <= MAX_DEPTH, "json: nesting deeper than {MAX_DEPTH}");
        self.skip_ws();
        match self.peek() {
            None => bail!("json: unexpected end of input"),
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => {
                self.literal("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'n') => {
                self.literal("null")?;
                Ok(Json::Null)
            }
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => bail!("json: unexpected byte `{}` at {}", c as char, self.pos),
        }
    }

    fn literal(&mut self, lit: &str) -> Result<()> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            bail!("json: invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let v: f64 = s.parse().map_err(|_| anyhow!("json: invalid number `{s}` at {start}"))?;
        ensure!(v.is_finite(), "json: number `{s}` overflows f64");
        Ok(Json::Num(v))
    }

    fn hex4(&mut self) -> Result<u32> {
        ensure!(self.pos + 4 <= self.bytes.len(), "json: truncated \\u escape");
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow!("json: bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| anyhow!("json: bad \\u escape `{s}`"))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        // Caller ensured the opening quote.
        self.pos += 1;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else { bail!("json: unterminated string") };
            match b {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let Some(e) = self.peek() else { bail!("json: unterminated escape") };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xd800..0xdc00).contains(&hi) {
                                self.literal("\\u")?;
                                let lo = self.hex4()?;
                                ensure!(
                                    (0xdc00..0xe000).contains(&lo),
                                    "json: invalid low surrogate \\u{lo:04x}"
                                );
                                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                hi
                            };
                            let c = char::from_u32(code)
                                .ok_or_else(|| anyhow!("json: invalid \\u escape {code:#x}"))?;
                            out.push(c);
                        }
                        other => bail!("json: unknown escape `\\{}`", other as char),
                    }
                }
                b if b < 0x20 => bail!("json: unescaped control character in string"),
                _ => {
                    // Raw run up to the next quote/escape. The delimiters
                    // are ASCII, so both endpoints sit on char boundaries
                    // and the slice is valid UTF-8 (input was &str).
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' || c < 0x20 {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.pos += 1; // [
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("json: expected `,` or `]` at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.pos += 1; // {
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            ensure!(self.peek() == Some(b'"'), "json: expected string key at byte {}", self.pos);
            let key = self.string()?;
            self.skip_ws();
            ensure!(self.peek() == Some(b':'), "json: expected `:` at byte {}", self.pos);
            self.pos += 1;
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => bail!("json: expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_escapes() {
        let doc = Json::object()
            .with("quote\"backslash\\", "line\nbreak\ttab")
            .with("unicode", "café ☕")
            .with("control", "\u{0001}bell\u{0007}");
        let text = doc.encode();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
        // Escapes actually appear escaped on the wire.
        assert!(text.contains("\\\""));
        assert!(text.contains("\\n"));
        assert!(text.contains("\\u0001"));
    }

    #[test]
    fn nested_objects_and_whitespace() {
        let text = r#"
            { "a" : [ 1 , 2 , { "b" : [ ] , "c" : { } } ] ,
              "d" : null , "e" : true , "f" : false }
        "#;
        let v = Json::parse(text).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_u64(), Some(1));
        assert_eq!(a[2].get("b").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.get("e").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("f").unwrap().as_bool(), Some(false));
        // Round-trip through the compact encoding.
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -7, 2.5, 1e3, 1.25e-2, 9007199254740991]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_u64(), Some(0));
        assert_eq!(a[1].as_f64(), Some(-7.0));
        assert_eq!(a[1].as_u64(), None, "negative is not u64");
        assert_eq!(a[2].as_f64(), Some(2.5));
        assert_eq!(a[2].as_u64(), None, "fraction is not u64");
        assert_eq!(a[3].as_f64(), Some(1000.0));
        assert_eq!(a[4].as_f64(), Some(0.0125));
        assert_eq!(a[5].as_u64(), Some(9007199254740991), "2^53 - 1 is exact");
        let big = Json::parse("9007199254740992").unwrap();
        assert_eq!(big.as_u64(), None, "2^53 is past the exact range");
        // Integral floats encode without a decimal point; fractions keep it.
        assert_eq!(Json::Num(3.0).encode(), "3");
        assert_eq!(Json::Num(2.5).encode(), "2.5");
        assert_eq!(Json::Num(f64::NAN).encode(), "null");
        assert!(Json::parse("1e999").is_err(), "overflow must not parse to inf");
    }

    #[test]
    fn unicode_escapes_and_raw_utf8() {
        assert_eq!(Json::parse(r#""\u00e9""#).unwrap(), Json::Str("é".into()));
        // Surrogate pair escape for U+1F600.
        assert_eq!(Json::parse(r#""\ud83d\ude00""#).unwrap(), Json::Str("😀".into()));
        // Raw (unescaped) multi-byte UTF-8 passes through untouched.
        assert_eq!(Json::parse("\"caffè 😀\"").unwrap(), Json::Str("caffè 😀".into()));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone high surrogate");
        assert!(Json::parse(r#""\uzzzz""#).is_err());
    }

    #[test]
    fn malformed_documents_are_rejected() {
        for bad in [
            "",
            "{",
            "{\"a\":}",
            "{\"a\" 1}",
            "{a:1}",
            "[1 2]",
            "\"unterminated",
            "nul",
            "1 trailing",
            "{} {}",
            "\"raw\u{0001}control\"",
            "--5",
        ] {
            assert!(Json::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn depth_limit_holds() {
        let deep = "[".repeat(MAX_DEPTH + 8) + &"]".repeat(MAX_DEPTH + 8);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(16) + &"]".repeat(16);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn builder_and_accessors() {
        let doc = Json::object()
            .with("name", "gq")
            .with("n", 3usize)
            .with("on", true)
            .with("items", vec![Json::from(1u32), Json::from(2u32)]);
        assert_eq!(doc.get("name").unwrap().as_str(), Some("gq"));
        assert_eq!(doc.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(doc.get("on").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("items").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(doc.get("missing"), None);
        assert_eq!(Json::Null.get("x"), None);
    }
}
