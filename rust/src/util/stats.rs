//! Tiny descriptive-statistics helpers for benches and metrics.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation; 0 for fewer than two values.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile (nearest-rank with linear interpolation), `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let f = rank - lo as f64;
        v[lo] * (1.0 - f) + v[hi] * f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_is_zero_for_any_p() {
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[], p), 0.0);
        }
    }

    #[test]
    fn percentile_single_element_is_that_element() {
        // rank = p/100 * 0 = 0 for every p: no interpolation, no panic.
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.25], p), 7.25);
        }
    }

    #[test]
    fn percentile_is_monotone_in_p() {
        let xs = [5.0, 1.0, 9.0, 3.0, 3.0];
        let mut last = f64::NEG_INFINITY;
        for p in 0..=100 {
            let v = percentile(&xs, p as f64);
            assert!(v >= last, "p={p}: {v} < {last}");
            last = v;
        }
    }
}
