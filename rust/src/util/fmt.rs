//! Human-readable formatting for the CLI/coordinator logs.

/// `1_532_000` -> "1.53M"
pub fn human_count(n: u64) -> String {
    const UNITS: [(&str, f64); 4] = [("B", 1e9), ("M", 1e6), ("K", 1e3), ("", 1.0)];
    for (suffix, div) in UNITS {
        if n as f64 >= div && div > 1.0 {
            return format!("{:.2}{}", n as f64 / div, suffix);
        }
    }
    n.to_string()
}

/// `1_532_000` bytes -> "1.46 MiB"
pub fn human_bytes(n: u64) -> String {
    const UNITS: [(&str, f64); 4] = [
        ("GiB", 1024.0 * 1024.0 * 1024.0),
        ("MiB", 1024.0 * 1024.0),
        ("KiB", 1024.0),
        ("B", 1.0),
    ];
    for (suffix, div) in UNITS {
        if n as f64 >= div && div > 1.0 {
            return format!("{:.2} {}", n as f64 / div, suffix);
        }
    }
    format!("{n} B")
}

/// Seconds -> "1.2s" / "3m12s" / "450ms"
pub fn human_duration(secs: f64) -> String {
    if secs < 1.0 {
        format!("{:.0}ms", secs * 1000.0)
    } else if secs < 60.0 {
        format!("{secs:.1}s")
    } else {
        let m = (secs / 60.0).floor() as u64;
        format!("{}m{:02.0}s", m, secs - 60.0 * m as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        assert_eq!(human_count(532), "532");
        assert_eq!(human_count(1_530), "1.53K");
        assert_eq!(human_count(2_000_000), "2.00M");
        assert_eq!(human_count(3_100_000_000), "3.10B");
    }

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(1_572_864), "1.50 MiB");
    }

    #[test]
    fn durations() {
        assert_eq!(human_duration(0.45), "450ms");
        assert_eq!(human_duration(12.34), "12.3s");
        assert_eq!(human_duration(125.0), "2m05s");
    }
}
