//! Wall-clock timing helpers used by the coordinator metrics and the bench
//! harness (the offline stand-in for criterion).

use std::time::{Duration, Instant};

/// A simple start/lap timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap()` (or construction).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }

    pub fn elapsed_duration(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let mut t = Timer::new();
        std::thread::sleep(Duration::from_millis(5));
        let lap1 = t.lap();
        assert!(lap1 >= 0.004);
        let lap2 = t.lap();
        assert!(lap2 < lap1);
        assert!(t.elapsed() >= lap1);
    }

    #[test]
    fn time_it_returns_result() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
