//! Dense f32 matrix substrate: storage, blocked/threaded matmul, binary I/O.

pub mod io;
pub mod mat;
pub mod ops;

pub use mat::Mat;
pub use ops::{matmul, matmul_tn, matvec};
