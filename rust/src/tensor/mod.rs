//! Dense f32 matrix substrate: storage, blocked/threaded matmul, the tiled
//! quantized-GEMM engine, and binary I/O.

pub mod gemm;
pub mod io;
pub mod mat;
pub mod ops;
pub mod simd;

pub use gemm::ColWindow;
pub use mat::Mat;
pub use ops::{matmul, matmul_tn, matvec};
