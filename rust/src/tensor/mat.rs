//! Row-major f32 matrix. The workhorse container for weights, activations
//! and Hessians throughout the quantization pipeline.

use crate::util::Rng;

#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. N(0, sigma^2) entries.
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal_f32() * sigma);
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            *self.at_mut(i, j) = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Columns `[lo, hi)` as a new matrix.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols);
        Mat::from_fn(self.rows, hi - lo, |i, j| self.at(i, lo + j))
    }

    /// Overwrite columns `[lo, lo + src.cols)` with `src`.
    pub fn paste_cols(&mut self, lo: usize, src: &Mat) {
        assert_eq!(src.rows, self.rows);
        assert!(lo + src.cols <= self.cols);
        for i in 0..self.rows {
            for j in 0..src.cols {
                *self.at_mut(i, lo + j) = src.at(i, j);
            }
        }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// self += other * s
    pub fn axpy(&mut self, other: &Mat, s: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b * s;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Symmetrize in place: self = (self + self^T)/2. Requires square.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let avg = 0.5 * (self.at(i, j) + self.at(j, i));
                *self.at_mut(i, j) = avg;
                *self.at_mut(j, i) = avg;
            }
        }
    }

    /// Add `lambda` to the diagonal (damping, paper §4.2).
    pub fn add_diag(&mut self, lambda: f32) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += lambda;
        }
    }

    pub fn diag(&self) -> Vec<f32> {
        (0..self.rows.min(self.cols)).map(|i| self.at(i, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let mut m = Mat::zeros(2, 3);
        *m.at_mut(1, 2) = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m.col(2), vec![0.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn from_vec_checks_shape() {
        Mat::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(0);
        let m = Mat::randn(37, 53, 1.0, &mut rng);
        let t = m.transpose();
        assert_eq!((t.rows, t.cols), (53, 37));
        assert_eq!(t.transpose(), m);
        assert_eq!(m.at(10, 20), t.at(20, 10));
    }

    #[test]
    fn slice_and_paste_cols() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let s = m.slice_cols(1, 3);
        assert_eq!(s.row(0), &[1.0, 2.0]);
        let mut m2 = Mat::zeros(3, 4);
        m2.paste_cols(1, &s);
        assert_eq!(m2.at(2, 2), m.at(2, 2));
        assert_eq!(m2.at(2, 0), 0.0);
    }

    #[test]
    fn symmetrize_and_diag() {
        let mut m = Mat::from_vec(2, 2, vec![1.0, 2.0, 4.0, 3.0]);
        m.symmetrize();
        assert_eq!(m.at(0, 1), 3.0);
        assert_eq!(m.at(1, 0), 3.0);
        m.add_diag(0.5);
        assert_eq!(m.diag(), vec![1.5, 3.5]);
    }

    #[test]
    fn norms() {
        let m = Mat::from_vec(1, 3, vec![3.0, -4.0, 0.0]);
        assert!((m.frob_norm_sq() - 25.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
    }
}
