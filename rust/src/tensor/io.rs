//! Binary tensor I/O: a minimal named-tensor container ("GQTB" format) used
//! for trained weights, Hessian caches and quantized model checkpoints.
//!
//! Layout (little-endian):
//!   magic "GQTB" | u32 version | u32 count
//!   per entry: u32 name_len | name bytes | u32 rows | u32 cols | f32 data
//!
//! No serde offline — the format is deliberately trivial and versioned.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Mat;

const MAGIC: &[u8; 4] = b"GQTB";
const VERSION: u32 = 1;

/// Ordered collection of named matrices.
#[derive(Default, Debug, Clone)]
pub struct TensorFile {
    pub entries: BTreeMap<String, Mat>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, m: Mat) {
        self.entries.insert(name.into(), m);
    }

    pub fn get(&self, name: &str) -> Option<&Mat> {
        self.entries.get(name)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w =
            std::io::BufWriter::new(std::fs::File::create(path).with_context(|| format!("create {path:?}"))?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, m) in &self.entries {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(m.rows as u32).to_le_bytes())?;
            w.write_all(&(m.cols as u32).to_le_bytes())?;
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(m.data.as_ptr() as *const u8, m.data.len() * 4)
            };
            w.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut r =
            std::io::BufReader::new(std::fs::File::open(path).with_context(|| format!("open {path:?}"))?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: bad magic {magic:?}");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("{path:?}: unsupported version {version}");
        }
        let count = read_u32(&mut r)? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            if name_len > 4096 {
                bail!("{path:?}: corrupt name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name utf8")?;
            let rows = read_u32(&mut r)? as usize;
            let cols = read_u32(&mut r)? as usize;
            let mut data = vec![0f32; rows * cols];
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, data.len() * 4)
            };
            r.read_exact(bytes)?;
            entries.insert(name, Mat::from_vec(rows, cols, data));
        }
        Ok(TensorFile { entries })
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gq_test_{tag}_{}.gqtb", std::process::id()))
    }

    #[test]
    fn save_load_round_trip() {
        let mut rng = Rng::new(0);
        let mut tf = TensorFile::new();
        tf.insert("w.a", Mat::randn(7, 5, 1.0, &mut rng));
        tf.insert("w.b", Mat::randn(1, 9, 2.0, &mut rng));
        let path = tmpfile("roundtrip");
        tf.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back.entries.len(), 2);
        assert_eq!(back.get("w.a").unwrap(), tf.get("w.a").unwrap());
        assert_eq!(back.get("w.b").unwrap(), tf.get("w.b").unwrap());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(TensorFile::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(TensorFile::load("/nonexistent/gq.bin").is_err());
    }
}
