//! Explicit SIMD micro-kernels for the serving hot loops, with runtime
//! dispatch and a bit-identity contract.
//!
//! ## Dispatch
//!
//! The active level is resolved once per process from `GQ_SIMD` (env, read
//! once): `0` forces the chunked scalar fallbacks everywhere; unset or any
//! other value uses the best level the CPU supports
//! (`is_x86_feature_detected!`): AVX2, then SSE2 (always present on
//! x86-64), scalar on other architectures. Benches and tests can override
//! the routing in-process via [`force`] — safe to flip at any time because
//! every primitive is **bit-identical across levels** (see below), so a
//! mid-flight switch can never change results, only speed.
//!
//! ## Bit-identity contract
//!
//! Every primitive produces exactly the same f32 results (per element, `==`)
//! at every level:
//!
//! * Vector paths use separate multiply + add (never fused FMA, whose
//!   single rounding differs from the scalar two-rounding sequence).
//! * [`dot`] keeps 8 independent accumulator lanes — exactly the scalar
//!   fallback's 8-wide unroll — and reduces them in the same fixed
//!   `acc[0] + acc[1] + … + acc[7]` order.
//! * [`axpy`], [`panel_fma4`]/[`panel_fma1`], and the dequant epilogues
//!   ([`scale_affine`], [`scale_inplace`], [`lut_gather`]) are elementwise:
//!   each output element sees the same operations in the same order
//!   regardless of how many land per instruction.
//! * [`max`] is a plain max-reduction: f32 max over finite inputs is
//!   associative and commutative, so lane order cannot change the value
//!   (callers feed it finite attention scores; NaN inputs are excluded by
//!   contract).
//! * The f16 readers ([`dot_f16`], [`axpy_f16`]) widen half floats on read;
//!   widening is exact (f16 ⊂ f32), so they are bit-identical across
//!   levels too — F16C hardware converts agree with the software codec.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::util::half::f16_to_f32;

/// Vector width every panel/epilogue primitive is built around (f32 lanes
/// of one AVX2 register; the scalar fallbacks unroll to the same width).
pub const WIDTH: usize = 8;

/// Active instruction level for the dispatched primitives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    Scalar,
    Sse2,
    Avx2,
}

/// Best level this CPU supports (ignores `GQ_SIMD`).
fn detected() -> Level {
    static DET: OnceLock<Level> = OnceLock::new();
    *DET.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                Level::Avx2
            } else {
                Level::Sse2 // baseline of the x86-64 ISA
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            Level::Scalar
        }
    })
}

/// `GQ_SIMD`-resolved level: `0` forces scalar, anything else auto-detects.
fn env_level() -> Level {
    static CFG: OnceLock<Level> = OnceLock::new();
    *CFG.get_or_init(|| match std::env::var("GQ_SIMD") {
        Ok(v) if v.trim() == "0" => Level::Scalar,
        _ => detected(),
    })
}

/// In-process routing override: 0 = follow `GQ_SIMD`, 1 = force scalar,
/// 2 = force the detected SIMD level (ignoring `GQ_SIMD`).
static FORCE: AtomicU8 = AtomicU8::new(0);

/// Test/bench hook: `Some(false)` forces the scalar fallbacks,
/// `Some(true)` forces the detected SIMD level (ignoring `GQ_SIMD`),
/// `None` restores `GQ_SIMD` routing. Safe to flip while other threads run
/// kernels — all levels are bit-identical, so only throughput changes.
pub fn force(mode: Option<bool>) {
    let v = match mode {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    FORCE.store(v, Ordering::Relaxed);
}

/// The level the next primitive call will dispatch to.
#[inline]
pub fn level() -> Level {
    match FORCE.load(Ordering::Relaxed) {
        1 => Level::Scalar,
        2 => detected(),
        _ => env_level(),
    }
}

/// Whether F16C hardware f16<->f32 converts are used by the f16 readers
/// (requires an active SIMD level; scalar routing uses the software codec).
#[inline]
fn use_f16c() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static F16C: OnceLock<bool> = OnceLock::new();
        level() != Level::Scalar
            && *F16C.get_or_init(|| std::arch::is_x86_feature_detected!("f16c"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Human-readable description of the active routing — benches print this so
/// recorded numbers say what ran.
pub fn desc() -> &'static str {
    match level() {
        Level::Avx2 => {
            if use_f16c() {
                "simd avx2+f16c"
            } else {
                "simd avx2"
            }
        }
        Level::Sse2 => "simd sse2",
        Level::Scalar => "scalar (GQ_SIMD=0)",
    }
}

// ---------------------------------------------------------------------------
// dot / axpy / max
// ---------------------------------------------------------------------------

/// Dense dot product: 8 independent accumulator lanes over the 8-aligned
/// prefix (one AVX2 register / two SSE registers / the scalar unroll),
/// reduced in fixed lane order, scalar remainder.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    match level() {
        Level::Avx2 => return unsafe { x86::dot_avx2(a, b) },
        Level::Sse2 => return unsafe { x86::dot_sse2(a, b) },
        Level::Scalar => {}
    }
    dot_scalar(a, b)
}

/// The scalar fallback of [`dot`] (8-wide chunked unroll, auto-vec
/// friendly). Public so tests can pin the vector paths against it.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += a · x, elementwise in index order.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    match level() {
        Level::Avx2 => return unsafe { x86::axpy_avx2(y, a, x) },
        Level::Sse2 => return unsafe { x86::axpy_sse2(y, a, x) },
        Level::Scalar => {}
    }
    axpy_scalar(y, a, x);
}

/// The scalar fallback of [`axpy`].
#[inline]
pub fn axpy_scalar(y: &mut [f32], a: f32, x: &[f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// Max over a slice (`NEG_INFINITY` when empty). Order-independent for the
/// finite inputs the softmax feeds it, so the vector reduction is exact.
#[inline]
pub fn max(xs: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        return unsafe { x86::max_avx2(xs) };
    }
    xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
}

// ---------------------------------------------------------------------------
// GEMM micro-panel row sweeps
// ---------------------------------------------------------------------------

/// Sweep a decoded tile's rows into 4 lanes × [`WIDTH`] columns of resumed
/// accumulators: `acc[r][j] += xrows[r][i0 + i] * tile[i * w + jp + j]` for
/// every tile row `i`, rows ascending, per-`(r, j)` chains independent.
/// The accumulators stay in registers across the whole sweep.
#[inline]
pub fn panel_fma4(
    acc: &mut [[f32; WIDTH]; 4],
    xrows: &[&[f32]; 4],
    tile: &[f32],
    w: usize,
    jp: usize,
    i0: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        unsafe { x86::panel4_avx2(acc, xrows, tile, w, jp, i0) };
        return;
    }
    let rows = tile.len() / w;
    for i in 0..rows {
        let trow = &tile[i * w + jp..i * w + jp + WIDTH];
        for (xr, a) in xrows.iter().zip(acc.iter_mut()) {
            let xi = xr[i0 + i];
            for (av, &tv) in a.iter_mut().zip(trow) {
                *av += xi * tv;
            }
        }
    }
}

/// One-lane variant of [`panel_fma4`] (batch remainder rows).
#[inline]
pub fn panel_fma1(
    acc: &mut [f32; WIDTH],
    xrow: &[f32],
    tile: &[f32],
    w: usize,
    jp: usize,
    i0: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        unsafe { x86::panel1_avx2(acc, xrow, tile, w, jp, i0) };
        return;
    }
    let rows = tile.len() / w;
    for i in 0..rows {
        let trow = &tile[i * w + jp..i * w + jp + WIDTH];
        let xi = xrow[i0 + i];
        for (av, &tv) in acc.iter_mut().zip(trow) {
            *av += xi * tv;
        }
    }
}

// ---------------------------------------------------------------------------
// Dequant epilogues
// ---------------------------------------------------------------------------

/// Affine epilogue of the uniform-scalar format:
/// `out[j] = out[j] * scale[j] + xsum * zero[j]`, elementwise.
#[inline]
pub fn scale_affine(out: &mut [f32], scale: &[f32], zero: &[f32], xsum: f32) {
    debug_assert_eq!(out.len(), scale.len());
    debug_assert_eq!(out.len(), zero.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        unsafe { x86::scale_affine_avx2(out, scale, zero, xsum) };
        return;
    }
    for ((o, &s), &z) in out.iter_mut().zip(scale).zip(zero) {
        *o = *o * s + xsum * z;
    }
}

/// Per-column scale epilogue of the trellis format: `out[j] *= scale[j]`.
#[inline]
pub fn scale_inplace(out: &mut [f32], scale: &[f32]) {
    debug_assert_eq!(out.len(), scale.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        unsafe { x86::scale_inplace_avx2(out, scale) };
        return;
    }
    for (o, &s) in out.iter_mut().zip(scale) {
        *o *= s;
    }
}

/// Per-channel LUT gather of the non-uniform format:
/// `out[j] = cb[(lo + j) * m + codes[j]]` (an exact copy — the AVX2 path
/// uses hardware gathers, trivially bit-identical).
#[inline]
pub fn lut_gather(cb: &[f32], m: usize, lo: usize, codes: &[u16], out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        unsafe { x86::lut_gather_avx2(cb, m, lo, codes, out) };
        return;
    }
    for (jj, (o, &code)) in out.iter_mut().zip(codes).enumerate() {
        *o = cb[(lo + jj) * m + code as usize];
    }
}

// ---------------------------------------------------------------------------
// f16 widen-on-read kernels
// ---------------------------------------------------------------------------

/// [`dot`] against a packed-f16 operand, widening on read. Same 8-lane
/// accumulator structure and reduction order as [`dot`]; the widening
/// itself is exact, so results are identical across levels and codecs.
#[inline]
pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if use_f16c() {
        return unsafe { x86::dot_f16c(a, b) };
    }
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * f16_to_f32(b[i + l]);
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        s += a[i] * f16_to_f32(b[i]);
    }
    s
}

/// [`axpy`] against a packed-f16 operand, widening on read.
#[inline]
pub fn axpy_f16(y: &mut [f32], a: f32, x: &[u16]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if use_f16c() {
        unsafe { x86::axpy_f16c(y, a, x) };
        return;
    }
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * f16_to_f32(xv);
    }
}

// ---------------------------------------------------------------------------
// x86-64 vector implementations
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! `core::arch` implementations. Every function mirrors its scalar
    //! fallback's arithmetic exactly: separate `mul` + `add` (no FMA), the
    //! same accumulator lane structure, and the same reduction order.

    use super::WIDTH;
    use crate::util::half::f16_to_f32;
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let chunks = a.len() / 8;
        let mut vacc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vb));
        }
        let mut acc = [0.0f32; 8];
        _mm256_storeu_ps(acc.as_mut_ptr(), vacc);
        let mut s = acc.iter().sum::<f32>();
        for i in chunks * 8..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        let chunks = a.len() / 8;
        let mut lo = _mm_setzero_ps();
        let mut hi = _mm_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let a0 = _mm_loadu_ps(a.as_ptr().add(i));
            let b0 = _mm_loadu_ps(b.as_ptr().add(i));
            lo = _mm_add_ps(lo, _mm_mul_ps(a0, b0));
            let a1 = _mm_loadu_ps(a.as_ptr().add(i + 4));
            let b1 = _mm_loadu_ps(b.as_ptr().add(i + 4));
            hi = _mm_add_ps(hi, _mm_mul_ps(a1, b1));
        }
        let mut acc = [0.0f32; 8];
        _mm_storeu_ps(acc.as_mut_ptr(), lo);
        _mm_storeu_ps(acc.as_mut_ptr().add(4), hi);
        let mut s = acc.iter().sum::<f32>();
        for i in chunks * 8..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len();
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
            i += 8;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn axpy_sse2(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len();
        let va = _mm_set1_ps(a);
        let mut i = 0;
        while i + 4 <= n {
            let vy = _mm_loadu_ps(y.as_ptr().add(i));
            let vx = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(y.as_mut_ptr().add(i), _mm_add_ps(vy, _mm_mul_ps(va, vx)));
            i += 4;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn max_avx2(xs: &[f32]) -> f32 {
        let n = xs.len();
        let mut m = f32::NEG_INFINITY;
        let mut i = 0;
        if n >= 8 {
            let mut vm = _mm256_loadu_ps(xs.as_ptr());
            i = 8;
            while i + 8 <= n {
                vm = _mm256_max_ps(vm, _mm256_loadu_ps(xs.as_ptr().add(i)));
                i += 8;
            }
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), vm);
            for &v in &lanes {
                m = m.max(v);
            }
        }
        while i < n {
            m = m.max(xs[i]);
            i += 1;
        }
        m
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn panel4_avx2(
        acc: &mut [[f32; WIDTH]; 4],
        xrows: &[&[f32]; 4],
        tile: &[f32],
        w: usize,
        jp: usize,
        i0: usize,
    ) {
        let rows = tile.len() / w;
        let mut v0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut v1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut v2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut v3 = _mm256_loadu_ps(acc[3].as_ptr());
        for i in 0..rows {
            let trow = _mm256_loadu_ps(tile.as_ptr().add(i * w + jp));
            let x0 = _mm256_set1_ps(*xrows[0].get_unchecked(i0 + i));
            v0 = _mm256_add_ps(v0, _mm256_mul_ps(x0, trow));
            let x1 = _mm256_set1_ps(*xrows[1].get_unchecked(i0 + i));
            v1 = _mm256_add_ps(v1, _mm256_mul_ps(x1, trow));
            let x2 = _mm256_set1_ps(*xrows[2].get_unchecked(i0 + i));
            v2 = _mm256_add_ps(v2, _mm256_mul_ps(x2, trow));
            let x3 = _mm256_set1_ps(*xrows[3].get_unchecked(i0 + i));
            v3 = _mm256_add_ps(v3, _mm256_mul_ps(x3, trow));
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), v0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), v1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), v2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), v3);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn panel1_avx2(
        acc: &mut [f32; WIDTH],
        xrow: &[f32],
        tile: &[f32],
        w: usize,
        jp: usize,
        i0: usize,
    ) {
        let rows = tile.len() / w;
        let mut v = _mm256_loadu_ps(acc.as_ptr());
        for i in 0..rows {
            let trow = _mm256_loadu_ps(tile.as_ptr().add(i * w + jp));
            let xi = _mm256_set1_ps(*xrow.get_unchecked(i0 + i));
            v = _mm256_add_ps(v, _mm256_mul_ps(xi, trow));
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), v);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_affine_avx2(
        out: &mut [f32],
        scale: &[f32],
        zero: &[f32],
        xsum: f32,
    ) {
        let n = out.len();
        let vx = _mm256_set1_ps(xsum);
        let mut j = 0;
        while j + 8 <= n {
            let vo = _mm256_loadu_ps(out.as_ptr().add(j));
            let vs = _mm256_loadu_ps(scale.as_ptr().add(j));
            let vz = _mm256_loadu_ps(zero.as_ptr().add(j));
            let r = _mm256_add_ps(_mm256_mul_ps(vo, vs), _mm256_mul_ps(vx, vz));
            _mm256_storeu_ps(out.as_mut_ptr().add(j), r);
            j += 8;
        }
        while j < n {
            out[j] = out[j] * scale[j] + xsum * zero[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_inplace_avx2(out: &mut [f32], scale: &[f32]) {
        let n = out.len();
        let mut j = 0;
        while j + 8 <= n {
            let vo = _mm256_loadu_ps(out.as_ptr().add(j));
            let vs = _mm256_loadu_ps(scale.as_ptr().add(j));
            _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_mul_ps(vo, vs));
            j += 8;
        }
        while j < n {
            out[j] *= scale[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn lut_gather_avx2(
        cb: &[f32],
        m: usize,
        lo: usize,
        codes: &[u16],
        out: &mut [f32],
    ) {
        let n = out.len();
        let mut j = 0;
        if m <= i32::MAX as usize && cb.len() <= i32::MAX as usize {
            // Per-lane index: (lo + j + l) * m + codes[j + l].
            let vm = _mm256_set1_epi32(m as i32);
            let steps = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
            while j + 8 <= n {
                // Widen 8 u16 codes to i32 lanes.
                let c = _mm_loadu_si128(codes.as_ptr().add(j) as *const __m128i);
                let vcode = _mm256_cvtepu16_epi32(c);
                let base = _mm256_add_epi32(_mm256_set1_epi32((lo + j) as i32), steps);
                let idx = _mm256_add_epi32(_mm256_mullo_epi32(base, vm), vcode);
                let g = _mm256_i32gather_ps::<4>(cb.as_ptr(), idx);
                _mm256_storeu_ps(out.as_mut_ptr().add(j), g);
                j += 8;
            }
        }
        while j < n {
            out[j] = cb[(lo + j) * m + codes[j] as usize];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "f16c")]
    pub(super) unsafe fn dot_f16c(a: &[f32], b: &[u16]) -> f32 {
        let chunks = a.len() / 8;
        let mut vacc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let h = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let vb = _mm256_cvtph_ps(h);
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vb));
        }
        let mut acc = [0.0f32; 8];
        _mm256_storeu_ps(acc.as_mut_ptr(), vacc);
        let mut s = acc.iter().sum::<f32>();
        for i in chunks * 8..a.len() {
            s += a[i] * f16_to_f32(b[i]);
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "f16c")]
    pub(super) unsafe fn axpy_f16c(y: &mut [f32], a: f32, x: &[u16]) {
        let n = y.len();
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let h = _mm_loadu_si128(x.as_ptr().add(i) as *const __m128i);
            let vx = _mm256_cvtph_ps(h);
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
            i += 8;
        }
        while i < n {
            y[i] += a * f16_to_f32(x[i]);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::half::f32_to_f16;
    use crate::util::Rng;

    /// Run `f` once forced-scalar and once forced-SIMD, restoring `GQ_SIMD`
    /// routing afterwards. On hardware without the vector paths both runs
    /// take the scalar route and the comparison is trivially true — the CI
    /// runners exercise the real thing.
    fn both_levels<T>(f: impl Fn() -> T) -> (T, T) {
        force(Some(false));
        let scalar = f();
        force(Some(true));
        let simd = f();
        force(None);
        (scalar, simd)
    }

    #[test]
    fn dot_and_axpy_are_bit_identical_across_levels() {
        let mut rng = Rng::new(41);
        for n in [0usize, 1, 7, 8, 9, 16, 19, 64, 127, 257] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let (ds, dv) = both_levels(|| dot(&a, &b));
            assert_eq!(ds.to_bits(), dv.to_bits(), "dot n={n}");
            assert_eq!(ds.to_bits(), dot_scalar(&a, &b).to_bits(), "dot fallback n={n}");
            let y0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let (ys, yv) = both_levels(|| {
                let mut y = y0.clone();
                axpy(&mut y, 0.37, &a);
                y
            });
            assert_eq!(ys, yv, "axpy n={n}");
        }
    }

    #[test]
    fn max_and_epilogues_are_bit_identical_across_levels() {
        let mut rng = Rng::new(43);
        for n in [1usize, 5, 8, 13, 64, 100] {
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let (ms, mv) = both_levels(|| max(&xs));
            assert_eq!(ms.to_bits(), mv.to_bits(), "max n={n}");
            let scale: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let zero: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let out0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let (os, ov) = both_levels(|| {
                let mut o = out0.clone();
                scale_affine(&mut o, &scale, &zero, 1.25);
                o
            });
            assert_eq!(os, ov, "scale_affine n={n}");
            let (ps, pv) = both_levels(|| {
                let mut o = out0.clone();
                scale_inplace(&mut o, &scale);
                o
            });
            assert_eq!(ps, pv, "scale_inplace n={n}");
        }
        assert_eq!(max(&[]), f32::NEG_INFINITY);
    }

    #[test]
    fn panel_sweeps_are_bit_identical_across_levels() {
        let mut rng = Rng::new(47);
        let (rows, w, jp, i0) = (13usize, 24usize, 8usize, 3usize);
        let tile: Vec<f32> = (0..rows * w).map(|_| rng.normal_f32()).collect();
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..i0 + rows).map(|_| rng.normal_f32()).collect())
            .collect();
        let acc0: [[f32; WIDTH]; 4] =
            std::array::from_fn(|_| std::array::from_fn(|_| rng.normal_f32()));
        let xrows: [&[f32]; 4] = std::array::from_fn(|r| xs[r].as_slice());
        let (a4s, a4v) = both_levels(|| {
            let mut acc = acc0;
            panel_fma4(&mut acc, &xrows, &tile, w, jp, i0);
            acc
        });
        assert_eq!(a4s, a4v, "panel_fma4");
        let (a1s, a1v) = both_levels(|| {
            let mut acc = acc0[0];
            panel_fma1(&mut acc, &xs[0], &tile, w, jp, i0);
            acc
        });
        assert_eq!(a1s, a1v, "panel_fma1");
    }

    #[test]
    fn lut_gather_matches_scalar_indexing() {
        let mut rng = Rng::new(51);
        let (m, d_out) = (16usize, 37usize);
        let cb: Vec<f32> = (0..d_out * m).map(|_| rng.normal_f32()).collect();
        for (lo, n) in [(0usize, 37usize), (5, 20), (11, 3)] {
            let codes: Vec<u16> = (0..n).map(|_| rng.below(m) as u16).collect();
            let (gs, gv) = both_levels(|| {
                let mut out = vec![0.0f32; n];
                lut_gather(&cb, m, lo, &codes, &mut out);
                out
            });
            assert_eq!(gs, gv, "lo={lo} n={n}");
            for (jj, &o) in gs.iter().enumerate() {
                assert_eq!(o, cb[(lo + jj) * m + codes[jj] as usize]);
            }
        }
    }

    #[test]
    fn f16_readers_widen_exactly_at_every_level() {
        let mut rng = Rng::new(53);
        for n in [1usize, 8, 19, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let bh: Vec<u16> = (0..n).map(|_| f32_to_f16(rng.normal_f32())).collect();
            let bw: Vec<f32> = bh.iter().map(|&h| crate::util::half::f16_to_f32(h)).collect();
            let (ds, dv) = both_levels(|| dot_f16(&a, &bh));
            assert_eq!(ds.to_bits(), dv.to_bits(), "dot_f16 n={n}");
            // Widening is exact, so the f16 dot equals the f32 dot over the
            // widened operand bit-for-bit.
            assert_eq!(ds.to_bits(), dot(&a, &bw).to_bits(), "dot_f16 vs widened n={n}");
            let y0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let (ys, yv) = both_levels(|| {
                let mut y = y0.clone();
                axpy_f16(&mut y, 0.21, &bh);
                y
            });
            assert_eq!(ys, yv, "axpy_f16 n={n}");
            let mut yw = y0.clone();
            axpy(&mut yw, 0.21, &bw);
            assert_eq!(ys, yw, "axpy_f16 vs widened n={n}");
        }
    }

    #[test]
    fn force_overrides_and_restores_routing() {
        let base = level();
        force(Some(false));
        assert_eq!(level(), Level::Scalar);
        force(Some(true));
        assert_ne!(level(), Level::Scalar, "detected level is never scalar on x86-64");
        force(None);
        assert_eq!(level(), base);
        assert!(!desc().is_empty());
    }
}
