//! Tiled quantized-GEMM engine: decode-once register-blocked kernels shared
//! by every serving format.
//!
//! ## Why tiles
//!
//! The row-at-a-time batched kernels (`LinearOp::matmul_cols`) unpack one
//! code row per input channel and immediately FMA it into every lane. That
//! amortizes *decode* across the batch, but the inner loops stay short and
//! branchy (per-lane zero skips, per-row staging), which defeats
//! auto-vectorization. The tiled engine instead decodes a
//! `[tile_rows × window]` block of weights ONCE into thread-local f32
//! scratch ([`LinearOp::decode_tile`], with code→f32 tables pre-expanded at
//! format construction), then applies the whole tile to all batch lanes
//! with a straight-line register-blocked micro-kernel (a fixed
//! [`PANEL_J`]-column panel unrolled over [`PANEL_LANES`] lanes, no
//! zero-skip branches) before the next tile is decoded.
//!
//! ## Bit-identity contract
//!
//! Every output element accumulates its terms in ascending input-row order:
//! the micro-kernel resumes each `(lane, column)` accumulator from the
//! output buffer, so splitting the input rows into tiles never reorders a
//! sum. Combined with the per-format epilogues
//! ([`LinearOp::tile_epilogue`]), the tiled product is exactly equal
//! (f32 `==`, per element) to looping [`LinearOp::matvec`] over the lanes —
//! at any tile height, any column-shard count, and any thread count. The
//! row-at-a-time kernels remain as the `GQ_TILE=0` fallback and must stay
//! bit-identical too; CI runs the determinism suite with the tiled engine
//! both forced on and forced off.
//!
//! ## Knobs
//!
//! `GQ_TILE` (env, read once): `0` disables the tiled engine (row-at-a-time
//! kernels everywhere), `1` or unset enables it with the default
//! [`TILE_ROWS`] tile height, any other integer `N >= 2` enables it with
//! tile height `N`. `GQ_SIMD` (see [`super::simd`]) independently routes
//! the panel sweep between explicit vector code and the scalar fallback —
//! results are bit-identical either way, so the two knobs compose freely.

use std::cell::Cell;
use std::marker::PhantomData;
use std::sync::OnceLock;

use crate::model::forward::LinearOp;
use crate::tensor::Mat;

/// Default decode-tile height (input rows decoded per tile). 64 rows keeps
/// a full-width tile of a 2k-channel layer in the hundreds of KB and a
/// per-shard tile comfortably cache-resident, while amortizing per-tile
/// decode setup (e.g. the trellis checkpoint replay) over many rows.
pub const TILE_ROWS: usize = 64;

/// Columns held in registers by the micro-kernel panel (one AVX2 register
/// of f32 lanes — the panel sweep dispatches through [`super::simd`]).
const PANEL_J: usize = super::simd::WIDTH;

/// Batch lanes blocked per micro-kernel pass (`PANEL_LANES * PANEL_J`
/// accumulators stay in registers).
const PANEL_LANES: usize = 4;

/// Parsed `GQ_TILE` setting: `None` = tiled engine disabled, `Some(rows)` =
/// enabled with that tile height. Read once per process.
fn tile_cfg() -> Option<usize> {
    static CFG: OnceLock<Option<usize>> = OnceLock::new();
    *CFG.get_or_init(|| match std::env::var("GQ_TILE") {
        Err(_) => Some(TILE_ROWS),
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(0) => None,
            Ok(1) => Some(TILE_ROWS),
            Ok(n) => Some(n),
            Err(_) => Some(TILE_ROWS),
        },
    })
}

/// Whether the tiled engine is enabled for auto-routed products.
pub fn tiled_enabled() -> bool {
    tile_cfg().is_some()
}

/// Tile height the auto-routed engine uses (the `GQ_TILE` override or
/// [`TILE_ROWS`]).
pub fn tile_rows() -> usize {
    tile_cfg().unwrap_or(TILE_ROWS)
}

/// Human-readable description of which batched decode kernel is active —
/// benches print this so recorded numbers say what ran.
pub fn kernel_desc() -> String {
    let simd = super::simd::desc();
    match tile_cfg() {
        Some(rows) => format!("tiled-gemm (dequant-once, tile rows {rows}, {simd})"),
        None => format!("row-at-a-time (GQ_TILE=0, {simd})"),
    }
}

// ---------------------------------------------------------------------------
// Output column windows
// ---------------------------------------------------------------------------

/// Mutable view of columns `[lo, hi)` of a row-major `[rows, stride]`
/// output buffer — the unit of work of the column-sharded batched linear.
///
/// The sharded driver materializes one window per shard over the SAME
/// output matrix (disjoint column ranges, in-place writes: no per-shard
/// staging buffer, no paste copy), so the view is raw-pointer-backed; each
/// row window is handed out as an ordinary `&mut [f32]`. Safe constructors
/// ([`ColWindow::full`], [`ColWindow::window`]) cover the exclusive-access
/// cases; only the driver uses the unsafe disjoint-shard constructor.
pub struct ColWindow<'a> {
    ptr: *mut f32,
    rows: usize,
    stride: usize,
    lo: usize,
    hi: usize,
    _life: PhantomData<&'a mut [f32]>,
}

// SAFETY: a window is an exclusive view of its column range (constructor
// contract); sending it to a pool worker moves that exclusive access.
unsafe impl Send for ColWindow<'_> {}

impl<'a> ColWindow<'a> {
    /// The whole matrix as one window.
    pub fn full(m: &'a mut Mat) -> Self {
        let (rows, stride) = (m.rows, m.cols);
        ColWindow {
            ptr: m.data.as_mut_ptr(),
            rows,
            stride,
            lo: 0,
            hi: stride,
            _life: PhantomData,
        }
    }

    /// Columns `[lo, hi)` of `m` as a window (exclusive borrow of the whole
    /// matrix, so trivially safe).
    pub fn window(m: &'a mut Mat, lo: usize, hi: usize) -> Self {
        assert!(lo <= hi && hi <= m.cols, "window [{lo}, {hi}) out of {} cols", m.cols);
        let (rows, stride) = (m.rows, m.cols);
        ColWindow { ptr: m.data.as_mut_ptr(), rows, stride, lo, hi, _life: PhantomData }
    }

    /// Window over a raw row-major buffer.
    ///
    /// # Safety
    /// `ptr` must point at a live `rows * stride` f32 buffer for `'a`,
    /// `lo <= hi <= stride`, and the column ranges of all concurrently
    /// live windows over that buffer must be pairwise disjoint (the
    /// sharded driver guarantees this by construction).
    pub unsafe fn from_raw(
        ptr: *mut f32,
        rows: usize,
        stride: usize,
        lo: usize,
        hi: usize,
    ) -> Self {
        debug_assert!(lo <= hi && hi <= stride);
        ColWindow { ptr, rows, stride, lo, hi, _life: PhantomData }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// First absolute output column of the window.
    #[inline]
    pub fn lo(&self) -> usize {
        self.lo
    }

    /// One past the last absolute output column of the window.
    #[inline]
    pub fn hi(&self) -> usize {
        self.hi
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.hi - self.lo
    }

    /// Row `r` of the window: the `[lo, hi)` slice of output row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        // SAFETY: in-bounds (r < rows, hi <= stride); `&mut self` makes
        // this view's access exclusive, and disjointness across views is
        // the `from_raw` contract.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.ptr.add(r * self.stride + self.lo),
                self.hi - self.lo,
            )
        }
    }

    pub fn fill(&mut self, v: f32) {
        for r in 0..self.rows {
            self.row_mut(r).fill(v);
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-local kernel scratch
// ---------------------------------------------------------------------------

thread_local! {
    // `const`-init Cells with take/put discipline: no lazy registration on
    // the hot path, re-entrancy degrades to a fresh allocation instead of
    // a panic, and a warm steady-state kernel call allocates nothing.
    static KERNEL_U16: Cell<Vec<u16>> = const { Cell::new(Vec::new()) };
    static KERNEL_F32: Cell<Vec<f32>> = const { Cell::new(Vec::new()) };
    static TILE_F32: Cell<Vec<f32>> = const { Cell::new(Vec::new()) };
    static FULL_F32: Cell<Vec<f32>> = const { Cell::new(Vec::new()) };
}

fn with_cell_u16<T>(cell: &Cell<Vec<u16>>, len: usize, f: impl FnOnce(&mut [u16]) -> T) -> T {
    let mut v = cell.take();
    if v.len() < len {
        v.resize(len, 0);
    }
    let out = f(&mut v[..len]);
    cell.set(v);
    out
}

fn with_cell_f32<T>(cell: &Cell<Vec<f32>>, len: usize, f: impl FnOnce(&mut [f32]) -> T) -> T {
    let mut v = cell.take();
    if v.len() < len {
        v.resize(len, 0.0);
    }
    let out = f(&mut v[..len]);
    cell.set(v);
    out
}

/// Thread-local u16 code staging scratch for the format kernels (replaces
/// the per-call `vec![0u16; ...]` decode buffers).
pub(crate) fn with_u16_scratch<T>(len: usize, f: impl FnOnce(&mut [u16]) -> T) -> T {
    KERNEL_U16.with(|c| with_cell_u16(c, len, f))
}

/// Thread-local f32 scratch for the format kernels (decoded weight rows,
/// per-lane accumulators).
pub(crate) fn with_f32_scratch<T>(len: usize, f: impl FnOnce(&mut [f32]) -> T) -> T {
    KERNEL_F32.with(|c| with_cell_f32(c, len, f))
}

/// Thread-local scratch for the trait-default whole-row `matvec` staging
/// (kept separate from [`with_f32_scratch`] so a default `matmul_cols`
/// wrapping a format matvec does not thrash the kernel cell).
pub(crate) fn with_full_scratch<T>(len: usize, f: impl FnOnce(&mut [f32]) -> T) -> T {
    FULL_F32.with(|c| with_cell_f32(c, len, f))
}

fn with_tile_scratch<T>(len: usize, f: impl FnOnce(&mut [f32]) -> T) -> T {
    TILE_F32.with(|c| with_cell_f32(c, len, f))
}

// ---------------------------------------------------------------------------
// The tiled engine
// ---------------------------------------------------------------------------

/// Auto-routed batched window product: the tiled engine when it is enabled
/// and the format supports tile decode, the format's row-at-a-time
/// `matmul_cols` kernel otherwise. Both paths are bit-identical; this is
/// the entry point the column-sharded driver uses per shard.
pub fn matmul_cols_auto(op: &dyn LinearOp, xs: &Mat, out: &mut ColWindow) {
    match tile_cfg() {
        Some(rows) if op.supports_decode_tile() => matmul_tiled_with(op, xs, out, rows),
        _ => op.matmul_cols(xs, out),
    }
}

/// Tiled window product at an explicit tile height (exposed for the
/// bit-identity tests and the row-vs-tiled bench rows; heights that do not
/// divide `d_in` are fine — the last tile is shorter).
///
/// `out.row(r)[lo..hi] = epilogue(xs.row(r) @ D[:, lo..hi])` where `D` is
/// the format's pre-epilogue decoded weight matrix: each tile of `D` is
/// decoded once into thread-local scratch and applied to every lane before
/// the next tile is decoded. Accumulation per output element stays in
/// ascending input-row order (resumed from `out` across tiles), so the
/// result is bit-identical to looping `matvec`.
pub fn matmul_tiled_with(op: &dyn LinearOp, xs: &Mat, out: &mut ColWindow, tile_height: usize) {
    let d_in = op.d_in();
    debug_assert_eq!(xs.cols, d_in);
    debug_assert_eq!(xs.rows, out.rows());
    debug_assert!(out.hi() <= op.d_out());
    let (lo, hi, w) = (out.lo(), out.hi(), out.width());
    let b = xs.rows;
    if w == 0 || b == 0 {
        return;
    }
    let th = tile_height.max(1);
    out.fill(0.0);
    with_tile_scratch(th.min(d_in.max(1)) * w, |tile| {
        let mut i0 = 0;
        while i0 < d_in {
            let i1 = (i0 + th).min(d_in);
            let t = &mut tile[..(i1 - i0) * w];
            op.decode_tile(i0, i1, lo, hi, t);
            apply_tile(xs, out, t, i0);
            i0 = i1;
        }
    });
    for r in 0..b {
        op.tile_epilogue(xs.row(r), out.row_mut(r), lo);
    }
}

/// FMA one decoded tile (rows `[i0, i0 + tile.len()/width)`) into every
/// lane's output window: register-blocked panels of [`PANEL_J`] columns ×
/// [`PANEL_LANES`] lanes, with narrower straight-line remainders. Every
/// `(lane, column)` accumulator is loaded from `out`, extended over the
/// tile's rows in ascending order, and stored back — a resumed flat sum.
fn apply_tile(xs: &Mat, out: &mut ColWindow, tile: &[f32], i0: usize) {
    let w = out.width();
    let b = xs.rows;
    let mut jp = 0;
    while jp < w {
        let nj = (w - jp).min(PANEL_J);
        if nj == PANEL_J {
            let mut r0 = 0;
            while r0 + PANEL_LANES <= b {
                micro_panel4(xs, out, tile, i0, jp, r0);
                r0 += PANEL_LANES;
            }
            while r0 < b {
                micro_panel1(xs, out, tile, i0, jp, r0);
                r0 += 1;
            }
        } else {
            for r in 0..b {
                micro_panel_rem(xs, out, tile, i0, jp, nj, r);
            }
        }
        jp += nj;
    }
}

/// Full-width panel: [`PANEL_LANES`] lanes × [`PANEL_J`] columns of
/// accumulators held in registers across the tile's row sweep. The sweep
/// itself dispatches through [`super::simd::panel_fma4`], whose scalar and
/// vector paths are bit-identical (separate mul+add, same per-element
/// chains) — so the tiled product stays exactly equal at any `GQ_SIMD`.
#[inline]
fn micro_panel4(xs: &Mat, out: &mut ColWindow, tile: &[f32], i0: usize, jp: usize, r0: usize) {
    let w = out.width();
    let xrows: [&[f32]; PANEL_LANES] = std::array::from_fn(|r| xs.row(r0 + r));
    let mut acc = [[0.0f32; PANEL_J]; PANEL_LANES];
    for (r, a) in acc.iter_mut().enumerate() {
        a.copy_from_slice(&out.row_mut(r0 + r)[jp..jp + PANEL_J]);
    }
    super::simd::panel_fma4(&mut acc, &xrows, tile, w, jp, i0);
    for (r, a) in acc.iter().enumerate() {
        out.row_mut(r0 + r)[jp..jp + PANEL_J].copy_from_slice(a);
    }
}

/// One-lane variant of [`micro_panel4`] (batch remainder rows).
#[inline]
fn micro_panel1(xs: &Mat, out: &mut ColWindow, tile: &[f32], i0: usize, jp: usize, r0: usize) {
    let w = out.width();
    let mut acc = [0.0f32; PANEL_J];
    acc.copy_from_slice(&out.row_mut(r0)[jp..jp + PANEL_J]);
    super::simd::panel_fma1(&mut acc, xs.row(r0), tile, w, jp, i0);
    out.row_mut(r0)[jp..jp + PANEL_J].copy_from_slice(&acc);
}

/// Remainder panel (window width not a multiple of [`PANEL_J`]): one lane,
/// `nj < PANEL_J` columns, same resumed ascending-row accumulation.
#[inline]
fn micro_panel_rem(
    xs: &Mat,
    out: &mut ColWindow,
    tile: &[f32],
    i0: usize,
    jp: usize,
    nj: usize,
    r: usize,
) {
    let w = out.width();
    let rows = tile.len() / w;
    let xrow = xs.row(r);
    let mut acc = [0.0f32; PANEL_J];
    acc[..nj].copy_from_slice(&out.row_mut(r)[jp..jp + nj]);
    for i in 0..rows {
        let xi = xrow[i0 + i];
        let trow = &tile[i * w + jp..i * w + jp + nj];
        for (av, &tv) in acc[..nj].iter_mut().zip(trow) {
            *av += xi * tv;
        }
    }
    out.row_mut(r)[jp..jp + nj].copy_from_slice(&acc[..nj]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::LinearOp;
    use crate::testing;
    use crate::util::Rng;

    fn looped_matvec(op: &dyn LinearOp, xs: &Mat) -> Mat {
        let mut want = Mat::zeros(xs.rows, op.d_out());
        for r in 0..xs.rows {
            op.matvec(xs.row(r), want.row_mut(r));
        }
        want
    }

    #[test]
    fn tiled_fp32_matches_looped_matvec_property() {
        // Random shapes, batches, and tile heights — including heights that
        // do not divide d_in and exceed it — must all be exactly equal to
        // the per-lane matvec reference (panel remainders included: widths
        // sweep across the PANEL_J boundary).
        testing::check("tiled-vs-matvec", 30, |rng| {
            let d_in = 1 + rng.below(40);
            let d_out = 1 + rng.below(40);
            let b = 1 + rng.below(7);
            let w = Mat::randn(d_in, d_out, 1.0, rng);
            let mut xs = Mat::randn(b, d_in, 1.0, rng);
            xs.row_mut(0)[rng.below(d_in)] = 0.0; // zero-skip vs straight-line
            let want = looped_matvec(&w, &xs);
            for tile in [1, 2, 3, d_in, d_in + 5] {
                let mut got = Mat::zeros(b, d_out);
                matmul_tiled_with(&w, &xs, &mut ColWindow::full(&mut got), tile);
                testing::ensure(
                    got.data == want.data,
                    format!("tile={tile} d_in={d_in} d_out={d_out} b={b}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_window_matches_matvec_columns() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(24, 19, 1.0, &mut rng);
        let xs = Mat::randn(4, 24, 1.0, &mut rng);
        let want = looped_matvec(&w, &xs);
        let (lo, hi) = (5usize, 17usize);
        let mut got = Mat::zeros(4, 19);
        matmul_tiled_with(&w, &xs, &mut ColWindow::window(&mut got, lo, hi), 7);
        for r in 0..4 {
            assert_eq!(&got.row(r)[lo..hi], &want.row(r)[lo..hi], "row {r}");
            // Outside the window stays untouched.
            assert!(got.row(r)[..lo].iter().all(|&v| v == 0.0));
            assert!(got.row(r)[hi..].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn col_window_views_rows() {
        let mut m = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        let mut win = ColWindow::window(&mut m, 1, 4);
        assert_eq!(win.rows(), 3);
        assert_eq!((win.lo(), win.hi(), win.width()), (1, 4, 3));
        assert_eq!(win.row_mut(2), &[11.0, 12.0, 13.0]);
        win.fill(-1.0);
        assert_eq!(m.row(0), &[0.0, -1.0, -1.0, -1.0, 4.0]);
    }

    #[test]
    fn tiled_product_is_bit_identical_across_simd_levels() {
        use crate::tensor::simd;
        let mut rng = Rng::new(21);
        // Width 29 exercises full panels and the nj < PANEL_J remainder;
        // batch 6 exercises the 4-lane panel plus one-lane remainders.
        let w = Mat::randn(48, 29, 1.0, &mut rng);
        let xs = Mat::randn(6, 48, 1.0, &mut rng);
        let run = || {
            let mut got = Mat::zeros(6, 29);
            matmul_tiled_with(&w, &xs, &mut ColWindow::full(&mut got), 16);
            got
        };
        simd::force(Some(false));
        let scalar = run();
        simd::force(Some(true));
        let vector = run();
        simd::force(None);
        assert_eq!(scalar.data, vector.data);
    }

    #[test]
    fn gq_tile_knob_reports_kernel() {
        // The parsed setting is process-wide; whatever it is, the report
        // string and the enabled flag must agree.
        assert_eq!(kernel_desc().starts_with("tiled"), tiled_enabled());
        assert!(tile_rows() >= 1);
    }

    #[test]
    fn warm_kernel_scratch_does_not_allocate() {
        use crate::testing::alloc_count::count_allocs;
        with_u16_scratch(128, |s| s.fill(1));
        with_f32_scratch(128, |s| s.fill(1.0));
        let ((), n) = count_allocs(|| {
            with_u16_scratch(128, |s| {
                s[0] = 2;
            });
            with_f32_scratch(64, |s| {
                s[0] = 2.0;
            });
        });
        assert_eq!(n, 0, "warm scratch reuse must not touch the heap");
    }
}
