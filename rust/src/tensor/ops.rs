//! Matrix multiplication kernels: blocked, transposed variants, and a
//! std::thread row-parallel driver (no rayon offline). These are the
//! CPU hot paths behind the quantization solvers and the serving engine's
//! fp32 baseline.

use super::Mat;

/// Number of worker threads for the parallel matmul paths.
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// C = A @ B, blocked over K with a row-parallel outer loop.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dims");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A @ B into preallocated `c` (overwritten).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, n) = (a.rows, b.cols);
    let threads = if m * n * a.cols >= 1 << 18 { num_threads() } else { 1 };
    if threads <= 1 || m < 2 {
        matmul_rows(a, b, &mut c.data, 0, m);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let chunks: Vec<(usize, &mut [f32])> = {
        let mut out = Vec::new();
        let mut rest = c.data.as_mut_slice();
        let mut row = 0;
        while row < m {
            let take = rows_per.min(m - row);
            let (head, tail) = rest.split_at_mut(take * n);
            out.push((row, head));
            rest = tail;
            row += take;
        }
        out
    };
    std::thread::scope(|s| {
        for (row0, chunk) in chunks {
            s.spawn(move || {
                let nrows = chunk.len() / n;
                matmul_rows_into(a, b, chunk, row0, row0 + nrows);
            });
        }
    });
}

fn matmul_rows(a: &Mat, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
    matmul_rows_into(a, b, &mut c[r0 * b.cols..r1 * b.cols], r0, r1);
}

/// Compute rows [r0, r1) of A@B into `c` (length (r1-r0)*n), i-k-j order so
/// the inner loop is a contiguous axpy over B's rows (auto-vectorizes).
fn matmul_rows_into(a: &Mat, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
    let n = b.cols;
    let k = a.cols;
    c.fill(0.0);
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
        for kk in 0..k {
            let aik = arow[kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..kk * n + n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// C = A^T @ B without materializing A^T (A: k x m, B: k x n -> C: m x n).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn inner dims");
    let (m, n, k) = (a.cols, b.cols, a.rows);
    let mut c = Mat::zeros(m, n);
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..m {
            let aik = arow[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..i * n + n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// y = A @ x.
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    (0..a.rows).map(|i| dot(a.row(i), x)).collect()
}

/// Dense dot product (8-way unrolled for the serving hot path).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_property() {
        testing::check("matmul-vs-naive", 20, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            testing::assert_close(&matmul(&a, &b).data, &naive(&a, &b).data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn matmul_threaded_large() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(130, 70, 1.0, &mut rng);
        let b = Mat::randn(70, 90, 1.0, &mut rng);
        testing::assert_close(&matmul(&a, &b).data, &naive(&a, &b).data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(33, 17, 1.0, &mut rng);
        let b = Mat::randn(33, 21, 1.0, &mut rng);
        let want = matmul(&a.transpose(), &b);
        testing::assert_close(&matmul_tn(&a, &b).data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matvec_and_dot() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        let xs: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let want: f32 = xs.iter().map(|v| v * v).sum();
        assert_eq!(dot(&xs, &xs), want);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(12, 12, 1.0, &mut rng);
        let i = Mat::eye(12);
        testing::assert_close(&matmul(&a, &i).data, &a.data, 1e-6, 1e-6).unwrap();
        testing::assert_close(&matmul(&i, &a).data, &a.data, 1e-6, 1e-6).unwrap();
    }
}
