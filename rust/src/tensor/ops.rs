//! Matrix multiplication kernels: blocked, transposed variants, and
//! row-parallel drivers on the shared worker pool (no rayon offline).
//! These are the CPU hot paths behind the quantization solvers, the
//! Hessian accumulation, and the serving engine's fp32 baseline.

use super::Mat;

/// Below this many multiply-accumulates a kernel stays serial — the pool
/// round-trip would cost more than it saves.
const PAR_MIN_MACS: usize = 1 << 18;

/// Number of worker threads for the parallel kernels and the shared pool.
///
/// Honors a `GQ_THREADS` env override (>= 1; `GQ_THREADS=1` forces fully
/// serial execution) so CI and benches run deterministically-sized; falls
/// back to `available_parallelism`. Cached on first read — the global pool
/// is sized from this once per process.
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("GQ_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
    })
}

/// C = A @ B, blocked over K with a row-parallel outer loop.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dims");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A @ B into preallocated `c` (overwritten). Large products split
/// into row chunks that run as jobs on the shared worker pool.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, n) = (a.rows, b.cols);
    let threads = if m * n * a.cols >= PAR_MIN_MACS { num_threads() } else { 1 };
    if threads <= 1 || m < 2 {
        matmul_rows(a, b, &mut c.data, 0, m);
        return;
    }
    let jobs: Vec<_> = split_rows(&mut c.data, m, n, threads)
        .into_iter()
        .map(|(head, r0, r1)| move || matmul_rows_into(a, b, head, r0, r1))
        .collect();
    crate::coordinator::run_jobs(jobs, threads);
}

/// Partition the row-major buffer of an (m x n) matrix into per-worker row
/// chunks: `(chunk, r0, r1)` triples covering `[0, m)` in order. Shared by
/// every row-parallel kernel driver so chunk sizing stays consistent.
fn split_rows(c: &mut [f32], m: usize, n: usize, threads: usize) -> Vec<(&mut [f32], usize, usize)> {
    let rows_per = m.div_ceil(threads);
    let mut out = Vec::with_capacity(threads);
    let mut rest = c;
    let mut row = 0;
    while row < m {
        let take = rows_per.min(m - row);
        let (head, tail) = rest.split_at_mut(take * n);
        out.push((head, row, row + take));
        rest = tail;
        row += take;
    }
    out
}

fn matmul_rows(a: &Mat, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
    matmul_rows_into(a, b, &mut c[r0 * b.cols..r1 * b.cols], r0, r1);
}

/// Compute rows [r0, r1) of A@B into `c` (length (r1-r0)*n), i-k-j order so
/// the inner loop is a contiguous, branch-free axpy over B's rows (dense
/// inputs auto-vectorize; zero-skipping lives in [`matmul_sparse`] only).
fn matmul_rows_into(a: &Mat, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
    let n = b.cols;
    let k = a.cols;
    c.fill(0.0);
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
        for kk in 0..k {
            let aik = arow[kk];
            let brow = &b.data[kk * n..kk * n + n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// A @ B for inputs where A is mostly zeros: skips zero multiplicands
/// row-by-row. The zero test pessimizes dense inputs (it defeats
/// auto-vectorization of the inner axpy), so the dense kernels above never
/// branch — call this entry point only when A's sparsity is known to be
/// high (e.g. masked or pruned activations).
pub fn matmul_sparse(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dims");
    let n = b.cols;
    let mut c = Mat::zeros(a.rows, n);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..kk * n + n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// C = A^T @ B without materializing A^T (A: k x m, B: k x n -> C: m x n) —
/// the Hessian-accumulation kernel (H = X^T X and friends). Large products
/// run row-parallel on the shared worker pool.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    let (m, n, k) = (a.cols, b.cols, a.rows);
    let threads = if m * n * k >= PAR_MIN_MACS { num_threads() } else { 1 };
    matmul_tn_with(a, b, threads)
}

/// [`matmul_tn`] with an explicit worker count (1 = the serial tiled
/// kernel). Row partitioning does not change per-element accumulation
/// order, so results are bit-identical at any thread count; exposed for
/// the bit-identity tests and the serial-vs-pool bench rows.
pub fn matmul_tn_with(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn inner dims");
    let (m, n) = (a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let threads = threads.clamp(1, m);
    if threads <= 1 {
        matmul_tn_rows(a, b, &mut c.data, 0, m);
        return c;
    }
    let jobs: Vec<_> = split_rows(&mut c.data, m, n, threads)
        .into_iter()
        .map(|(head, r0, r1)| move || matmul_tn_rows(a, b, head, r0, r1))
        .collect();
    let n_jobs = jobs.len();
    crate::coordinator::run_jobs(jobs, n_jobs);
    c
}

/// Rows [r0, r1) of A^T @ B into `c` (length (r1-r0)*n). Output rows are
/// processed in tiles that stay cache-resident across the K sweep; the
/// inner loop is a contiguous, branch-free axpy over B's row.
fn matmul_tn_rows(a: &Mat, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
    let n = b.cols;
    let k = a.rows;
    c.fill(0.0);
    const ROW_TILE: usize = 32;
    let mut t0 = r0;
    while t0 < r1 {
        let t1 = (t0 + ROW_TILE).min(r1);
        for kk in 0..k {
            let arow = &a.row(kk)[t0..t1];
            let brow = b.row(kk);
            for (i, &aik) in arow.iter().enumerate() {
                let off = (t0 - r0 + i) * n;
                let crow = &mut c[off..off + n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
        t0 = t1;
    }
}

/// y = A @ x.
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    (0..a.rows).map(|i| dot(a.row(i), x)).collect()
}

/// y += a * x, elementwise in index order — the attention context
/// accumulation kernel. Dispatches to the explicit SIMD path when enabled
/// (`tensor::simd`); callers that rely on bit-identical results depend on
/// the in-order accumulation, which every dispatch level preserves.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    super::simd::axpy(y, a, x);
}

/// Dense dot product (8-way unrolled for the serving hot path). Dispatches
/// to the explicit SIMD path when enabled; all levels keep the same 8
/// accumulator lanes and reduction order, so results are bit-identical.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    super::simd::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_property() {
        testing::check("matmul-vs-naive", 20, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            testing::assert_close(&matmul(&a, &b).data, &naive(&a, &b).data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn matmul_threaded_large() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(130, 70, 1.0, &mut rng);
        let b = Mat::randn(70, 90, 1.0, &mut rng);
        testing::assert_close(&matmul(&a, &b).data, &naive(&a, &b).data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matmul_sparse_matches_dense() {
        let mut rng = Rng::new(7);
        let mut a = Mat::randn(23, 31, 1.0, &mut rng);
        // ~80% zeros.
        for v in a.data.iter_mut() {
            if rng.f32() < 0.8 {
                *v = 0.0;
            }
        }
        let b = Mat::randn(31, 19, 1.0, &mut rng);
        testing::assert_close(&matmul_sparse(&a, &b).data, &matmul(&a, &b).data, 1e-5, 1e-5)
            .unwrap();
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(33, 17, 1.0, &mut rng);
        let b = Mat::randn(33, 21, 1.0, &mut rng);
        let want = matmul(&a.transpose(), &b);
        testing::assert_close(&matmul_tn(&a, &b).data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matmul_tn_parallel_is_bit_identical_to_serial() {
        let mut rng = Rng::new(8);
        // m = 70 does not divide evenly across 4 chunks; k crosses the
        // 32-row tile boundary.
        let a = Mat::randn(65, 70, 1.0, &mut rng);
        let b = Mat::randn(65, 40, 1.0, &mut rng);
        let serial = matmul_tn_with(&a, &b, 1);
        for threads in [2, 3, 4, 7] {
            let par = matmul_tn_with(&a, &b, threads);
            assert_eq!(par.data, serial.data, "threads={threads}");
        }
        // And the tiled kernel still matches the naive transpose product.
        let want = matmul(&a.transpose(), &b);
        testing::assert_close(&serial.data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matmul_tn_large_goes_through_the_pool() {
        // Big enough to clear PAR_MIN_MACS so `matmul_tn` takes the
        // parallel path end to end.
        let mut rng = Rng::new(9);
        let a = Mat::randn(80, 96, 1.0, &mut rng);
        let b = Mat::randn(80, 64, 1.0, &mut rng);
        let got = matmul_tn(&a, &b);
        assert_eq!(got.data, matmul_tn_with(&a, &b, 1).data);
    }

    #[test]
    fn axpy_accumulates_in_order() {
        let mut y = vec![1.0f32, 2.0, 3.0];
        axpy(&mut y, 0.5, &[2.0, 4.0, 6.0]);
        assert_eq!(y, vec![2.0, 4.0, 6.0]);
        // Bitwise equivalence to the scalar loop (the attention invariant).
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..37).map(|_| rng.normal_f32()).collect();
        let mut a = vec![0.25f32; 37];
        let mut b = a.clone();
        axpy(&mut a, 0.3, &x);
        for (bv, &xv) in b.iter_mut().zip(&x) {
            *bv += 0.3 * xv;
        }
        assert_eq!(a, b);
    }

    #[test]
    fn matvec_and_dot() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        let xs: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let want: f32 = xs.iter().map(|v| v * v).sum();
        assert_eq!(dot(&xs, &xs), want);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(12, 12, 1.0, &mut rng);
        let i = Mat::eye(12);
        testing::assert_close(&matmul(&a, &i).data, &a.data, 1e-6, 1e-6).unwrap();
        testing::assert_close(&matmul(&i, &a).data, &a.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn num_threads_is_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
