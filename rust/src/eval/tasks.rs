//! Synthetic downstream tasks — the Table 12 zero-shot/few-shot analog.
//!
//! Real benchmarks (BoolQ, PIQA, ...) are unavailable offline; these tasks
//! exercise the same measurement machinery on the synthetic language:
//! * next-token accuracy: greedy top-1 vs the actual continuation,
//! * multiple-choice: the model must assign the lowest continuation NLL to
//!   the true continuation among k distractors (the lm-eval-harness scoring
//!   rule for multiple-choice tasks).

use crate::data::{Corpus, Split};
use crate::model::NativeModel;
use crate::util::Rng;

/// Greedy next-token accuracy over `n` positions.
pub fn next_token_accuracy(model: &NativeModel, corpus: &Corpus, split: Split, n: usize) -> f64 {
    let ctx = 32usize;
    let toks = corpus.tokens(split, n + ctx + 1);
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut state = model.new_state();
    let mut logits = model.step(&mut state, toks[0]);
    for t in 1..toks.len().min(n + ctx) {
        if t >= ctx {
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as u32)
                .unwrap();
            if argmax == toks[t] {
                correct += 1;
            }
            total += 1;
        }
        logits = model.step(&mut state, toks[t]);
    }
    correct as f64 / total.max(1) as f64
}

/// Multiple-choice: for `n` prompts of length `ctx`, the true `cont_len`
/// continuation competes against `k − 1` random distractor continuations;
/// score = fraction where the true continuation has the lowest NLL.
pub fn multiple_choice_accuracy(
    model: &NativeModel,
    corpus: &Corpus,
    split: Split,
    n: usize,
    k: usize,
    seed: u64,
) -> f64 {
    let ctx = 24usize;
    let cont_len = 8usize;
    let stream = corpus.tokens(split, (n + k) * (ctx + cont_len) + 1);
    let mut rng = Rng::new(seed ^ 0x7a5c);
    let mut correct = 0usize;
    for q in 0..n {
        let lo = q * (ctx + cont_len);
        let prompt = &stream[lo..lo + ctx];
        let true_cont = &stream[lo + ctx..lo + ctx + cont_len];
        let mut best_is_true = true;
        let true_nll = continuation_nll(model, prompt, true_cont);
        for _ in 0..k - 1 {
            // Distractors are real corpus continuations from *other*
            // contexts — plausible surface statistics, wrong context
            // (the hard negatives that make the task discriminative).
            let dlo = (n + rng.below(k)) * (ctx + cont_len) + rng.below(ctx);
            let distractor = &stream[dlo..dlo + cont_len];
            if continuation_nll(model, prompt, distractor) <= true_nll {
                best_is_true = false;
                break;
            }
        }
        if best_is_true {
            correct += 1;
        }
    }
    correct as f64 / n.max(1) as f64
}

/// Sum NLL of `cont` following `prompt`.
fn continuation_nll(model: &NativeModel, prompt: &[u32], cont: &[u32]) -> f64 {
    let mut state = model.new_state();
    let mut logits = vec![];
    for &t in prompt {
        logits = model.step(&mut state, t);
    }
    let mut nll = 0.0f64;
    for &t in cont {
        let row = &logits;
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse = max as f64
            + row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>().ln();
        nll += lse - row[t as usize] as f64;
        logits = model.step(&mut state, t);
    }
    nll
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::data::CorpusConfig;
    use crate::model::ParamStore;

    fn setup() -> (NativeModel, Corpus) {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let corpus = Corpus::new(CorpusConfig::for_vocab(cfg.vocab, 3));
        (NativeModel::from_params(&ps), corpus)
    }

    #[test]
    fn next_token_accuracy_in_unit_interval() {
        let (model, corpus) = setup();
        let acc = next_token_accuracy(&model, &corpus, Split::Eval, 40);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn mc_accuracy_beats_chance_even_untrained() {
        // Random distractors are uniform over the vocab; the corpus tokens
        // concentrate on pocket vocabularies, so even an untrained model
        // (uniform logits) ties, and any training signal pushes above 1/k.
        let (model, corpus) = setup();
        let acc = multiple_choice_accuracy(&model, &corpus, Split::Eval, 16, 4, 0);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn continuation_nll_additive() {
        let (model, _) = setup();
        let p = [1u32, 2, 3];
        let c = [4u32, 5];
        let nll = continuation_nll(&model, &p, &c);
        assert!(nll.is_finite() && nll > 0.0);
    }
}
