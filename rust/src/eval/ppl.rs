//! Perplexity evaluation via the fwd_loss / fwd_loss_qa* artifacts.
//!
//! All quantization methods are judged through the *same* compiled graph
//! with their (de)quantized weights as inputs, so no method gets a
//! different numeric path (the paper's evaluation discipline).

use anyhow::Result;

use crate::data::{Batcher, Corpus, Split};
use crate::model::ParamStore;
use crate::runtime::{Runtime, Value};

/// Perplexity over `n_batches` of `split`, through `artifact`
/// ("fwd_loss", "fwd_loss_qa4kv4", ...). Returns exp(mean NLL per token).
pub fn perplexity(
    rt: &Runtime,
    ps: &ParamStore,
    corpus: &Corpus,
    split: Split,
    n_batches: usize,
    artifact: &str,
) -> Result<f64> {
    let art = rt.artifact(artifact)?;
    let bc = rt.manifest.batch;
    let mut batcher = Batcher::new(corpus, split, bc, n_batches);
    let param_args = rt.param_args(ps);
    let mut loss_sum = 0.0f64;
    let mut tokens = 0usize;
    while let Some(toks) = batcher.next_batch() {
        let mut args = param_args.clone();
        args.push(Value::tokens(bc.batch, bc.seq, &toks));
        let outs = art.execute(&args)?;
        loss_sum += outs[0].scalar_f32()? as f64;
        tokens += bc.batch * (bc.seq - 1);
    }
    anyhow::ensure!(tokens > 0, "no eval batches");
    Ok((loss_sum / tokens as f64).exp())
}

/// Native-forward perplexity (no artifacts; used by serving-side checks and
/// fine-tuning evaluation on arbitrary token streams).
pub fn perplexity_native(model: &crate::model::NativeModel, tokens: &[u32], chunk: usize) -> f64 {
    let mut loss = 0.0f64;
    let mut count = 0usize;
    for win in tokens.chunks(chunk) {
        if win.len() < 2 {
            continue;
        }
        loss += model.loss_sum(win);
        count += win.len() - 1;
    }
    (loss / count.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::data::CorpusConfig;
    use crate::model::NativeModel;
    use crate::util::Rng;

    #[test]
    fn native_ppl_near_vocab_for_untrained() {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let model = NativeModel::from_params(&ps);
        let corpus = Corpus::new(CorpusConfig::for_vocab(cfg.vocab, 0));
        let toks = corpus.tokens(Split::Eval, 96);
        let ppl = perplexity_native(&model, &toks, 48);
        // Untrained model ≈ uniform ≈ vocab-size perplexity.
        assert!(ppl > 100.0 && ppl < 5.0 * cfg.vocab as f64, "{ppl}");
    }
}
