//! Evaluation: perplexity through the shared fwd artifacts (identical eval
//! path for every method — the paper's Wiki2/C4 columns) and synthetic
//! downstream tasks (the Table 12 zero-shot analog).

pub mod ppl;
pub mod tasks;

pub use ppl::perplexity;
pub use tasks::{multiple_choice_accuracy, next_token_accuracy};
