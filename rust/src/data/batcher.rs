//! Turns token streams into fixed-shape (batch, seq) i32 batches matching
//! the artifact input signatures.

use super::corpus::{Corpus, Split};
use crate::cfg::BatchConfig;

/// Deterministic batch iterator over a split.
pub struct Batcher<'a> {
    corpus: &'a Corpus,
    split: Split,
    bc: BatchConfig,
    cursor: usize,
    stream: Vec<u32>,
}

impl<'a> Batcher<'a> {
    /// Pre-generates enough tokens for `n_batches` batches.
    pub fn new(corpus: &'a Corpus, split: Split, bc: BatchConfig, n_batches: usize) -> Self {
        let need = bc.tokens() * n_batches;
        Batcher { corpus, split, bc, cursor: 0, stream: corpus.tokens(split, need) }
    }

    /// Next (batch*seq) i32 tokens in row-major (batch, seq) order, or None
    /// when the pre-generated stream is exhausted.
    pub fn next_batch(&mut self) -> Option<Vec<i32>> {
        let need = self.bc.tokens();
        if self.cursor + need > self.stream.len() {
            return None;
        }
        let out = self.stream[self.cursor..self.cursor + need]
            .iter()
            .map(|&t| t as i32)
            .collect();
        self.cursor += need;
        Some(out)
    }

    pub fn batch_config(&self) -> BatchConfig {
        self.bc
    }

    pub fn split(&self) -> Split {
        self.split
    }

    /// Restart from the beginning of the pre-generated stream.
    pub fn reset(&mut self) {
        self.cursor = 0;
    }

    pub fn remaining(&self) -> usize {
        (self.stream.len() - self.cursor) / self.bc.tokens()
    }

    pub fn corpus(&self) -> &Corpus {
        self.corpus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    #[test]
    fn yields_exact_batches_then_none() {
        let corpus = Corpus::new(CorpusConfig::for_vocab(128, 1));
        let bc = BatchConfig { batch: 2, seq: 16 };
        let mut b = Batcher::new(&corpus, Split::Train, bc, 3);
        assert_eq!(b.remaining(), 3);
        for _ in 0..3 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 32);
            assert!(batch.iter().all(|&t| (0..128).contains(&t)));
        }
        assert!(b.next_batch().is_none());
        b.reset();
        assert_eq!(b.remaining(), 3);
    }

    #[test]
    fn batches_are_deterministic() {
        let corpus = Corpus::new(CorpusConfig::for_vocab(128, 1));
        let bc = BatchConfig { batch: 2, seq: 8 };
        let mut b1 = Batcher::new(&corpus, Split::Calib, bc, 2);
        let mut b2 = Batcher::new(&corpus, Split::Calib, bc, 2);
        assert_eq!(b1.next_batch(), b2.next_batch());
    }
}
