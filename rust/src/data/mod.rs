//! Synthetic corpus substrate (RedPajama / WikiText2 / C4 stand-in).
//!
//! A seeded hidden-state Markov "language" with Zipfian token marginals:
//! structured enough for the MiniLlama models to learn real conditional
//! statistics (so the post-training "converged model" assumption behind the
//! Fisher approximation holds), deterministic so the Python build path and
//! Rust runtime never need to share data files. A temperature knob produces
//! the C4-analog out-of-calibration-distribution eval split (DESIGN.md §2).

pub mod batcher;
pub mod corpus;

pub use batcher::Batcher;
pub use corpus::{Corpus, CorpusConfig, Split};
