//! Markov-Zipf synthetic language generator.

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Hidden Markov states (each with its own token emission pocket).
    pub n_states: usize,
    /// Successor states per state.
    pub branch: usize,
    /// Tokens emitted per state (its "topic vocabulary").
    pub emit: usize,
    pub seed: u64,
}

impl CorpusConfig {
    pub fn for_vocab(vocab: usize, seed: u64) -> Self {
        // Low-entropy configuration: few hidden states with small, sharply
        // Zipf-weighted emission pockets. A trained MiniLlama reaches a
        // perplexity far below the unigram baseline, which is what makes
        // quantization damage (and the method ordering of Tables 1/3/4)
        // measurable at this scale.
        CorpusConfig {
            vocab,
            n_states: (vocab / 32).clamp(8, 64),
            branch: 4,
            emit: (vocab / 64).clamp(4, 32),
            seed,
        }
    }
}

/// Which data split to draw. Splits use disjoint RNG streams; `EvalShift`
/// additionally flattens the emission distribution (temperature > 1) to act
/// as the out-of-distribution eval set (the paper's C4 column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Calib,
    Eval,
    EvalShift,
}

impl Split {
    fn tag(self) -> u64 {
        match self {
            Split::Train => 0x7261696e,
            Split::Calib => 0x63616c69,
            Split::Eval => 0x6576616c,
            Split::EvalShift => 0x65763273,
        }
    }

    fn temperature(self) -> f64 {
        match self {
            Split::EvalShift => 1.8,
            _ => 1.0,
        }
    }
}

/// The generator. Construction builds the state machine (transition and
/// emission tables); `tokens(split, n)` streams deterministic token ids.
pub struct Corpus {
    pub cfg: CorpusConfig,
    /// transitions[s] = successor state ids (Zipf-weighted by rank).
    transitions: Vec<Vec<usize>>,
    /// emissions[s] = token ids this state can emit (Zipf-weighted by rank).
    emissions: Vec<Vec<u32>>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Rng::new(cfg.seed ^ 0xC0A9F5);
        let mut transitions = Vec::with_capacity(cfg.n_states);
        let mut emissions = Vec::with_capacity(cfg.n_states);
        for _ in 0..cfg.n_states {
            let succ: Vec<usize> = (0..cfg.branch).map(|_| rng.below(cfg.n_states)).collect();
            transitions.push(succ);
            let toks: Vec<u32> = (0..cfg.emit).map(|_| rng.below(cfg.vocab) as u32).collect();
            emissions.push(toks);
        }
        Corpus { cfg, transitions, emissions }
    }

    /// Zipf rank weights 1/(r+1)^alpha with optional temperature flattening.
    fn zipf_weights(n: usize, temperature: f64) -> Vec<f64> {
        (0..n).map(|r| (1.0 / (r as f64 + 1.0)).powf(1.3 / temperature)).collect()
    }

    /// Deterministic token stream for a split.
    pub fn tokens(&self, split: Split, n: usize) -> Vec<u32> {
        let mut rng = Rng::new(self.cfg.seed ^ split.tag().wrapping_mul(0x9E3779B97F4A7C15));
        let temp = split.temperature();
        let tw = Self::zipf_weights(self.cfg.branch, 1.0);
        let ew = Self::zipf_weights(self.cfg.emit, temp);
        let mut state = rng.below(self.cfg.n_states);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let toks = &self.emissions[state];
            out.push(toks[rng.weighted(&ew)]);
            state = self.transitions[state][rng.weighted(&tw)];
        }
        out
    }

    /// Empirical unigram distribution over `n` sampled tokens (diagnostics).
    pub fn unigram(&self, split: Split, n: usize) -> Vec<f64> {
        let mut counts = vec![0usize; self.cfg.vocab];
        for t in self.tokens(split, n) {
            counts[t as usize] += 1;
        }
        counts.into_iter().map(|c| c as f64 / n as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig::for_vocab(512, 42))
    }

    #[test]
    fn deterministic_per_split() {
        let c = corpus();
        assert_eq!(c.tokens(Split::Train, 256), c.tokens(Split::Train, 256));
        assert_ne!(c.tokens(Split::Train, 256), c.tokens(Split::Eval, 256));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = corpus();
        for t in c.tokens(Split::Calib, 4096) {
            assert!((t as usize) < 512);
        }
    }

    #[test]
    fn marginals_are_skewed_zipf_like() {
        let c = corpus();
        let mut u = c.unigram(Split::Train, 50_000);
        u.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // Top-32 tokens should carry well over a uniform share of the mass.
        let top: f64 = u[..32].iter().sum();
        assert!(top > 0.2, "top mass {top}");
    }

    #[test]
    fn shifted_split_changes_distribution() {
        let c = corpus();
        let a = c.unigram(Split::Eval, 40_000);
        let b = c.unigram(Split::EvalShift, 40_000);
        let l1: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(l1 > 0.05, "distribution shift too small: {l1}");
    }

    #[test]
    fn stream_has_structure() {
        // Bigram entropy must be lower than unigram entropy (Markov signal).
        let c = corpus();
        let toks = c.tokens(Split::Train, 60_000);
        let v = 512usize;
        let mut uni = vec![0f64; v];
        for &t in &toks {
            uni[t as usize] += 1.0;
        }
        let n = toks.len() as f64;
        let h_uni: f64 = uni
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| {
                let p = c / n;
                -p * p.ln()
            })
            .sum();
        use std::collections::HashMap;
        let mut big: HashMap<(u32, u32), f64> = HashMap::new();
        let mut prev_count: HashMap<u32, f64> = HashMap::new();
        for w in toks.windows(2) {
            *big.entry((w[0], w[1])).or_default() += 1.0;
            *prev_count.entry(w[0]).or_default() += 1.0;
        }
        let h_cond: f64 = big
            .iter()
            .map(|(&(a, _), &c)| {
                let p_joint = c / (n - 1.0);
                let p_cond = c / prev_count[&a];
                -p_joint * p_cond.ln()
            })
            .sum();
        assert!(
            h_cond < 0.8 * h_uni,
            "conditional entropy {h_cond} not below unigram {h_uni}"
        );
    }
}
