//! Orthogonal residual-stream rotations (QuaRot / SpinQuant substrate):
//! randomized Hadamard transforms R = H·D/√d with D a random ±1 diagonal.
//! All MiniLlama hidden sizes are powers of two, so the fast Walsh–Hadamard
//! transform applies directly.

use crate::tensor::Mat;
use crate::util::Rng;

/// In-place fast Walsh–Hadamard transform (unnormalized). len power of two.
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// A randomized-Hadamard rotation R = Hd·D/√d acting on row vectors as
/// x ↦ x·R. Orthogonal: R·Rᵀ = I.
#[derive(Debug, Clone)]
pub struct HadamardRotation {
    pub signs: Vec<f32>, // ±1
}

impl HadamardRotation {
    pub fn random(d: usize, rng: &mut Rng) -> Self {
        assert!(d.is_power_of_two());
        HadamardRotation {
            signs: (0..d).map(|_| if rng.f32() < 0.5 { -1.0 } else { 1.0 }).collect(),
        }
    }

    pub fn identity_signs(d: usize) -> Self {
        HadamardRotation { signs: vec![1.0; d] }
    }

    pub fn dim(&self) -> usize {
        self.signs.len()
    }

    /// y = R x (column-vector action): R x = Hd(D x)/√d.
    pub fn apply(&self, x: &mut [f32]) {
        let d = self.dim();
        assert_eq!(x.len(), d);
        for (v, &s) in x.iter_mut().zip(&self.signs) {
            *v *= s;
        }
        fwht(x);
        let norm = 1.0 / (d as f32).sqrt();
        for v in x.iter_mut() {
            *v *= norm;
        }
    }

    /// y = Rᵀ x: Rᵀ x = D·Hd(x)/√d.
    pub fn apply_t(&self, x: &mut [f32]) {
        let d = self.dim();
        assert_eq!(x.len(), d);
        fwht(x);
        let norm = 1.0 / (d as f32).sqrt();
        for (v, &s) in x.iter_mut().zip(&self.signs) {
            *v = *v * norm * s;
        }
    }

    /// W' = Rᵀ W (reading linears: input arrives pre-rotated).
    pub fn rotate_left_t(&self, w: &Mat) -> Mat {
        assert_eq!(w.rows, self.dim());
        let mut out = w.clone();
        let mut col = vec![0.0f32; w.rows];
        for j in 0..w.cols {
            for i in 0..w.rows {
                col[i] = w.at(i, j);
            }
            self.apply_t(&mut col);
            out.set_col(j, &col);
        }
        out
    }

    /// W' = W R (writing linears: output leaves rotated).
    /// Row convention: (W R)ᵢ. = Rᵀ·(Wᵢ.)ᵀ.
    pub fn rotate_right(&self, w: &Mat) -> Mat {
        assert_eq!(w.cols, self.dim());
        let mut out = w.clone();
        for i in 0..w.rows {
            let row = out.row_mut(i);
            // row' = row · R  ⇔ apply Rᵀ to the row as a column vector? No:
            // (row·R)_j = Σ_k row_k R_kj = (Rᵀ row)_j.
            let mut v = row.to_vec();
            self.apply_t_row(&mut v);
            row.copy_from_slice(&v);
        }
        out
    }

    /// Helper: y_j = Σ_k x_k R_kj = (Rᵀ x)_j — same as apply_t? No: apply_t
    /// computes Rᵀx = D·H·x/√d while Σ_k x_k R_kj needs R's columns:
    /// R = H·D/√d so R_kj = (H D)_kj/√d = H_kj·s_j/√d and
    /// (xᵀR)_j = s_j · (H x)_j / √d — i.e. fwht THEN signs.
    fn apply_t_row(&self, x: &mut [f32]) {
        let d = self.dim();
        fwht(x);
        let norm = 1.0 / (d as f32).sqrt();
        for (v, &s) in x.iter_mut().zip(&self.signs) {
            *v = *v * norm * s;
        }
    }
}

/// Activation-outlier metric used by the SpinQuant-lite rotation search:
/// mean over rows of (max |x| / rms(x)) — the quantity rotations reduce.
pub fn outlier_score(x: &Mat) -> f64 {
    let mut total = 0.0f64;
    for i in 0..x.rows {
        let row = x.row(i);
        let max = row.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
        let rms = (row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / row.len() as f64)
            .sqrt()
            .max(1e-12);
        total += max / rms;
    }
    total / x.rows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn fwht_involution_up_to_n() {
        let mut rng = Rng::new(0);
        let orig: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let mut x = orig.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a / 16.0 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_is_orthogonal() {
        testing::check("rotation-orthogonal", 10, |rng| {
            let d = 32;
            let r = HadamardRotation::random(d, rng);
            let mut x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let norm0: f32 = x.iter().map(|v| v * v).sum();
            r.apply(&mut x);
            let norm1: f32 = x.iter().map(|v| v * v).sum();
            testing::ensure((norm0 - norm1).abs() < 1e-3 * norm0, "norm not preserved")?;
            // Rᵀ undoes R.
            let mut y = x.clone();
            r.apply_t(&mut y);
            let mut x0: Vec<f32> = vec![0.0; d];
            // reconstruct original by applying R then Rᵀ to a fresh copy
            let orig: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            x0.copy_from_slice(&orig);
            r.apply(&mut x0);
            r.apply_t(&mut x0);
            testing::assert_close(&x0, &orig, 1e-4, 1e-4)
        });
    }

    #[test]
    fn rotate_left_then_input_rotation_is_identity_map() {
        // x·R @ (Rᵀ W) == x @ W for all x.
        let mut rng = Rng::new(1);
        let d = 16;
        let r = HadamardRotation::random(d, &mut rng);
        let w = Mat::randn(d, 8, 1.0, &mut rng);
        let wr = r.rotate_left_t(&w);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        // x·R (row vector): via apply_t_row semantics == fwht+signs
        let mut xr = x.clone();
        fwht(&mut xr);
        let norm = 1.0 / (d as f32).sqrt();
        for (v, &s) in xr.iter_mut().zip(&r.signs) {
            *v = *v * norm * s;
        }
        let want = crate::tensor::ops::matvec(&w.transpose(), &x);
        let got = crate::tensor::ops::matvec(&wr.transpose(), &xr);
        testing::assert_close(&got, &want, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn rotate_right_matches_explicit_matrix() {
        let mut rng = Rng::new(2);
        let d = 8;
        let r = HadamardRotation::random(d, &mut rng);
        // Build explicit R: columns R e_j? Use apply on basis vectors:
        // R e_j gives column j of R.
        let mut rm = Mat::zeros(d, d);
        for j in 0..d {
            let mut e = vec![0.0f32; d];
            e[j] = 1.0;
            r.apply(&mut e);
            rm.set_col(j, &e);
        }
        let w = Mat::randn(3, d, 1.0, &mut rng);
        let want = crate::tensor::ops::matmul(&w, &rm);
        let got = r.rotate_right(&w);
        testing::assert_close(&got.data, &want.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn rotation_reduces_outliers_on_spiky_activations() {
        let mut rng = Rng::new(3);
        let d = 64;
        // Spiky activations: one huge channel.
        let mut x = Mat::randn(32, d, 0.1, &mut rng);
        for i in 0..32 {
            *x.at_mut(i, 7) = 20.0;
        }
        let before = outlier_score(&x);
        let r = HadamardRotation::random(d, &mut rng);
        let mut xr = x.clone();
        for i in 0..32 {
            let mut row = xr.row(i).to_vec();
            fwht(&mut row);
            let norm = 1.0 / (d as f32).sqrt();
            for (v, &s) in row.iter_mut().zip(&r.signs) {
                *v = *v * norm * s;
            }
            xr.row_mut(i).copy_from_slice(&row);
        }
        let after = outlier_score(&xr);
        assert!(after < before * 0.5, "outliers not reduced: {before} -> {after}");
    }
}
