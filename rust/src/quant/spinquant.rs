//! SpinQuant-style weight-and-activation quantization pipeline.
//!
//! Steps (QuaRot/SpinQuant, CPU-scale — DESIGN.md §2 substitution):
//!  1. fold RMSNorm scales into adjacent weights (required for rotation
//!     commutation),
//!  2. rotate the residual stream with a randomized-Hadamard R; QuaRot uses
//!     a random R, SpinQuant *optimizes* R — we search N candidates and
//!     keep the one minimizing the activation outlier score on calibration
//!     tokens (a search stand-in for Cayley-SGD),
//!  3. quantize weights with GPTQ (optionally per-group GuidedQuant
//!     Hessians) — done by the coordinator,
//!  4. evaluate through the `fwd_loss_qa*` artifacts which fake-quantize
//!     activations and KV cache in-graph.
//!
//! The rotated model computes the *same function* in fp32 (tested below),
//! so perplexity differences after step 4 are attributable to quantization.

use crate::model::{NativeModel, ParamStore};
use crate::util::Rng;

use super::rotation::{outlier_score, HadamardRotation};

/// Fold every RMSNorm's gamma into the linears it feeds; gammas become 1.
/// attn_norm -> wq/wk/wv; mlp_norm -> wgate/wup; final_norm -> head.
pub fn fold_norms(ps: &mut ParamStore) {
    let n_layers = ps.cfg.n_layers;
    for l in 0..n_layers {
        let p = format!("layers.{l}.");
        for (norm, targets) in [
            (format!("{p}attn_norm"), vec![format!("{p}wq"), format!("{p}wk"), format!("{p}wv")]),
            (format!("{p}mlp_norm"), vec![format!("{p}wgate"), format!("{p}wup")]),
        ] {
            let gamma = ps.get(&norm).data.clone();
            for t in targets {
                let w = ps.get_mut(&t);
                for i in 0..w.rows {
                    let g = gamma[i];
                    for v in w.row_mut(i) {
                        *v *= g;
                    }
                }
            }
            let gm = ps.get_mut(&norm);
            for v in gm.data.iter_mut() {
                *v = 1.0;
            }
        }
    }
    let gamma = ps.get("final_norm").data.clone();
    let head = ps.get_mut("head");
    for i in 0..head.rows {
        let g = gamma[i];
        for v in head.row_mut(i) {
            *v *= g;
        }
    }
    let gm = ps.get_mut("final_norm");
    for v in gm.data.iter_mut() {
        *v = 1.0;
    }
}

/// Apply residual rotation R (requires folded norms): function-preserving.
pub fn rotate_residual(ps: &mut ParamStore, r: &HadamardRotation) {
    assert_eq!(r.dim(), ps.cfg.d_model);
    // Embedding rows live in the residual space: emb' = emb · R.
    let emb = r.rotate_right(ps.get("tok_emb"));
    ps.set("tok_emb", emb);
    for l in 0..ps.cfg.n_layers {
        let p = format!("layers.{l}.");
        for name in ["wq", "wk", "wv", "wgate", "wup"] {
            let w = r.rotate_left_t(ps.get(&format!("{p}{name}")));
            ps.set(&format!("{p}{name}"), w);
        }
        for name in ["wo", "wdown"] {
            let w = r.rotate_right(ps.get(&format!("{p}{name}")));
            ps.set(&format!("{p}{name}"), w);
        }
    }
    let head = r.rotate_left_t(ps.get("head"));
    ps.set("head", head);
}

/// Measure the activation outlier score of a model over sample tokens:
/// captures the inputs of every linear via the native forward.
pub fn model_outlier_score(ps: &ParamStore, tokens: &[u32]) -> f64 {
    let model = NativeModel::from_params(ps);
    let xs = model.record_linear_inputs(tokens);
    let mut total = 0.0;
    for x in &xs {
        total += outlier_score(x);
    }
    total / xs.len().max(1) as f64
}

/// SpinQuant-lite rotation search: fold norms, then keep the best of
/// `candidates` random rotations by outlier score (candidate 0 is the
/// identity-sign rotation = plain Hadamard = QuaRot).
pub fn spinquant_rotate(
    ps: &mut ParamStore,
    tokens: &[u32],
    candidates: usize,
    rng: &mut Rng,
) -> (HadamardRotation, f64, f64) {
    fold_norms(ps);
    let before = model_outlier_score(ps, tokens);
    let d = ps.cfg.d_model;
    let mut best: Option<(HadamardRotation, f64)> = None;
    for c in 0..candidates.max(1) {
        let r = if c == 0 {
            HadamardRotation::identity_signs(d)
        } else {
            HadamardRotation::random(d, rng)
        };
        let mut trial = ps.clone();
        rotate_residual(&mut trial, &r);
        let score = model_outlier_score(&trial, tokens);
        if best.as_ref().map(|(_, s)| score < *s).unwrap_or(true) {
            best = Some((r, score));
        }
    }
    let (r, after) = best.unwrap();
    rotate_residual(ps, &r);
    (r, before, after)
}

/// Symmetric per-token fake-quant of a vector (matches the python
/// `_fake_quant_sym` used in the fwd_loss_qa artifacts).
pub fn fake_quant_sym(x: &mut [f32], bits: u32) {
    if bits >= 16 {
        return;
    }
    let qmax = (1i64 << (bits - 1)) as f32 - 1.0;
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let scale = amax / qmax;
    for v in x.iter_mut() {
        *v = (*v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::testing;

    fn setup() -> (ParamStore, Vec<u32>) {
        let (cfg, _) = preset("tiny");
        let mut rng = Rng::new(0);
        let ps = ParamStore::init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..24).map(|_| rng.below(cfg.vocab) as u32).collect();
        (ps, toks)
    }

    #[test]
    fn fold_norms_preserves_function() {
        let (ps, toks) = setup();
        let before = NativeModel::from_params(&ps).forward_sequence(&toks);
        let mut folded = ps.clone();
        fold_norms(&mut folded);
        let after = NativeModel::from_params(&folded).forward_sequence(&toks);
        testing::assert_close(&after.data, &before.data, 2e-3, 2e-3).unwrap();
        assert!(folded.get("layers.0.attn_norm").data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn rotation_preserves_function() {
        let (ps, toks) = setup();
        let before = NativeModel::from_params(&ps).forward_sequence(&toks);
        let mut rotated = ps.clone();
        fold_norms(&mut rotated);
        let r = HadamardRotation::random(ps.cfg.d_model, &mut Rng::new(5));
        rotate_residual(&mut rotated, &r);
        let after = NativeModel::from_params(&rotated).forward_sequence(&toks);
        testing::assert_close(&after.data, &before.data, 5e-3, 5e-3).unwrap();
    }

    #[test]
    fn spinquant_search_does_not_increase_outliers() {
        let (mut ps, toks) = setup();
        let mut rng = Rng::new(1);
        let (_r, _before, after) = spinquant_rotate(&mut ps, &toks, 3, &mut rng);
        // The chosen rotation's score is the minimum over candidates, which
        // includes plain Hadamard; sanity: finite positive score.
        assert!(after.is_finite() && after >= 1.0);
    }

    #[test]
    fn fake_quant_matches_python_semantics() {
        let mut x = vec![0.1f32, -0.5, 0.25, 1.0];
        fake_quant_sym(&mut x, 4);
        // qmax = 7, scale = 1/7; values round to k/7.
        for v in &x {
            let k = v * 7.0;
            assert!((k - k.round()).abs() < 1e-4, "{v}");
        }
        let mut y = vec![0.3f32, -0.7];
        let orig = y.clone();
        fake_quant_sym(&mut y, 16);
        assert_eq!(y, orig);
    }
}
