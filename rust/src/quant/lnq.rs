//! LNQ — Layer-wise Non-uniform Quantization (the paper's Algorithm 2).
//!
//! Alternating minimization over per-output-channel codebooks c^(j) and
//! assignments P^(j):
//!   * codebook step: exact closed form c* = (P^T H P + λI)^{-1} P^T H w
//!     (Eq. 9; λ = 1e-7 damping per §4.2),
//!   * assignment step: K cycles of cyclic CD with precomputation + lazy
//!     batch updates (Algorithms 3/4, `quant::cd`).
//! Initialized from a weighted k-means on each channel (SqueezeLLM
//! assignments when a sensitivity matrix is supplied, else diag(H) weights).
//!
//! Both steps are descent steps, so LNQ monotonically decreases the
//! objective (Prop 4.1) — enforced by property tests below.

use anyhow::Result;

use crate::linalg::{solve_damped_ls, DEFAULT_DAMP};
use crate::tensor::{ops::matmul, Mat};
use crate::util::Rng;

use super::cd::{cd_inplace, CdConfig};
use super::grid::{avg_bits_scalar, LutGrid};
use super::kmeans1d::lloyd;
use super::{LayerQuantizer, QuantResult};

#[derive(Debug, Clone)]
pub struct Lnq {
    pub bits: u32,
    /// Alternating iterations T (paper: 2 for 7B/13B, 1 for 70B).
    pub t_iters: usize,
    pub cd: CdConfig,
    /// Optional per-weight sensitivity (d_in × d_out diag Fisher) for the
    /// SqueezeLLM-style initialization; falls back to diag(H).
    pub sensitivity: Option<Mat>,
    pub seed: u64,
}

impl Lnq {
    pub fn new(bits: u32) -> Self {
        Lnq { bits, t_iters: 2, cd: CdConfig::default(), sensitivity: None, seed: 0 }
    }

    pub fn with_sensitivity(mut self, s: Mat) -> Self {
        self.sensitivity = Some(s);
        self
    }
}

/// Weighted-k-means initial codebooks + codes, one codebook per column.
pub fn init_codebooks(
    w: &Mat,
    weights_per_col: impl Fn(usize) -> Vec<f32>,
    m: usize,
    rng: &mut Rng,
) -> (Mat, Vec<u16>) {
    let d_in = w.rows;
    let d_out = w.cols;
    let mut codebooks = Mat::zeros(d_out, m);
    let mut codes = vec![0u16; d_in * d_out];
    for j in 0..d_out {
        let col = w.col(j);
        let ws = weights_per_col(j);
        let km = lloyd(&col, &ws, m, 30, rng);
        // Pad centers if k-means collapsed (fewer distinct points than m).
        for q in 0..m {
            *codebooks.at_mut(j, q) = *km.centers.get(q).unwrap_or(km.centers.last().unwrap());
        }
        for i in 0..d_in {
            codes[i * d_out + j] = km.assign[i];
        }
    }
    (codebooks, codes)
}

/// Exact closed-form codebook update for every column (Eq. 9).
/// codes are row-major (d_in × d_out); codebooks is (d_out × m), updated
/// in place. Empty codebook entries keep their previous value.
pub fn codebook_ls_update(h: &Mat, w: &Mat, codes: &[u16], codebooks: &mut Mat) -> Result<()> {
    let d_in = w.rows;
    let d_out = w.cols;
    let m = codebooks.cols;
    let hw = matmul(h, w); // (d_in × d_out)

    // Parallelize across output channels (the paper notes each column is
    // independent); chunk columns over threads.
    let threads = crate::tensor::ops::num_threads().min(d_out).max(1);
    let chunk = d_out.div_ceil(threads);
    let results: Vec<Result<Vec<(usize, Vec<f64>)>>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(d_out);
            if lo >= hi {
                break;
            }
            let hw = &hw;
            let codebooks = &*codebooks;
            handles.push(s.spawn(move || {
                let mut out = Vec::new();
                let mut mrows = vec![0.0f64; m * d_in];
                for j in lo..hi {
                    // M[q, :] = Σ_{i: code(i,j)=q} H[i, :]
                    mrows.fill(0.0);
                    let mut counts = vec![0usize; m];
                    for i in 0..d_in {
                        let q = codes[i * d_out + j] as usize;
                        counts[q] += 1;
                        let hrow = h.row(i);
                        let mrow = &mut mrows[q * d_in..(q + 1) * d_in];
                        for (mv, &hv) in mrow.iter_mut().zip(hrow) {
                            *mv += hv as f64;
                        }
                    }
                    // A[q, r] = Σ_{k: code(k,j)=r} M[q, k];  b[q] = Σ_{i∈q} (Hw)_ij
                    let mut a = vec![0.0f64; m * m];
                    let mut b = vec![0.0f64; m];
                    for k in 0..d_in {
                        let r = codes[k * d_out + j] as usize;
                        for q in 0..m {
                            a[q * m + r] += mrows[q * d_in + k];
                        }
                    }
                    for i in 0..d_in {
                        let q = codes[i * d_out + j] as usize;
                        b[q] += hw.at(i, j) as f64;
                    }
                    let sol = solve_damped_ls(&a, &b, m, DEFAULT_DAMP)?;
                    // Keep previous centers for empty codes.
                    let mut newc = vec![0.0f64; m];
                    for q in 0..m {
                        newc[q] = if counts[q] > 0 { sol[q] } else { codebooks.at(j, q) as f64 };
                    }
                    out.push((j, newc));
                }
                Ok(out)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for res in results {
        for (j, newc) in res? {
            for q in 0..m {
                *codebooks.at_mut(j, q) = newc[q] as f32;
            }
        }
    }
    Ok(())
}

/// Decode codes through per-column codebooks.
pub fn decode(codes: &[u16], codebooks: &Mat, d_in: usize) -> Mat {
    let d_out = codebooks.rows;
    Mat::from_fn(d_in, d_out, |i, j| codebooks.at(j, codes[i * d_out + j] as usize))
}

/// Run LNQ (Algorithm 2) against Hessian `h`. Returns codes + codebooks.
pub fn lnq_quantize(h: &Mat, w: &Mat, cfg: &Lnq) -> Result<QuantResult> {
    let d_in = w.rows;
    let d_out = w.cols;
    assert_eq!((h.rows, h.cols), (d_in, d_in));
    let m = 1usize << cfg.bits;
    let mut rng = Rng::new(cfg.seed ^ 0x4c4e51);

    let diag_h = h.diag();
    let weights = |j: usize| -> Vec<f32> {
        match &cfg.sensitivity {
            Some(s) => (0..d_in).map(|i| s.at(i, j).max(1e-12)).collect(),
            None => diag_h.iter().map(|&v| v.max(1e-12)).collect(),
        }
    };
    let (mut codebooks, mut codes) = init_codebooks(w, weights, m, &mut rng);

    for _t in 0..cfg.t_iters {
        // Codebook step (optimal closed form).
        codebook_ls_update(h, w, &codes, &mut codebooks)?;
        let mut w_hat = decode(&codes, &codebooks, d_in);
        // Assignment step (K cycles of CD, descent with feasible init).
        let grid = LutGrid::new(codebooks.clone());
        cd_inplace(h, w, &mut w_hat, &mut codes, &grid, cfg.cd);
        // CD only changes codes; decode happens next iteration/final step.
    }
    // Final codebook refit (Algorithm 2, line 13–14).
    codebook_ls_update(h, w, &codes, &mut codebooks)?;
    let w_hat = decode(&codes, &codebooks, d_in);

    Ok(QuantResult {
        w_hat,
        codes: Some(codes),
        codebooks: Some(codebooks),
        avg_bits: avg_bits_scalar(d_in, d_out, cfg.bits),
    })
}

impl LayerQuantizer for Lnq {
    fn quantize(&self, h: &Mat, w: &Mat) -> Result<QuantResult> {
        lnq_quantize(h, w, self)
    }

    fn name(&self) -> &'static str {
        "lnq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::rtn_quantize;
    use crate::quant::objective::proxy_loss;
    use crate::tensor::ops::matmul_tn;
    use crate::testing;

    fn problem(rng: &mut Rng, d_in: usize, d_out: usize) -> (Mat, Mat) {
        let x = Mat::randn(d_in * 2, d_in, 1.0, rng);
        let h = matmul_tn(&x, &x);
        let w = Mat::randn(d_in, d_out, 1.0, rng);
        (h, w)
    }

    #[test]
    fn lnq_monotone_descent_prop_4_1() {
        // The paper's Proposition 4.1: each alternating iteration does not
        // increase the objective. We track it across manual iterations.
        testing::check("lnq-prop-4.1", 6, |rng| {
            let d_in = 12 + rng.below(12);
            let d_out = 2 + rng.below(4);
            let (h, w) = problem(rng, d_in, d_out);
            let m = 4usize;
            let diag = h.diag();
            let (mut cbs, mut codes) =
                init_codebooks(&w, |_| diag.iter().map(|&v| v.max(1e-9)).collect(), m, rng);
            let mut prev = f64::INFINITY;
            for _ in 0..3 {
                codebook_ls_update(&h, &w, &codes, &mut cbs).map_err(|e| e.to_string())?;
                let mut w_hat = decode(&codes, &cbs, w.rows);
                let after_cb = proxy_loss(&h, &w, &w_hat);
                testing::ensure(
                    after_cb <= prev + 1e-4 * (1.0 + prev.abs().min(1e12)),
                    format!("codebook step rose {prev} -> {after_cb}"),
                )?;
                let grid = LutGrid::new(cbs.clone());
                cd_inplace(&h, &w, &mut w_hat, &mut codes, &grid, CdConfig::default());
                let after_cd = proxy_loss(&h, &w, &w_hat);
                testing::ensure(
                    after_cd <= after_cb + 1e-4 * (1.0 + after_cb.abs()),
                    format!("cd step rose {after_cb} -> {after_cd}"),
                )?;
                prev = after_cd;
            }
            Ok(())
        });
    }

    #[test]
    fn lnq_beats_rtn_and_runs_end_to_end() {
        let mut rng = Rng::new(1);
        let (h, w) = problem(&mut rng, 32, 8);
        let res = lnq_quantize(&h, &w, &Lnq::new(2)).unwrap();
        let rtn = rtn_quantize(&w, 2);
        let lnq_obj = proxy_loss(&h, &w, &res.w_hat);
        let rtn_obj = proxy_loss(&h, &w, &rtn.w_hat);
        assert!(lnq_obj < rtn_obj, "lnq {lnq_obj} !< rtn {rtn_obj}");
        assert!(res.avg_bits >= 2.0);
    }

    #[test]
    fn codebook_update_is_optimal_for_fixed_codes() {
        // After the LS update, perturbing any single codebook entry must not
        // decrease the objective (first-order optimality, small damping).
        let mut rng = Rng::new(2);
        let (h, w) = problem(&mut rng, 10, 2);
        let m = 4;
        let diag = h.diag();
        let (mut cbs, codes) =
            init_codebooks(&w, |_| diag.iter().map(|&v| v.max(1e-9)).collect(), m, &mut rng);
        codebook_ls_update(&h, &w, &codes, &mut cbs).unwrap();
        let base = proxy_loss(&h, &w, &decode(&codes, &cbs, w.rows));
        for j in 0..2 {
            for q in 0..m {
                for delta in [-1e-3f32, 1e-3] {
                    let mut cbs2 = cbs.clone();
                    *cbs2.at_mut(j, q) += delta;
                    let obj = proxy_loss(&h, &w, &decode(&codes, &cbs2, w.rows));
                    assert!(obj >= base - 1e-5 * (1.0 + base), "perturb ({j},{q}) improved");
                }
            }
        }
    }

    #[test]
    fn decode_round_trips_codes() {
        let cbs = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let codes = vec![0u16, 1, 1, 0];
        let w = decode(&codes, &cbs, 2);
        assert_eq!(w.data, vec![1.0, 4.0, 2.0, 3.0]);
    }

    #[test]
    fn sensitivity_init_changes_outcome_gracefully() {
        let mut rng = Rng::new(4);
        let (h, w) = problem(&mut rng, 16, 3);
        let sens = Mat::from_fn(16, 3, |i, _| if i < 4 { 100.0 } else { 0.01 });
        let res = lnq_quantize(&h, &w, &Lnq::new(3).with_sensitivity(sens)).unwrap();
        assert!(res.w_hat.data.iter().all(|v| v.is_finite()));
    }
}
