//! Vector-quantization substrate: weighted k-means in R^dim over weight
//! vectors formed from `dim` consecutive rows of one output channel.
//! Used by GPTVQ 2D/4D and (as initialization) the trellis quantizer.

use crate::tensor::Mat;
use crate::util::Rng;

/// Weighted k-means over `points` (n × dim flattened), weights per point.
#[derive(Debug, Clone)]
pub struct KMeansVq {
    /// k × dim centroids.
    pub centers: Vec<f32>,
    pub dim: usize,
    pub assign: Vec<u16>,
    pub objective: f64,
}

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum()
}

fn assign_nearest(points: &[f32], dim: usize, centers: &[f32]) -> Vec<u16> {
    let n = points.len() / dim;
    let k = centers.len() / dim;
    (0..n)
        .map(|i| {
            let p = &points[i * dim..(i + 1) * dim];
            let mut best = 0u16;
            let mut bd = f32::INFINITY;
            for q in 0..k {
                let d = dist2(p, &centers[q * dim..(q + 1) * dim]);
                if d < bd {
                    bd = d;
                    best = q as u16;
                }
            }
            best
        })
        .collect()
}

/// Lloyd with k-means++ seeding in R^dim.
pub fn lloyd_vq(points: &[f32], dim: usize, weights: &[f32], k: usize, iters: usize, rng: &mut Rng) -> KMeansVq {
    let n = points.len() / dim;
    assert_eq!(weights.len(), n);
    assert!(n > 0);
    let k = k.min(n).max(1);
    // k-means++ seeding.
    let wsum: Vec<f64> = weights.iter().map(|&w| w.max(0.0) as f64).collect();
    let mut centers = Vec::with_capacity(k * dim);
    let first = rng.weighted(&wsum);
    centers.extend_from_slice(&points[first * dim..(first + 1) * dim]);
    let mut d2: Vec<f64> = (0..n)
        .map(|i| wsum[i] * dist2(&points[i * dim..(i + 1) * dim], &centers[0..dim]) as f64)
        .collect();
    while centers.len() < k * dim {
        let idx = rng.weighted(&d2);
        let c = &points[idx * dim..(idx + 1) * dim];
        centers.extend_from_slice(c);
        let q = centers.len() / dim - 1;
        for i in 0..n {
            let nd = wsum[i] * dist2(&points[i * dim..(i + 1) * dim], &centers[q * dim..(q + 1) * dim]) as f64;
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    let mut assign = assign_nearest(points, dim, &centers);
    for _ in 0..iters {
        let mut num = vec![0.0f64; k * dim];
        let mut den = vec![0.0f64; k];
        for i in 0..n {
            let a = assign[i] as usize;
            den[a] += wsum[i];
            for t in 0..dim {
                num[a * dim + t] += wsum[i] * points[i * dim + t] as f64;
            }
        }
        for q in 0..k {
            if den[q] > 0.0 {
                for t in 0..dim {
                    centers[q * dim + t] = (num[q * dim + t] / den[q]) as f32;
                }
            }
        }
        let new_assign = assign_nearest(points, dim, &centers);
        if new_assign == assign {
            break;
        }
        assign = new_assign;
    }
    let objective = (0..n)
        .map(|i| {
            wsum[i] * dist2(
                &points[i * dim..(i + 1) * dim],
                &centers[assign[i] as usize * dim..(assign[i] as usize + 1) * dim],
            ) as f64
        })
        .sum();
    KMeansVq { centers, dim, assign, objective }
}

/// Extract VQ points from a weight column: `dim` consecutive rows per point.
/// d_in must be divisible by dim.
pub fn column_points(w: &Mat, j: usize, dim: usize) -> Vec<f32> {
    assert_eq!(w.rows % dim, 0);
    let mut out = Vec::with_capacity(w.rows);
    for i in 0..w.rows {
        out.push(w.at(i, j));
    }
    out // already contiguous along rows: point p = rows [p*dim, (p+1)*dim)
}

/// Per-point weights from a per-row weight vector (summed within a point).
pub fn point_weights(row_weights: &[f32], dim: usize) -> Vec<f32> {
    row_weights.chunks(dim).map(|c| c.iter().sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn vq_recovers_planted_clusters() {
        let mut rng = Rng::new(0);
        // Two clusters in R^2 at (0,0) and (5,5).
        let mut pts = Vec::new();
        for i in 0..40 {
            let base = if i % 2 == 0 { 0.0 } else { 5.0 };
            pts.push(base + 0.1 * rng.normal_f32());
            pts.push(base + 0.1 * rng.normal_f32());
        }
        let w = vec![1.0f32; 40];
        let km = lloyd_vq(&pts, 2, &w, 2, 30, &mut rng);
        let c0 = &km.centers[0..2];
        let c1 = &km.centers[2..4];
        let near = |c: &[f32], t: f32| (c[0] - t).abs() < 0.3 && (c[1] - t).abs() < 0.3;
        assert!((near(c0, 0.0) && near(c1, 5.0)) || (near(c0, 5.0) && near(c1, 0.0)));
        assert!(km.objective < 5.0);
    }

    #[test]
    fn lloyd_vq_objective_nonincreasing_vs_random_assign() {
        testing::check("vq-better-than-random", 8, |rng| {
            let n = 32;
            let dim = 2;
            let pts: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32()).collect();
            let ws = vec![1.0f32; n];
            let km = lloyd_vq(&pts, dim, &ws, 4, 30, rng);
            // Compare against centroid-of-all (k=1) objective: must be <=.
            let k1 = lloyd_vq(&pts, dim, &ws, 1, 10, rng);
            testing::ensure(km.objective <= k1.objective + 1e-6, "k=4 worse than k=1")
        });
    }

    #[test]
    fn point_weights_sums() {
        assert_eq!(point_weights(&[1.0, 2.0, 3.0, 4.0], 2), vec![3.0, 7.0]);
    }

    #[test]
    fn assign_within_k() {
        let mut rng = Rng::new(5);
        let pts: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let ws = vec![1.0f32; 16];
        let km = lloyd_vq(&pts, 4, &ws, 5, 10, &mut rng);
        assert!(km.assign.iter().all(|&a| (a as usize) < 5));
        assert_eq!(km.assign.len(), 16);
    }
}
