//! GuidedQuant — the paper's Algorithm 1.
//!
//! Output channels of each layer are partitioned into g consecutive groups
//! J_1..J_g; group k is quantized by any layer-wise method Q against the
//! group-averaged Fisher Hessian H̄_k = X^T·Diag(s_k)·X (computed by the L1
//! Pallas kernel inside the calib_stats artifact and accumulated by
//! `fisher::`). With g = 0 (or hessians = [H]) this degrades to the plain
//! layer-wise objective — the ablation axis of Figure 2 and Table 13.

use anyhow::Result;

use crate::tensor::Mat;

use super::{LayerQuantizer, QuantResult};

/// Consecutive-channel partition (Algorithm 1, line 1).
pub fn group_ranges(d_out: usize, g: usize) -> Vec<(usize, usize)> {
    assert!(g >= 1);
    let g = g.min(d_out);
    let base = d_out / g;
    let rem = d_out % g;
    let mut out = Vec::with_capacity(g);
    let mut lo = 0;
    for k in 0..g {
        let sz = base + usize::from(k < rem);
        out.push((lo, lo + sz));
        lo += sz;
    }
    out
}

/// Apply Q per group with per-group Hessians; reassemble Ŵ/codes/codebooks.
///
/// `hessians` must have one Mat (d_in × d_in) per group; pass a single
/// Hessian for the unguided baseline. The g group solves (Algorithm 1's
/// loop body) are independent and fan out across the shared worker pool.
pub fn guided_quantize(
    q: &dyn LayerQuantizer,
    hessians: &[Mat],
    w: &Mat,
) -> Result<QuantResult> {
    guided_quantize_with(q, hessians, w, crate::tensor::ops::num_threads())
}

/// [`guided_quantize`] with an explicit worker count (1 = the serial group
/// loop). Group solves are pure functions of (H̄_k, W_k) and reassembly is
/// order-preserving, so output is bit-identical at any worker count;
/// exposed for the bit-identity regression tests.
pub fn guided_quantize_with(
    q: &dyn LayerQuantizer,
    hessians: &[Mat],
    w: &Mat,
    workers: usize,
) -> Result<QuantResult> {
    let g = hessians.len();
    anyhow::ensure!(g >= 1, "need at least one Hessian");
    let ranges = group_ranges(w.cols, g);
    let jobs: Vec<_> = ranges
        .iter()
        .enumerate()
        .map(|(k, &(lo, hi))| {
            let h = &hessians[k];
            move || -> Result<QuantResult> {
                let wg = w.slice_cols(lo, hi);
                let res = q.quantize(h, &wg)?;
                anyhow::ensure!(
                    res.w_hat.rows == wg.rows && res.w_hat.cols == wg.cols,
                    "Q returned wrong shape for group {k}"
                );
                Ok(res)
            }
        })
        .collect();
    let outs = crate::coordinator::run_jobs(jobs, workers);
    let mut w_hat = Mat::zeros(w.rows, w.cols);
    let mut codes: Option<Vec<u16>> = None;
    let mut codebooks: Option<Mat> = None;
    let mut bits_acc = 0.0f64;
    for (out, &(lo, hi)) in outs.into_iter().zip(ranges.iter()) {
        let res = out?;
        w_hat.paste_cols(lo, &res.w_hat);
        bits_acc += res.avg_bits * (hi - lo) as f64;
        match (res.codes, res.codebooks) {
            (Some(gc), Some(gcb)) => {
                let codes_slot = codes.get_or_insert_with(|| vec![0u16; w.rows * w.cols]);
                for i in 0..w.rows {
                    for (jj, j) in (lo..hi).enumerate() {
                        codes_slot[i * w.cols + j] = gc[i * (hi - lo) + jj];
                    }
                }
                let cb_slot = codebooks.get_or_insert_with(|| Mat::zeros(w.cols, gcb.cols));
                anyhow::ensure!(cb_slot.cols == gcb.cols, "codebook width changed across groups");
                for (jj, j) in (lo..hi).enumerate() {
                    cb_slot.row_mut(j).copy_from_slice(gcb.row(jj));
                }
            }
            _ => {
                codes = None;
                codebooks = None;
            }
        }
    }
    Ok(QuantResult { w_hat, codes, codebooks, avg_bits: bits_acc / w.cols as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::Gptq;
    use crate::quant::lnq::Lnq;
    use crate::quant::objective::proxy_loss;
    use crate::tensor::ops::matmul_tn;
    use crate::util::Rng;

    #[test]
    fn ranges_partition_exactly() {
        assert_eq!(group_ranges(10, 4), vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        assert_eq!(group_ranges(8, 2), vec![(0, 4), (4, 8)]);
        assert_eq!(group_ranges(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        let r = group_ranges(257, 4);
        assert_eq!(r.last().unwrap().1, 257);
    }

    /// Build a synthetic guided problem: activations X, per-sample saliency
    /// per group -> H̄_k; weighted output error should drop vs unguided.
    fn guided_problem(rng: &mut Rng, n: usize, d_in: usize, d_out: usize, g: usize) -> (Mat, Vec<Mat>, Mat, Mat) {
        let x = Mat::randn(n, d_in, 1.0, rng);
        let h = matmul_tn(&x, &x);
        // Saliency: group k weights samples differently (simulating ∂ℓ/∂z).
        let mut hs = Vec::new();
        let mut sal = Mat::zeros(g, n);
        for k in 0..g {
            for i in 0..n {
                let s = (0.1 + rng.f32() * 2.0) * if i % (k + 2) == 0 { 4.0 } else { 1.0 };
                *sal.at_mut(k, i) = s;
            }
            // H̄_k = X^T diag(s_k) X
            let mut xw = x.clone();
            for i in 0..n {
                let s = sal.at(k, i);
                for v in xw.row_mut(i) {
                    *v *= s.sqrt();
                }
            }
            hs.push(matmul_tn(&xw, &xw));
        }
        let w = Mat::randn(d_in, d_out, 1.0, rng);
        (h, hs, w, sal)
    }

    #[test]
    fn guided_improves_weighted_objective() {
        let mut rng = Rng::new(0);
        let g = 2;
        let (h, hs, w, _) = guided_problem(&mut rng, 48, 16, 8, g);
        let q = Gptq::new(2);
        let unguided = guided_quantize(&q, std::slice::from_ref(&h), &w).unwrap();
        let guided = guided_quantize(&q, &hs, &w).unwrap();
        // Evaluate both under the *guided* objective (Eq. 7):
        let eval = |what: &Mat| -> f64 {
            let ranges = group_ranges(w.cols, g);
            ranges
                .iter()
                .enumerate()
                .map(|(k, &(lo, hi))| {
                    proxy_loss(&hs[k], &w.slice_cols(lo, hi), &what.slice_cols(lo, hi))
                })
                .sum()
        };
        let gu = eval(&guided.w_hat);
        let un = eval(&unguided.w_hat);
        assert!(gu <= un * 1.01, "guided {gu} !<= unguided {un}");
    }

    #[test]
    fn single_group_equals_direct_call() {
        let mut rng = Rng::new(1);
        let (h, _, w, _) = guided_problem(&mut rng, 32, 12, 6, 1);
        let q = Gptq::new(3);
        let direct = q.quantize(&h, &w).unwrap();
        let via = guided_quantize(&q, std::slice::from_ref(&h), &w).unwrap();
        crate::testing::assert_close(&via.w_hat.data, &direct.w_hat.data, 1e-6, 1e-6).unwrap();
        assert_eq!(via.codes, direct.codes);
    }

    #[test]
    fn codes_and_codebooks_reassembled() {
        let mut rng = Rng::new(2);
        let (_, hs, w, _) = guided_problem(&mut rng, 40, 12, 8, 2);
        let q = Lnq::new(2);
        let res = guided_quantize(&q, &hs, &w).unwrap();
        let codes = res.codes.expect("codes");
        let cbs = res.codebooks.expect("codebooks");
        // Decode must reproduce w_hat.
        for i in 0..w.rows {
            for j in 0..w.cols {
                assert_eq!(res.w_hat.at(i, j), cbs.at(j, codes[i * w.cols + j] as usize));
            }
        }
    }

    #[test]
    fn parallel_groups_are_bit_identical_to_serial() {
        // The pooled group fan-out must reproduce the serial loop EXACTLY:
        // same Ŵ bits, same codes, same codebooks, same avg_bits.
        let mut rng = Rng::new(4);
        let (_, hs, w, _) = guided_problem(&mut rng, 40, 12, 10, 4);
        for q in [&Gptq::new(2) as &dyn LayerQuantizer, &Lnq::new(2) as &dyn LayerQuantizer] {
            let serial = guided_quantize_with(q, &hs, &w, 1).unwrap();
            for workers in [2usize, 4, 8] {
                let par = guided_quantize_with(q, &hs, &w, workers).unwrap();
                assert_eq!(par.w_hat.data, serial.w_hat.data, "workers={workers}");
                assert_eq!(par.codes, serial.codes, "workers={workers}");
                assert_eq!(
                    par.codebooks.as_ref().map(|m| &m.data),
                    serial.codebooks.as_ref().map(|m| &m.data),
                    "workers={workers}"
                );
                assert_eq!(par.avg_bits, serial.avg_bits, "workers={workers}");
            }
        }
    }

    #[test]
    fn avg_bits_weighted_average() {
        let mut rng = Rng::new(3);
        let (_, hs, w, _) = guided_problem(&mut rng, 32, 12, 7, 2);
        let q = Gptq::new(2);
        let res = guided_quantize(&q, &hs, &w).unwrap();
        // 2 bits + per-column codebook overhead (4 fp16 entries over d_in=12).
        let bound = 2.0 + 4.0 * 16.0 / 12.0 + 0.1;
        assert!(res.avg_bits >= 2.0 && res.avg_bits < bound, "{}", res.avg_bits);
    }
}
