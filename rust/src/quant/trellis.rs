//! QTIP-style trellis-coded quantization (Tseng et al., 2024b), CPU-scale.
//!
//! Each output channel's d_in weights are coded as a walk through a 2^L
//! state trellis: at step i the coder emits b bits, the state shift-register
//! absorbs them, and the decoded weight is a *computed* function of the
//! state — so only b bits/weight are stored, with no large codebook.
//!
//! Variants mirror the paper's three generators:
//! * `1MAD`  — one multiply-add hash of the state, mapped to a pseudo-
//!             Gaussian value (lookup-free),
//! * `3INST` — three xor/shift/multiply instructions (lookup-free),
//! * `HYB`   — hash selects an entry of a small L1-resident LUT (here 64
//!             entries) refined by k-means on the weight distribution.
//!
//! Assignment is exact Viterbi under diagonal-H weighting, followed by a
//! per-channel scale refit; GuidedQuant plugs in by handing the per-group
//! Hessian's diagonal. (Upstream QTIP interleaves BlockLDLQ feedback; at our
//! d_in ≤ 1024 the Viterbi path is already near-exhaustive. Documented in
//! DESIGN.md §2.)

use anyhow::Result;

use crate::cfg::TrellisVariant;
use crate::tensor::Mat;
use crate::util::Rng;

use super::{LayerQuantizer, QuantResult};

/// Trellis parameters: L state bits, b bits per weight.
#[derive(Debug, Clone)]
pub struct Trellis {
    pub bits: u32,
    pub state_bits: u32,
    pub variant: TrellisVariant,
    pub seed: u64,
}

impl Trellis {
    pub fn new(bits: u32, variant: TrellisVariant) -> Self {
        Trellis { bits, state_bits: 8, variant, seed: 0 }
    }

    pub fn n_states(&self) -> usize {
        1usize << self.state_bits
    }
}

/// Deterministic per-state value generator (unit-scale).
#[derive(Debug, Clone)]
pub struct Generator {
    variant: TrellisVariant,
    /// HYB lookup table (empty for computed variants).
    lut: Vec<f32>,
    lut_mask: u32,
}

impl Generator {
    pub fn new(variant: TrellisVariant, state_bits: u32, sample: &[f32], rng: &mut Rng) -> Self {
        let lut = if variant == TrellisVariant::Hyb {
            // Small L1-resident LUT: k-means centers of the (normalized)
            // weight sample give a matched non-uniform grid.
            let k = 64usize.min(1 << state_bits);
            let ws = vec![1.0f32; sample.len()];
            let km = super::kmeans1d::lloyd(sample, &ws, k, 40, rng);
            let mut centers = km.centers;
            centers.resize(k, *centers.last().unwrap_or(&0.0));
            centers
        } else {
            Vec::new()
        };
        let lut_mask = if lut.is_empty() { 0 } else { (lut.len() - 1) as u32 };
        Generator { variant, lut, lut_mask }
    }

    /// Decode the unit-scale value for a trellis state.
    #[inline]
    pub fn value(&self, state: u32) -> f32 {
        match self.variant {
            TrellisVariant::OneMad => {
                // One multiply-add then a scaled sum of byte fields: an
                // approximately Gaussian computed codebook (paper's 1MAD).
                let x = state.wrapping_mul(0x9E37_79B1).wrapping_add(0x7F4A_7C15);
                let b0 = (x & 0xFF) as i32;
                let b1 = ((x >> 8) & 0xFF) as i32;
                let b2 = ((x >> 16) & 0xFF) as i32;
                let b3 = ((x >> 24) & 0xFF) as i32;
                ((b0 + b1 + b2 + b3 - 510) as f32) / 147.0
            }
            TrellisVariant::ThreeInst => {
                let mut x = state;
                x ^= x << 13;
                x ^= x >> 7;
                x = x.wrapping_mul(0x2545_F491);
                // Map two 16-bit halves to a sum of uniforms (triangular ≈ gaussian-ish).
                let lo = (x & 0xFFFF) as f32 / 65535.0;
                let hi = (x >> 16) as f32 / 65535.0;
                (lo + hi - 1.0) * 2.45
            }
            TrellisVariant::Hyb => {
                let h = state.wrapping_mul(0x85EB_CA6B) >> 8;
                self.lut[(h & self.lut_mask) as usize]
            }
        }
    }
}

/// Result of trellis-coding one column: the packed b-bit transition stream.
#[derive(Debug, Clone)]
pub struct TrellisCode {
    pub initial_state: u32,
    /// b-bit symbols, one per weight.
    pub symbols: Vec<u16>,
    /// Per-column scale (decoded value = scale * generator(state)).
    pub scale: f32,
}

fn state_next(state: u32, sym: u32, state_bits: u32, bits: u32) -> u32 {
    ((state << bits) | sym) & ((1 << state_bits) - 1)
}

/// Viterbi assignment for one column under weights `diag_w` (≥ 0).
pub fn viterbi_column(
    col: &[f32],
    diag_w: &[f32],
    scale: f32,
    gen: &Generator,
    cfg: &Trellis,
) -> TrellisCode {
    let n = col.len();
    let n_states = cfg.n_states();
    let branch = 1usize << cfg.bits;
    let inf = f32::INFINITY;
    // dp[s] = best cost ending in state s; bk[i][s] = chosen symbol.
    let mut dp = vec![0.0f32; n_states];
    let mut ndp = vec![inf; n_states];
    let mut bk = vec![0u16; n * n_states];
    let mut prev_state = vec![0u32; n * n_states];
    for i in 0..n {
        ndp.iter_mut().for_each(|v| *v = inf);
        let target = col[i];
        let wgt = diag_w[i].max(1e-12);
        for s in 0..n_states {
            let base = dp[s];
            if base == inf {
                continue;
            }
            for sym in 0..branch {
                let ns = state_next(s as u32, sym as u32, cfg.state_bits, cfg.bits) as usize;
                let val = scale * gen.value(ns as u32);
                let d = val - target;
                let cost = base + wgt * d * d;
                if cost < ndp[ns] {
                    ndp[ns] = cost;
                    bk[i * n_states + ns] = sym as u16;
                    prev_state[i * n_states + ns] = s as u32;
                }
            }
        }
        std::mem::swap(&mut dp, &mut ndp);
    }
    // Backtrack from the best final state.
    let mut best_s = 0usize;
    let mut best_c = inf;
    for s in 0..n_states {
        if dp[s] < best_c {
            best_c = dp[s];
            best_s = s;
        }
    }
    let mut symbols = vec![0u16; n];
    let mut s = best_s as u32;
    for i in (0..n).rev() {
        symbols[i] = bk[i * n_states + s as usize];
        s = prev_state[i * n_states + s as usize];
    }
    TrellisCode { initial_state: s, symbols, scale }
}

/// Decode a column back to weights.
pub fn decode_column(code: &TrellisCode, gen: &Generator, cfg: &Trellis) -> Vec<f32> {
    let mut s = code.initial_state;
    code.symbols
        .iter()
        .map(|&sym| {
            s = state_next(s, sym as u32, cfg.state_bits, cfg.bits);
            code.scale * gen.value(s)
        })
        .collect()
}

/// Full-matrix trellis quantization. Per-column scale = rms(col)/rms(gen).
pub fn trellis_quantize(h: &Mat, w: &Mat, cfg: &Trellis) -> Result<(QuantResult, Vec<TrellisCode>, Generator)> {
    let d_in = w.rows;
    let d_out = w.cols;
    assert_eq!((h.rows, h.cols), (d_in, d_in));
    let mut rng = Rng::new(cfg.seed ^ 0x717469);
    // Normalized sample for the HYB LUT fit.
    let sample: Vec<f32> = {
        let rms = (w.frob_norm_sq() / (d_in * d_out) as f64).sqrt().max(1e-12) as f32;
        w.data.iter().take(4096).map(|&v| v / rms).collect()
    };
    let gen = Generator::new(cfg.variant, cfg.state_bits, &sample, &mut rng);
    // Generator rms over all states (for scale matching).
    let n_states = cfg.n_states();
    let gen_rms = ((0..n_states as u32).map(|s| (gen.value(s) as f64).powi(2)).sum::<f64>()
        / n_states as f64)
        .sqrt()
        .max(1e-9) as f32;
    let diag = h.diag();

    let mut w_hat = Mat::zeros(d_in, d_out);
    let mut codes_out = Vec::with_capacity(d_out);
    // Viterbi per column, parallelized over columns.
    let threads = crate::tensor::ops::num_threads().min(d_out).max(1);
    let chunk = d_out.div_ceil(threads);
    let results: Vec<Vec<(usize, TrellisCode, Vec<f32>)>> = std::thread::scope(|sc| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(d_out);
            if lo >= hi {
                break;
            }
            let gen = &gen;
            let diag = &diag;
            handles.push(sc.spawn(move || {
                let mut out = Vec::new();
                for j in lo..hi {
                    let col = w.col(j);
                    let col_rms = (col.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
                        / d_in as f64)
                        .sqrt()
                        .max(1e-12) as f32;
                    let scale = col_rms / gen_rms;
                    let code = viterbi_column(&col, diag, scale, gen, cfg);
                    let dec = decode_column(&code, gen, cfg);
                    out.push((j, code, dec));
                }
                out
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut per_col: Vec<Option<(TrellisCode, Vec<f32>)>> = (0..d_out).map(|_| None).collect();
    for chunk_res in results {
        for (j, code, dec) in chunk_res {
            per_col[j] = Some((code, dec));
        }
    }
    for (j, entry) in per_col.into_iter().enumerate() {
        let (code, dec) = entry.expect("column not coded");
        for i in 0..d_in {
            *w_hat.at_mut(i, j) = dec[i];
        }
        codes_out.push(code);
    }
    // b bits/weight + per-column fp16 scale + initial state.
    let avg_bits =
        cfg.bits as f64 + (16.0 + cfg.state_bits as f64) / d_in as f64;
    let qr = QuantResult { w_hat, codes: None, codebooks: None, avg_bits };
    Ok((qr, codes_out, gen))
}

impl LayerQuantizer for Trellis {
    fn quantize(&self, h: &Mat, w: &Mat) -> Result<QuantResult> {
        Ok(trellis_quantize(h, w, self)?.0)
    }

    fn name(&self) -> &'static str {
        "trellis"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::objective::weight_mse;
    use crate::tensor::ops::matmul_tn;

    fn cfg(variant: TrellisVariant) -> Trellis {
        Trellis { bits: 2, state_bits: 8, variant, seed: 0 }
    }

    fn problem(rng: &mut Rng, d_in: usize, d_out: usize) -> (Mat, Mat) {
        let x = Mat::randn(d_in * 2, d_in, 1.0, rng);
        let h = matmul_tn(&x, &x);
        let w = Mat::randn(d_in, d_out, 1.0, rng);
        (h, w)
    }

    #[test]
    fn decode_matches_viterbi_choice() {
        let mut rng = Rng::new(0);
        let (h, w) = problem(&mut rng, 32, 2);
        for variant in [TrellisVariant::OneMad, TrellisVariant::ThreeInst, TrellisVariant::Hyb] {
            let c = cfg(variant);
            let (qr, codes, gen) = trellis_quantize(&h, &w, &c).unwrap();
            for (j, code) in codes.iter().enumerate() {
                let dec = decode_column(code, &gen, &c);
                for i in 0..32 {
                    assert_eq!(qr.w_hat.at(i, j), dec[i], "{variant:?} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn trellis_tracks_weights_reasonably() {
        let mut rng = Rng::new(1);
        let (h, w) = problem(&mut rng, 64, 4);
        for variant in [TrellisVariant::OneMad, TrellisVariant::ThreeInst, TrellisVariant::Hyb] {
            let (qr, _, _) = trellis_quantize(&h, &w, &cfg(variant)).unwrap();
            let mse = weight_mse(&w, &qr.w_hat);
            // Unit-variance weights at 2 bits: MSE well below variance.
            assert!(mse < 0.5, "{variant:?} mse {mse}");
        }
    }

    #[test]
    fn viterbi_is_optimal_vs_greedy() {
        // Greedy symbol choice (pick best transition at each step) can never
        // beat Viterbi's total cost.
        let mut rng = Rng::new(2);
        let c = cfg(TrellisVariant::ThreeInst);
        let col: Vec<f32> = (0..48).map(|_| rng.normal_f32()).collect();
        let diag = vec![1.0f32; 48];
        let gen = Generator::new(c.variant, c.state_bits, &col, &mut rng);
        let code = viterbi_column(&col, &diag, 1.0, &gen, &c);
        let vit_cost: f64 = decode_column(&code, &gen, &c)
            .iter()
            .zip(&col)
            .map(|(&d, &t)| ((d - t) as f64).powi(2))
            .sum();
        // Greedy walk:
        let mut s = 0u32;
        let mut greedy_cost = 0.0f64;
        for &t in &col {
            let mut best = f64::INFINITY;
            let mut best_ns = 0u32;
            for sym in 0..(1u32 << c.bits) {
                let ns = state_next(s, sym, c.state_bits, c.bits);
                let d = (gen.value(ns) - t) as f64;
                if d * d < best {
                    best = d * d;
                    best_ns = ns;
                }
            }
            s = best_ns;
            greedy_cost += best;
        }
        assert!(vit_cost <= greedy_cost + 1e-6, "viterbi {vit_cost} > greedy {greedy_cost}");
    }

    #[test]
    fn avg_bits_close_to_target() {
        let mut rng = Rng::new(3);
        let (h, w) = problem(&mut rng, 128, 2);
        let (qr, _, _) = trellis_quantize(&h, &w, &cfg(TrellisVariant::OneMad)).unwrap();
        assert!(qr.avg_bits < 2.5, "{}", qr.avg_bits);
    }
}
