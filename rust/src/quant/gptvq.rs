//! GPTVQ (van Baalen et al., 2024) baselines.
//!
//! * GPTVQ 1D: the strongest prior non-uniform scalar method. Alternates
//!   (a) codebook update by *gradient descent* on the quadratic objective
//!   (exact line search per step — still suboptimal vs LNQ's closed form,
//!   which is the paper's point) and (b) assignment updates via GPTQ.
//! * GPTVQ 2D: vector variant — `dim` consecutive rows of a channel form a
//!   point, codebook per channel fit by weighted k-means (diag-H weights)
//!   with GPTQ-style sequential error feedback at point granularity.
//!
//! Simplification vs upstream (documented in DESIGN.md): codebooks are
//! per-output-channel instead of shared across large column groups (our
//! matrices are 128–1024 wide, not 4096–11008), and the EM-style codebook
//! re-sharing heuristics are dropped.

use anyhow::Result;

use crate::tensor::{ops::matmul, Mat};
use crate::util::Rng;

use super::gptq::gptq_with_grid;
use super::grid::{avg_bits_scalar, LutGrid};
use super::kmeans1d::lloyd;
use super::lnq::decode;
use super::{LayerQuantizer, QuantResult};

#[derive(Debug, Clone)]
pub struct Gptvq1d {
    pub bits: u32,
    /// Alternating iterations.
    pub t_iters: usize,
    /// GD steps per codebook update.
    pub gd_steps: usize,
    pub seed: u64,
}

impl Gptvq1d {
    pub fn new(bits: u32) -> Self {
        Gptvq1d { bits, t_iters: 2, gd_steps: 8, seed: 0 }
    }
}

/// One exact-line-search GD pass on every column's codebook.
/// For fixed codes the objective per column is f(c) = c^T A c − 2 b^T c + k;
/// GD with optimal step α = g·g / (2 g·A g). (Still generally worse than the
/// closed-form solve — LNQ's improvement.)
fn codebook_gd_update(h: &Mat, w: &Mat, codes: &[u16], codebooks: &mut Mat, steps: usize) {
    let d_in = w.rows;
    let d_out = w.cols;
    let m = codebooks.cols;
    let hw = matmul(h, w);
    for j in 0..d_out {
        // Build A (m×m) and b (m) as in the LS update.
        let mut mrows = vec![0.0f64; m * d_in];
        for i in 0..d_in {
            let q = codes[i * d_out + j] as usize;
            let hrow = h.row(i);
            let mrow = &mut mrows[q * d_in..(q + 1) * d_in];
            for (mv, &hv) in mrow.iter_mut().zip(hrow) {
                *mv += hv as f64;
            }
        }
        let mut a = vec![0.0f64; m * m];
        let mut b = vec![0.0f64; m];
        for k in 0..d_in {
            let r = codes[k * d_out + j] as usize;
            for q in 0..m {
                a[q * m + r] += mrows[q * d_in + k];
            }
        }
        for i in 0..d_in {
            let q = codes[i * d_out + j] as usize;
            b[q] += hw.at(i, j) as f64;
        }
        let mut c: Vec<f64> = (0..m).map(|q| codebooks.at(j, q) as f64).collect();
        for _ in 0..steps {
            // g = 2(Ac − b)
            let mut g = vec![0.0f64; m];
            for q in 0..m {
                let mut s = -b[q];
                for r in 0..m {
                    s += a[q * m + r] * c[r];
                }
                g[q] = 2.0 * s;
            }
            let gg: f64 = g.iter().map(|v| v * v).sum();
            if gg < 1e-24 {
                break;
            }
            // gAg
            let mut gag = 0.0f64;
            for q in 0..m {
                for r in 0..m {
                    gag += g[q] * a[q * m + r] * g[r];
                }
            }
            if gag <= 0.0 {
                break;
            }
            let alpha = gg / (2.0 * gag);
            for q in 0..m {
                c[q] -= alpha * g[q];
            }
        }
        for q in 0..m {
            *codebooks.at_mut(j, q) = c[q] as f32;
        }
    }
}

pub fn gptvq1d_quantize(h: &Mat, w: &Mat, cfg: &Gptvq1d) -> Result<QuantResult> {
    let d_in = w.rows;
    let d_out = w.cols;
    let m = 1usize << cfg.bits;
    let mut rng = Rng::new(cfg.seed ^ 0x675651);
    let diag = h.diag();
    let ws: Vec<f32> = diag.iter().map(|&v| v.max(1e-12)).collect();

    // Init: diag-weighted k-means per channel.
    let mut codebooks = Mat::zeros(d_out, m);
    let mut codes = vec![0u16; d_in * d_out];
    for j in 0..d_out {
        let col = w.col(j);
        let km = lloyd(&col, &ws, m, 30, &mut rng);
        for q in 0..m {
            *codebooks.at_mut(j, q) = *km.centers.get(q).unwrap_or(km.centers.last().unwrap());
        }
        for i in 0..d_in {
            codes[i * d_out + j] = km.assign[i];
        }
    }

    for _ in 0..cfg.t_iters {
        codebook_gd_update(h, w, &codes, &mut codebooks, cfg.gd_steps);
        let grid = LutGrid::new(codebooks.clone());
        let (_, new_codes) = gptq_with_grid(h, w, &grid, 32)?;
        codes = new_codes;
    }
    codebook_gd_update(h, w, &codes, &mut codebooks, cfg.gd_steps);
    let w_hat = decode(&codes, &codebooks, d_in);
    Ok(QuantResult {
        w_hat,
        codes: Some(codes),
        codebooks: Some(codebooks),
        avg_bits: avg_bits_scalar(d_in, d_out, cfg.bits),
    })
}

impl LayerQuantizer for Gptvq1d {
    fn quantize(&self, h: &Mat, w: &Mat) -> Result<QuantResult> {
        gptvq1d_quantize(h, w, self)
    }

    fn name(&self) -> &'static str {
        "gptvq1d"
    }
}

/// GPTVQ 2D/4D vector variant.
#[derive(Debug, Clone)]
pub struct GptvqVq {
    /// Bits per weight.
    pub bits: u32,
    /// VQ dimension (2 or 4).
    pub dim: usize,
    pub seed: u64,
}

impl GptvqVq {
    pub fn new(bits: u32, dim: usize) -> Self {
        GptvqVq { bits, dim, seed: 0 }
    }
}

pub fn gptvq_vq_quantize(h: &Mat, w: &Mat, cfg: &GptvqVq) -> Result<QuantResult> {
    let d_in = w.rows;
    let d_out = w.cols;
    let dim = cfg.dim;
    anyhow::ensure!(d_in % dim == 0, "d_in {d_in} not divisible by vq dim {dim}");
    let k = 1usize << (cfg.bits as usize * dim); // entries per codebook
    let k = k.min(d_in / dim * 4).min(4096);
    let mut rng = Rng::new(cfg.seed ^ 0x675632);
    let diag = h.diag();

    let mut w_hat = Mat::zeros(d_in, d_out);
    let n_pts = d_in / dim;
    let mut codes = vec![0u16; n_pts * d_out];
    let mut codebooks = Mat::zeros(d_out, k * dim);
    for j in 0..d_out {
        let pts = super::vq::column_points(w, j, dim);
        let rw: Vec<f32> = diag.iter().map(|&v| v.max(1e-12)).collect();
        let pw = super::vq::point_weights(&rw, dim);
        let km = super::vq::lloyd_vq(&pts, dim, &pw, k, 25, &mut rng);
        let kk = km.centers.len() / dim;
        for (p, &a) in km.assign.iter().enumerate() {
            codes[p * d_out + j] = a;
            for t in 0..dim {
                *w_hat.at_mut(p * dim + t, j) = km.centers[a as usize * dim + t];
            }
        }
        for e in 0..(k * dim) {
            *codebooks.at_mut(j, e) = if e < kk * dim { km.centers[e] } else { 0.0 };
        }
    }
    // Codebook storage overhead: k·dim fp16 entries per channel over d_in weights.
    let avg_bits = cfg.bits as f64 + (k as f64 * dim as f64 * 16.0) / d_in as f64;
    Ok(QuantResult { w_hat, codes: Some(codes), codebooks: Some(codebooks), avg_bits })
}

impl LayerQuantizer for GptvqVq {
    fn quantize(&self, h: &Mat, w: &Mat) -> Result<QuantResult> {
        gptvq_vq_quantize(h, w, self)
    }

    fn name(&self) -> &'static str {
        "gptvq_vq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::rtn_quantize;
    use crate::quant::objective::proxy_loss;
    use crate::tensor::ops::matmul_tn;
    use crate::util::Rng;

    fn problem(rng: &mut Rng, d_in: usize, d_out: usize) -> (Mat, Mat) {
        let x = Mat::randn(d_in * 2, d_in, 1.0, rng);
        let h = matmul_tn(&x, &x);
        let w = Mat::randn(d_in, d_out, 1.0, rng);
        (h, w)
    }

    #[test]
    fn gptvq1d_beats_rtn() {
        let mut rng = Rng::new(0);
        let (h, w) = problem(&mut rng, 24, 6);
        let res = gptvq1d_quantize(&h, &w, &Gptvq1d::new(2)).unwrap();
        let rtn = rtn_quantize(&w, 2);
        assert!(proxy_loss(&h, &w, &res.w_hat) < proxy_loss(&h, &w, &rtn.w_hat));
    }

    #[test]
    fn lnq_beats_gptvq1d_on_average() {
        // The paper's Table 3 claim: LNQ's closed-form codebook + CD beats
        // GPTVQ 1D's GD + GPTQ. Check the mean objective over instances.
        let mut rng = Rng::new(1);
        let mut lnq_total = 0.0;
        let mut gptvq_total = 0.0;
        for _ in 0..4 {
            let (h, w) = problem(&mut rng, 20, 4);
            let lnq = crate::quant::lnq::lnq_quantize(&h, &w, &crate::quant::lnq::Lnq::new(2)).unwrap();
            let gvq = gptvq1d_quantize(&h, &w, &Gptvq1d::new(2)).unwrap();
            lnq_total += proxy_loss(&h, &w, &lnq.w_hat);
            gptvq_total += proxy_loss(&h, &w, &gvq.w_hat);
        }
        assert!(
            lnq_total < gptvq_total * 1.05,
            "lnq {lnq_total} not better than gptvq {gptvq_total}"
        );
    }

    #[test]
    fn vq_variant_runs_and_decodes() {
        let mut rng = Rng::new(2);
        let (h, w) = problem(&mut rng, 16, 4);
        let res = gptvq_vq_quantize(&h, &w, &GptvqVq::new(2, 2)).unwrap();
        assert_eq!((res.w_hat.rows, res.w_hat.cols), (16, 4));
        assert!(res.w_hat.data.iter().all(|v| v.is_finite()));
        assert!(res.avg_bits > 2.0);
    }

    #[test]
    fn vq_dim_must_divide() {
        let mut rng = Rng::new(3);
        let (h, w) = problem(&mut rng, 10, 2);
        assert!(gptvq_vq_quantize(&h, &w, &GptvqVq::new(2, 4)).is_err());
    }
}
