//! GPTQ (Frantar et al., 2023) — the OBQ-derived sequential quantizer with
//! error feedback, implemented via the exact inverse-Hessian downdate (the
//! OBQ identity GPTQ is derived from), with lazy batch updates over rows.
//!
//! With H⁻¹ = L·Lᵀ (lower Cholesky — torch's `cholesky(Hinv, upper=True)`
//! is exactly Lᵀ), the GPTQ loop is, per visiting order i = 0..d_in:
//!   q_i   = Round(w_i)
//!   err_i = (w_i − q_i) / L_ii
//!   w_k  -= L_ki · err_i      for all k > i
//! Each Cholesky column is the correctly *downdated* inverse column OBQ
//! would recompute, which is the whole point of GPTQ. Feedback is batched
//! like Appendix B.3's lazy updates. Used standalone (uniform grid
//! baseline, SpinQuant's W-step) and inside GPTVQ 1D / Table 14 (LUT grids).

use anyhow::Result;

use crate::linalg::{Cholesky, DEFAULT_DAMP};
use crate::tensor::Mat;

use super::grid::{avg_bits_scalar, ColGrid, UniformGrid};
use super::{QuantResult, QuantResult as _QR};

/// Dense H⁻¹ via Cholesky solves against basis vectors.
pub fn invert_spd(h: &Mat, damp: f64) -> Result<Mat> {
    let ch = Cholesky::factor(h, damp)?;
    let n = h.rows;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0f64; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = ch.solve(&e);
        e[j] = 0.0;
        for i in 0..n {
            inv.data[i * n + j] = col[i] as f32;
        }
    }
    inv.symmetrize();
    Ok(inv)
}

/// Run GPTQ against an arbitrary grid. Returns (Ŵ, codes).
pub fn gptq_with_grid(h: &Mat, w: &Mat, grid: &dyn ColGrid, block: usize) -> Result<(Mat, Vec<u16>)> {
    let d_in = w.rows;
    let d_out = w.cols;
    assert_eq!((h.rows, h.cols), (d_in, d_in));
    let hinv = invert_spd(h, DEFAULT_DAMP)?;
    // Lower Cholesky of H⁻¹: column i holds the downdated inverse direction.
    let lchol = Cholesky::factor(&hinv, 1e-12)?;
    let lmat = lchol.l_mat();

    // Working copy of weights that receives the error feedback.
    let mut work = w.clone();
    let mut w_hat = Mat::zeros(d_in, d_out);
    let mut codes = vec![0u16; d_in * d_out];
    let block = block.max(1);

    let mut err_block = Mat::zeros(block, d_out); // err rows for deferred update
    let mut s = 0;
    while s < d_in {
        let e = (s + block).min(d_in);
        for r in err_block.data.iter_mut() {
            *r = 0.0;
        }
        for i in s..e {
            let dii = lmat.at(i, i).max(1e-12);
            // Quantize row i from the error-compensated working weights.
            for j in 0..d_out {
                let (dec, code) = grid.round(j, work.at(i, j));
                *w_hat.at_mut(i, j) = dec;
                codes[i * d_out + j] = code;
            }
            // err_i = (w_i − q_i) / L_ii
            for j in 0..d_out {
                let err = (work.at(i, j) - w_hat.at(i, j)) / dii;
                *err_block.at_mut(i - s, j) = err;
            }
            // Immediate feedback within the block.
            for k in (i + 1)..e {
                let lki = lmat.at(k, i);
                if lki == 0.0 {
                    continue;
                }
                let eb = err_block.row(i - s).to_vec();
                let wk = work.row_mut(k);
                for j in 0..d_out {
                    wk[j] -= lki * eb[j];
                }
            }
        }
        // Deferred feedback for the remaining rows.
        for k in e..d_in {
            let wk_off = k * d_out;
            for (bi, i) in (s..e).enumerate() {
                let lki = lmat.at(k, i);
                if lki == 0.0 {
                    continue;
                }
                let eb = err_block.row(bi);
                let wk = &mut work.data[wk_off..wk_off + d_out];
                for j in 0..d_out {
                    wk[j] -= lki * eb[j];
                }
            }
        }
        s = e;
    }
    Ok((w_hat, codes))
}

/// GPTQ with a min/max uniform grid (the Table 3 `GPTQ` baseline).
pub struct Gptq {
    pub bits: u32,
    pub block: usize,
}

impl Gptq {
    pub fn new(bits: u32) -> Self {
        Gptq { bits, block: 32 }
    }
}

impl super::LayerQuantizer for Gptq {
    fn quantize(&self, h: &Mat, w: &Mat) -> Result<QuantResult> {
        let grid = UniformGrid::fit(w, self.bits);
        let (w_hat, codes) = gptq_with_grid(h, w, &grid, self.block)?;
        let m = 1usize << self.bits;
        let codebooks = Mat::from_fn(w.cols, m, |j, q| grid.decode(j, q as u16));
        Ok(_QR {
            w_hat,
            codes: Some(codes),
            codebooks: Some(codebooks),
            avg_bits: avg_bits_scalar(w.rows, w.cols, self.bits),
        })
    }

    fn name(&self) -> &'static str {
        "gptq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::round_all;
    use crate::quant::objective::proxy_loss;
    use crate::quant::LayerQuantizer;
    use crate::tensor::ops::matmul_tn;
    use crate::testing;
    use crate::util::Rng;

    fn problem(rng: &mut Rng, d_in: usize, d_out: usize) -> (Mat, Mat) {
        let x = Mat::randn(d_in * 2, d_in, 1.0, rng);
        let h = matmul_tn(&x, &x);
        let w = Mat::randn(d_in, d_out, 1.0, rng);
        (h, w)
    }

    #[test]
    fn invert_spd_is_inverse() {
        let mut rng = Rng::new(0);
        let (h, _) = problem(&mut rng, 12, 1);
        let inv = invert_spd(&h, 1e-10).unwrap();
        let prod = crate::tensor::ops::matmul(&h, &inv);
        let eye = Mat::eye(12);
        testing::assert_close(&prod.data, &eye.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_hessian_in_aggregate() {
        // GPTQ is a greedy heuristic: it can lose to RTN on individual
        // instances, but must win clearly in aggregate (the Table 3 story).
        let mut rng = Rng::new(0xbeef);
        let mut rtn_total = 0.0;
        let mut gptq_total = 0.0;
        for _ in 0..10 {
            let d_in = 16 + rng.below(16);
            let d_out = 2 + rng.below(6);
            let (h, w) = problem(&mut rng, d_in, d_out);
            let grid = UniformGrid::fit(&w, 2);
            let (rtn_hat, _) = round_all(&w, &grid);
            rtn_total += proxy_loss(&h, &w, &rtn_hat);
            let (gq_hat, _) = gptq_with_grid(&h, &w, &grid, 8).unwrap();
            gptq_total += proxy_loss(&h, &w, &gq_hat);
        }
        assert!(
            gptq_total < 0.8 * rtn_total,
            "gptq {gptq_total} not clearly better than rtn {rtn_total}"
        );
    }

    #[test]
    fn block_size_does_not_change_result() {
        let mut rng = Rng::new(3);
        let (h, w) = problem(&mut rng, 24, 4);
        let grid = UniformGrid::fit(&w, 3);
        let (a, ca) = gptq_with_grid(&h, &w, &grid, 1).unwrap();
        let (b, cb) = gptq_with_grid(&h, &w, &grid, 8).unwrap();
        let (c, cc) = gptq_with_grid(&h, &w, &grid, 64).unwrap();
        assert_eq!(ca, cb);
        assert_eq!(ca, cc);
        testing::assert_close(&a.data, &b.data, 1e-5, 1e-5).unwrap();
        testing::assert_close(&a.data, &c.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        let mut rng = Rng::new(5);
        let w = Mat::randn(10, 3, 1.0, &mut rng);
        let h = Mat::eye(10);
        let grid = UniformGrid::fit(&w, 3);
        let (want, want_codes) = round_all(&w, &grid);
        let (got, got_codes) = gptq_with_grid(&h, &w, &grid, 4).unwrap();
        assert_eq!(got_codes, want_codes);
        testing::assert_close(&got.data, &want.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn quantizer_trait_reports_bits() {
        let mut rng = Rng::new(6);
        let (h, w) = problem(&mut rng, 16, 4);
        let q = Gptq::new(4);
        let res = q.quantize(&h, &w).unwrap();
        assert!(res.avg_bits >= 4.0);
        assert!(res.codes.is_some() && res.codebooks.is_some());
        assert_eq!((res.w_hat.rows, res.w_hat.cols), (16, 4));
    }
}
