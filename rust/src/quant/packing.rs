//! Bit-packing of code indices into u32 words — the storage format behind
//! the serving-engine formats (Table 2's bits accounting is real bytes).
//!
//! Two layouts live here:
//! * [`PackedCodes`] — element-major: each code's bits sit contiguously
//!   inside one word (the fixed-precision serving formats).
//! * [`BitPlanes`] — plane-major (Any-Precision-LLM layout): bit plane 0
//!   holds every code's most-significant bit, plane 1 the next one down,
//!   and so on. Reading a PREFIX of the planes reconstructs each code's
//!   high-order bits, so one stored artifact decodes at any precision
//!   `1..=bits` by touching only the planes that precision needs.

/// Codes packed `bits` per element into u32 words, row-major.
#[derive(Debug, Clone)]
pub struct PackedCodes {
    pub bits: u32,
    pub len: usize,
    words: Vec<u32>,
}

impl PackedCodes {
    pub fn pack(codes: &[u16], bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 16, "pack: bit width {bits} outside 1..=16");
        let per_word = 32 / bits as usize;
        let n_words = codes.len().div_ceil(per_word);
        let mask = (1u32 << bits) - 1;
        let mut words = vec![0u32; n_words];
        for (idx, &c) in codes.iter().enumerate() {
            // Always-on (not debug_assert): a silently truncated code would
            // decode to the wrong weight for the lifetime of the format.
            assert!(
                (c as u32) <= mask,
                "pack: code {c} at index {idx} does not fit in {bits} bits"
            );
            let w = idx / per_word;
            let off = (idx % per_word) as u32 * bits;
            words[w] |= ((c as u32) & mask) << off;
        }
        PackedCodes { bits, len: codes.len(), words }
    }

    /// Random-access decode of one code.
    ///
    /// The div/mod pair here is fine — and a cached-word fast path is
    /// unnecessary — because the serving tile paths NEVER call `get`:
    /// every hot decode loop goes through [`PackedCodes::unpack_range`] /
    /// [`PackedCodes::unpack_map_f32`], which walk words directly (one
    /// shift/mask per element). `get` serves only cold paths
    /// ([`PackedCodes::to_vec`], tests, one-off probes).
    ///
    /// A code also never straddles two words: `pack` places code `idx` at
    /// bit offset `(idx % per_word) * bits` with `per_word = 32 / bits`
    /// (integer division), so `off + bits <= 32` always holds — widths
    /// that don't divide 32 simply leave `32 % bits` pad bits at the top
    /// of each word (e.g. 3-bit packing stores 10 codes per word with 2
    /// dead bits). The single-word read below is therefore complete.
    #[inline]
    pub fn get(&self, idx: usize) -> u16 {
        debug_assert!(idx < self.len);
        let per_word = 32 / self.bits as usize;
        let w = idx / per_word;
        let off = (idx % per_word) as u32 * self.bits;
        ((self.words[w] >> off) & ((1u32 << self.bits) - 1)) as u16
    }

    /// Unpack a contiguous range (hot path: one shift/mask per element,
    /// word-at-a-time — no per-element division). Word-aligned ranges with
    /// power-of-two bits take a branch-free unrolled path.
    pub fn unpack_range(&self, start: usize, out: &mut [u16]) {
        debug_assert!(start + out.len() <= self.len);
        let bits = self.bits as usize;
        let per_word = 32 / bits;
        let mask = (1u32 << bits) - 1;
        if 32 % bits == 0 && start % per_word == 0 && out.len() % per_word == 0 {
            let w0 = start / per_word;
            for (chunk, &w) in out.chunks_exact_mut(per_word).zip(&self.words[w0..]) {
                let mut word = w;
                for o in chunk {
                    *o = (word & mask) as u16;
                    word >>= bits;
                }
            }
            return;
        }
        let mut w = start / per_word;
        let mut off = (start % per_word) * bits;
        let mut word = self.words[w] >> off;
        for o in out.iter_mut() {
            *o = (word & mask) as u16;
            off += bits;
            if off + bits > 32 {
                w += 1;
                off = 0;
                word = *self.words.get(w).unwrap_or(&0);
            } else {
                word >>= bits;
            }
        }
    }

    /// Decode a contiguous code range through an f32 lookup table:
    /// `out[k] = lut[code(start + k)]`. This is the tile-granular decode
    /// fast path of the serving formats — codes go straight from packed
    /// words to dequantized f32 (tables are pre-expanded at format
    /// construction), with no u16 staging buffer and no per-element
    /// int→float convert in the caller's inner loop. Word-aligned starts
    /// with power-of-two bit widths take a word-at-a-time path for any
    /// output length; other starts fall back to the rolling-word decode.
    pub fn unpack_map_f32(&self, start: usize, lut: &[f32], out: &mut [f32]) {
        debug_assert!(start + out.len() <= self.len);
        debug_assert!(lut.len() >= (1usize << self.bits.min(16)));
        let bits = self.bits as usize;
        let per_word = 32 / bits;
        let mask = (1u32 << bits) - 1;
        if 32 % bits == 0 && start % per_word == 0 {
            let w0 = start / per_word;
            let mut chunks = out.chunks_exact_mut(per_word);
            let mut used = 0usize;
            for (chunk, &wd) in (&mut chunks).zip(&self.words[w0..]) {
                let mut word = wd;
                for o in chunk {
                    *o = lut[(word & mask) as usize];
                    word >>= bits;
                }
                used += 1;
            }
            let rem = chunks.into_remainder();
            if !rem.is_empty() {
                let mut word = self.words[w0 + used];
                for o in rem {
                    *o = lut[(word & mask) as usize];
                    word >>= bits;
                }
            }
            return;
        }
        let mut w = start / per_word;
        let mut off = (start % per_word) * bits;
        let mut word = self.words[w] >> off;
        for o in out.iter_mut() {
            *o = lut[(word & mask) as usize];
            off += bits;
            if off + bits > 32 {
                w += 1;
                off = 0;
                word = *self.words.get(w).unwrap_or(&0);
            } else {
                word >>= bits;
            }
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 4
    }

    /// Raw packed words (for fused decode loops in the serving formats).
    #[inline]
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// True if rows of length `row_len` starting at multiples of `row_len`
    /// are word-aligned (the fused serving decode requires this).
    pub fn rows_aligned(&self, row_len: usize) -> bool {
        32 % self.bits == 0 && row_len % (32 / self.bits as usize) == 0
    }

    pub fn to_vec(&self) -> Vec<u16> {
        (0..self.len).map(|i| self.get(i)).collect()
    }
}

/// Codes stored as `bits` independent one-bit planes (the Any-Precision
/// layout). Plane 0 is every code's most-significant bit; plane `p` holds
/// bit `bits - 1 - p`. Decoding at precision `P <= bits` reads planes
/// `0..P` and reconstructs `code >> (bits - P)` — the code's top `P` bits
/// — so a single artifact serves every precision from a prefix of its
/// storage, and full-precision decode recovers the original codes exactly.
///
/// Planes are plane-major: plane `p` occupies words
/// `[p * words_per_plane, (p + 1) * words_per_plane)`, each word covering
/// 32 consecutive elements (element `i` at bit `i % 32`). A precision-`P`
/// decode therefore touches exactly the first `P * words_per_plane` words.
#[derive(Debug, Clone)]
pub struct BitPlanes {
    /// Planes stored — the artifact's full precision.
    pub bits: u32,
    /// Number of codes.
    pub len: usize,
    words_per_plane: usize,
    words: Vec<u32>,
}

impl BitPlanes {
    pub fn pack(codes: &[u16], bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 16, "bitplanes: bit width {bits} outside 1..=16");
        let words_per_plane = codes.len().div_ceil(32);
        let mask = (1u32 << bits) - 1;
        let mut words = vec![0u32; bits as usize * words_per_plane];
        for (idx, &c) in codes.iter().enumerate() {
            assert!(
                (c as u32) <= mask,
                "bitplanes: code {c} at index {idx} does not fit in {bits} bits"
            );
            for p in 0..bits {
                let bit = (c as u32 >> (bits - 1 - p)) & 1;
                words[p as usize * words_per_plane + idx / 32] |= bit << (idx % 32);
            }
        }
        BitPlanes { bits, len: codes.len(), words_per_plane, words }
    }

    /// Random-access decode of one code's top `prec` bits (cold paths and
    /// tests; hot loops use the range decoders below).
    #[inline]
    pub fn get(&self, idx: usize, prec: u32) -> u16 {
        debug_assert!(idx < self.len);
        debug_assert!(prec >= 1 && prec <= self.bits);
        let (w, bit) = (idx / 32, (idx % 32) as u32);
        let mut code = 0u16;
        for p in 0..prec as usize {
            code = (code << 1) | ((self.words[p * self.words_per_plane + w] >> bit) & 1) as u16;
        }
        code
    }

    /// Unpack a contiguous range at precision `prec`:
    /// `out[k] = code(start + k) >> (bits - prec)`. Walks each 32-element
    /// word column once per plane of the prefix — `prec` shift/mask ops
    /// per element, no per-element division.
    pub fn unpack_range(&self, start: usize, prec: u32, out: &mut [u16]) {
        debug_assert!(start + out.len() <= self.len);
        debug_assert!(prec >= 1 && prec <= self.bits);
        let wpp = self.words_per_plane;
        let mut idx = start;
        let mut o = 0usize;
        while o < out.len() {
            let (w, bit0) = (idx / 32, idx % 32);
            let take = (32 - bit0).min(out.len() - o);
            let run = &mut out[o..o + take];
            run.fill(0);
            for p in 0..prec as usize {
                let word = self.words[p * wpp + w] >> bit0;
                for (j, c) in run.iter_mut().enumerate() {
                    *c = (*c << 1) | ((word >> j) & 1) as u16;
                }
            }
            idx += take;
            o += take;
        }
    }

    /// Decode a contiguous range at precision `prec` through an f32 LUT:
    /// `out[k] = lut[code(start + k) >> (bits - prec)]`, where `lut` is the
    /// `2^prec`-entry table for that precision. Codes stage through a
    /// fixed stack buffer (one word column at a time), so the call is
    /// allocation-free — the plane-prefix analog of
    /// [`PackedCodes::unpack_map_f32`].
    pub fn unpack_map_f32(&self, start: usize, prec: u32, lut: &[f32], out: &mut [f32]) {
        debug_assert!(start + out.len() <= self.len);
        debug_assert!(prec >= 1 && prec <= self.bits);
        debug_assert!(lut.len() >= 1usize << prec);
        let wpp = self.words_per_plane;
        let mut codes = [0u16; 32];
        let mut idx = start;
        let mut o = 0usize;
        while o < out.len() {
            let (w, bit0) = (idx / 32, idx % 32);
            let take = (32 - bit0).min(out.len() - o);
            let staged = &mut codes[..take];
            staged.fill(0);
            for p in 0..prec as usize {
                let word = self.words[p * wpp + w] >> bit0;
                for (j, c) in staged.iter_mut().enumerate() {
                    *c = (*c << 1) | ((word >> j) & 1) as u16;
                }
            }
            for (ov, &c) in out[o..o + take].iter_mut().zip(staged.iter()) {
                *ov = lut[c as usize];
            }
            idx += take;
            o += take;
        }
    }

    /// Bytes of the full artifact (all planes).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 4
    }

    /// Bytes a precision-`prec` decode actually touches (its plane prefix).
    pub fn prefix_storage_bytes(&self, prec: u32) -> usize {
        debug_assert!(prec <= self.bits);
        prec as usize * self.words_per_plane * 4
    }

    /// All codes at precision `prec` (cold path).
    pub fn to_vec(&self, prec: u32) -> Vec<u16> {
        let mut out = vec![0u16; self.len];
        if self.len > 0 {
            self.unpack_range(0, prec, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn pack_unpack_round_trip_property() {
        testing::check("pack-roundtrip", 20, |rng| {
            let bits = 1 + rng.below(8) as u32;
            let n = 1 + rng.below(200);
            let max = (1u32 << bits) as usize;
            let codes: Vec<u16> = (0..n).map(|_| rng.below(max) as u16).collect();
            let packed = PackedCodes::pack(&codes, bits);
            testing::ensure(packed.to_vec() == codes, "roundtrip mismatch")?;
            let mut out = vec![0u16; n.min(7)];
            packed.unpack_range(0, &mut out);
            testing::ensure(out[..] == codes[..out.len()], "range mismatch")
        });
    }

    #[test]
    fn storage_is_compact() {
        let codes = vec![3u16; 64];
        let p2 = PackedCodes::pack(&codes, 2);
        assert_eq!(p2.storage_bytes(), 16); // 64*2 bits = 128 bits = 16 B
        let p4 = PackedCodes::pack(&codes, 4);
        assert_eq!(p4.storage_bytes(), 32);
    }

    #[test]
    fn unpack_map_f32_matches_staged_decode_property() {
        // The fused f32-table decode must agree with unpack_range + table
        // gather at every bit width, start offset, and length — including
        // word-aligned starts with non-word-multiple lengths (the tiled
        // GEMM window shape).
        testing::check("unpack-map-f32", 30, |rng| {
            let bits = 1 + rng.below(8) as u32;
            let n = 8 + rng.below(300);
            let levels = 1usize << bits;
            let codes: Vec<u16> = (0..n).map(|_| rng.below(levels) as u16).collect();
            let lut: Vec<f32> = (0..levels).map(|_| rng.normal_f32()).collect();
            let packed = PackedCodes::pack(&codes, bits);
            let start = rng.below(n);
            let len = rng.below(n - start + 1);
            let mut staged = vec![0u16; len];
            packed.unpack_range(start, &mut staged);
            let want: Vec<f32> = staged.iter().map(|&c| lut[c as usize]).collect();
            let mut got = vec![0.0f32; len];
            packed.unpack_map_f32(start, &lut, &mut got);
            testing::ensure(got == want, format!("bits={bits} start={start} len={len}"))
        });
    }

    #[test]
    #[should_panic(expected = "does not fit in 2 bits")]
    fn pack_rejects_out_of_range_codes() {
        PackedCodes::pack(&[1, 2, 7], 2);
    }

    #[test]
    fn three_bit_packing_crosses_words() {
        // 32/3 = 10 codes per word; code 10 starts a new word.
        let codes: Vec<u16> = (0..25).map(|i| (i % 8) as u16).collect();
        let p = PackedCodes::pack(&codes, 3);
        assert_eq!(p.to_vec(), codes);
    }

    #[test]
    fn get_at_word_boundaries_never_straddles() {
        // The no-straddle invariant `get` documents: at 3 bits, code 9 is
        // the last in word 0 (bits 27..30, with 30..32 pad) and code 10 is
        // the first in word 1 (bits 0..3). Both must decode whole from a
        // single-word read, with distinctive adjacent values so a straddle
        // (mixing word 0's pad bits into code 10, or truncating code 9)
        // cannot go unnoticed.
        let mut codes = vec![0u16; 25];
        codes[9] = 0b101; // last slot of word 0
        codes[10] = 0b110; // first slot of word 1
        codes[19] = 0b011; // last slot of word 1
        codes[20] = 0b111; // first slot of word 2
        let p = PackedCodes::pack(&codes, 3);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(p.get(i), c, "code {i}");
        }
        // Every stored width keeps off + bits <= 32 for the last slot of a
        // word — the arithmetic fact behind the single-word read.
        for bits in 1..=16u32 {
            let per_word = 32 / bits;
            assert!((per_word - 1) * bits + bits <= 32, "bits={bits} would straddle");
        }
        // The range decoders cross the same boundary identically.
        let mut out = vec![0u16; 4];
        p.unpack_range(8, &mut out);
        assert_eq!(out, codes[8..12]);
    }

    #[test]
    fn bitplane_round_trip_property() {
        // Full-precision decode recovers the codes exactly; every prefix
        // precision yields the codes' top bits (`code >> (bits - prec)`).
        // Lengths are deliberately non-word-aligned (the `+ 1 + below`
        // draw makes multiples of 32 rare), exercising the partial final
        // word column of every plane.
        testing::check("bitplane-roundtrip", 24, |rng| {
            let bits = 2 + rng.below(3) as u32; // 2, 3, 4
            let n = 1 + rng.below(300);
            let max = 1usize << bits;
            let codes: Vec<u16> = (0..n).map(|_| rng.below(max) as u16).collect();
            let planes = BitPlanes::pack(&codes, bits);
            testing::ensure(planes.to_vec(bits) == codes, "full-precision roundtrip")?;
            for prec in 1..=bits {
                let want: Vec<u16> = codes.iter().map(|&c| c >> (bits - prec)).collect();
                testing::ensure(
                    planes.to_vec(prec) == want,
                    format!("prefix decode bits={bits} prec={prec} n={n}"),
                )?;
                let idx = rng.below(n);
                testing::ensure(
                    planes.get(idx, prec) == want[idx],
                    format!("get({idx}, {prec})"),
                )?;
            }
            testing::ensure(
                planes.prefix_storage_bytes(1) * bits as usize == planes.storage_bytes(),
                "plane prefix bytes",
            )
        });
    }

    #[test]
    fn bitplane_unpack_map_f32_matches_staged_decode_property() {
        // The fused LUT decode must agree with unpack_range + gather at
        // every precision, start offset, and length — including runs that
        // start mid-word-column and spill across columns.
        testing::check("bitplane-map-f32", 30, |rng| {
            let bits = 2 + rng.below(3) as u32;
            let n = 8 + rng.below(300);
            let codes: Vec<u16> = (0..n).map(|_| rng.below(1usize << bits) as u16).collect();
            let planes = BitPlanes::pack(&codes, bits);
            let prec = 1 + rng.below(bits as usize) as u32;
            let lut: Vec<f32> = (0..1usize << prec).map(|_| rng.normal_f32()).collect();
            let start = rng.below(n);
            let len = rng.below(n - start + 1);
            let mut staged = vec![0u16; len];
            planes.unpack_range(start, prec, &mut staged);
            let want: Vec<f32> = staged.iter().map(|&c| lut[c as usize]).collect();
            let mut got = vec![0.0f32; len];
            planes.unpack_map_f32(start, prec, &lut, &mut got);
            testing::ensure(got == want, format!("bits={bits} prec={prec} start={start} len={len}"))
        });
    }

    #[test]
    fn bitplane_storage_matches_element_packing_at_full_width() {
        // Plane-major storage costs the same bits as element-major packing
        // (modulo per-word padding): 64 4-bit codes = 32 bytes either way.
        let codes: Vec<u16> = (0..64).map(|i| (i % 16) as u16).collect();
        let planes = BitPlanes::pack(&codes, 4);
        assert_eq!(planes.storage_bytes(), PackedCodes::pack(&codes, 4).storage_bytes());
        assert_eq!(planes.prefix_storage_bytes(2), 16, "2-bit reads touch half the words");
    }

    #[test]
    #[should_panic(expected = "does not fit in 2 bits")]
    fn bitplane_pack_rejects_out_of_range_codes() {
        BitPlanes::pack(&[1, 2, 7], 2);
    }
}
