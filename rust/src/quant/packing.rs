//! Bit-packing of code indices into u32 words — the storage format behind
//! the serving-engine formats (Table 2's bits accounting is real bytes).

/// Codes packed `bits` per element into u32 words, row-major.
#[derive(Debug, Clone)]
pub struct PackedCodes {
    pub bits: u32,
    pub len: usize,
    words: Vec<u32>,
}

impl PackedCodes {
    pub fn pack(codes: &[u16], bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 16, "pack: bit width {bits} outside 1..=16");
        let per_word = 32 / bits as usize;
        let n_words = codes.len().div_ceil(per_word);
        let mask = (1u32 << bits) - 1;
        let mut words = vec![0u32; n_words];
        for (idx, &c) in codes.iter().enumerate() {
            // Always-on (not debug_assert): a silently truncated code would
            // decode to the wrong weight for the lifetime of the format.
            assert!(
                (c as u32) <= mask,
                "pack: code {c} at index {idx} does not fit in {bits} bits"
            );
            let w = idx / per_word;
            let off = (idx % per_word) as u32 * bits;
            words[w] |= ((c as u32) & mask) << off;
        }
        PackedCodes { bits, len: codes.len(), words }
    }

    #[inline]
    pub fn get(&self, idx: usize) -> u16 {
        debug_assert!(idx < self.len);
        let per_word = 32 / self.bits as usize;
        let w = idx / per_word;
        let off = (idx % per_word) as u32 * self.bits;
        ((self.words[w] >> off) & ((1u32 << self.bits) - 1)) as u16
    }

    /// Unpack a contiguous range (hot path: one shift/mask per element,
    /// word-at-a-time — no per-element division). Word-aligned ranges with
    /// power-of-two bits take a branch-free unrolled path.
    pub fn unpack_range(&self, start: usize, out: &mut [u16]) {
        debug_assert!(start + out.len() <= self.len);
        let bits = self.bits as usize;
        let per_word = 32 / bits;
        let mask = (1u32 << bits) - 1;
        if 32 % bits == 0 && start % per_word == 0 && out.len() % per_word == 0 {
            let w0 = start / per_word;
            for (chunk, &w) in out.chunks_exact_mut(per_word).zip(&self.words[w0..]) {
                let mut word = w;
                for o in chunk {
                    *o = (word & mask) as u16;
                    word >>= bits;
                }
            }
            return;
        }
        let mut w = start / per_word;
        let mut off = (start % per_word) * bits;
        let mut word = self.words[w] >> off;
        for o in out.iter_mut() {
            *o = (word & mask) as u16;
            off += bits;
            if off + bits > 32 {
                w += 1;
                off = 0;
                word = *self.words.get(w).unwrap_or(&0);
            } else {
                word >>= bits;
            }
        }
    }

    /// Decode a contiguous code range through an f32 lookup table:
    /// `out[k] = lut[code(start + k)]`. This is the tile-granular decode
    /// fast path of the serving formats — codes go straight from packed
    /// words to dequantized f32 (tables are pre-expanded at format
    /// construction), with no u16 staging buffer and no per-element
    /// int→float convert in the caller's inner loop. Word-aligned starts
    /// with power-of-two bit widths take a word-at-a-time path for any
    /// output length; other starts fall back to the rolling-word decode.
    pub fn unpack_map_f32(&self, start: usize, lut: &[f32], out: &mut [f32]) {
        debug_assert!(start + out.len() <= self.len);
        debug_assert!(lut.len() >= (1usize << self.bits.min(16)));
        let bits = self.bits as usize;
        let per_word = 32 / bits;
        let mask = (1u32 << bits) - 1;
        if 32 % bits == 0 && start % per_word == 0 {
            let w0 = start / per_word;
            let mut chunks = out.chunks_exact_mut(per_word);
            let mut used = 0usize;
            for (chunk, &wd) in (&mut chunks).zip(&self.words[w0..]) {
                let mut word = wd;
                for o in chunk {
                    *o = lut[(word & mask) as usize];
                    word >>= bits;
                }
                used += 1;
            }
            let rem = chunks.into_remainder();
            if !rem.is_empty() {
                let mut word = self.words[w0 + used];
                for o in rem {
                    *o = lut[(word & mask) as usize];
                    word >>= bits;
                }
            }
            return;
        }
        let mut w = start / per_word;
        let mut off = (start % per_word) * bits;
        let mut word = self.words[w] >> off;
        for o in out.iter_mut() {
            *o = lut[(word & mask) as usize];
            off += bits;
            if off + bits > 32 {
                w += 1;
                off = 0;
                word = *self.words.get(w).unwrap_or(&0);
            } else {
                word >>= bits;
            }
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 4
    }

    /// Raw packed words (for fused decode loops in the serving formats).
    #[inline]
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// True if rows of length `row_len` starting at multiples of `row_len`
    /// are word-aligned (the fused serving decode requires this).
    pub fn rows_aligned(&self, row_len: usize) -> bool {
        32 % self.bits == 0 && row_len % (32 / self.bits as usize) == 0
    }

    pub fn to_vec(&self) -> Vec<u16> {
        (0..self.len).map(|i| self.get(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn pack_unpack_round_trip_property() {
        testing::check("pack-roundtrip", 20, |rng| {
            let bits = 1 + rng.below(8) as u32;
            let n = 1 + rng.below(200);
            let max = (1u32 << bits) as usize;
            let codes: Vec<u16> = (0..n).map(|_| rng.below(max) as u16).collect();
            let packed = PackedCodes::pack(&codes, bits);
            testing::ensure(packed.to_vec() == codes, "roundtrip mismatch")?;
            let mut out = vec![0u16; n.min(7)];
            packed.unpack_range(0, &mut out);
            testing::ensure(out[..] == codes[..out.len()], "range mismatch")
        });
    }

    #[test]
    fn storage_is_compact() {
        let codes = vec![3u16; 64];
        let p2 = PackedCodes::pack(&codes, 2);
        assert_eq!(p2.storage_bytes(), 16); // 64*2 bits = 128 bits = 16 B
        let p4 = PackedCodes::pack(&codes, 4);
        assert_eq!(p4.storage_bytes(), 32);
    }

    #[test]
    fn unpack_map_f32_matches_staged_decode_property() {
        // The fused f32-table decode must agree with unpack_range + table
        // gather at every bit width, start offset, and length — including
        // word-aligned starts with non-word-multiple lengths (the tiled
        // GEMM window shape).
        testing::check("unpack-map-f32", 30, |rng| {
            let bits = 1 + rng.below(8) as u32;
            let n = 8 + rng.below(300);
            let levels = 1usize << bits;
            let codes: Vec<u16> = (0..n).map(|_| rng.below(levels) as u16).collect();
            let lut: Vec<f32> = (0..levels).map(|_| rng.normal_f32()).collect();
            let packed = PackedCodes::pack(&codes, bits);
            let start = rng.below(n);
            let len = rng.below(n - start + 1);
            let mut staged = vec![0u16; len];
            packed.unpack_range(start, &mut staged);
            let want: Vec<f32> = staged.iter().map(|&c| lut[c as usize]).collect();
            let mut got = vec![0.0f32; len];
            packed.unpack_map_f32(start, &lut, &mut got);
            testing::ensure(got == want, format!("bits={bits} start={start} len={len}"))
        });
    }

    #[test]
    #[should_panic(expected = "does not fit in 2 bits")]
    fn pack_rejects_out_of_range_codes() {
        PackedCodes::pack(&[1, 2, 7], 2);
    }

    #[test]
    fn three_bit_packing_crosses_words() {
        // 32/3 = 10 codes per word; code 10 starts a new word.
        let codes: Vec<u16> = (0..25).map(|i| (i % 8) as u16).collect();
        let p = PackedCodes::pack(&codes, 3);
        assert_eq!(p.to_vec(), codes);
    }
}
