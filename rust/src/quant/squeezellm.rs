//! SqueezeLLM (Kim et al., 2024) — weight-only non-uniform scalar PTQ via
//! sensitivity-weighted k-means per output channel (the paper's Eq. 3
//! objective with the diagonal Fisher approximation).
//!
//! Not a layer-wise output-based method: it never sees H, only the
//! per-weight diagonal Fisher F_kk (from `fisher::` / the calib_stats
//! artifact). `Weighted k-means` column in Figure 2.

use anyhow::Result;

use crate::tensor::Mat;
use crate::util::Rng;

use super::grid::avg_bits_scalar;
use super::QuantResult;

#[derive(Debug, Clone)]
pub struct SqueezeLlm {
    pub bits: u32,
    /// Lloyd iterations per channel.
    pub iters: usize,
    pub seed: u64,
}

impl SqueezeLlm {
    pub fn new(bits: u32) -> Self {
        SqueezeLlm { bits, iters: 50, seed: 0 }
    }
}

/// Quantize `w` with per-weight sensitivities (d_in × d_out, non-negative).
/// Each output channel j solves a weighted 1-D k-means over its column.
pub fn squeezellm_quantize(w: &Mat, sensitivity: &Mat, cfg: &SqueezeLlm) -> Result<QuantResult> {
    assert_eq!((w.rows, w.cols), (sensitivity.rows, sensitivity.cols));
    let d_in = w.rows;
    let d_out = w.cols;
    let m = 1usize << cfg.bits;
    let mut codebooks = Mat::zeros(d_out, m);
    let mut codes = vec![0u16; d_in * d_out];
    let mut w_hat = Mat::zeros(d_in, d_out);
    let mut rng = Rng::new(cfg.seed ^ 0x53715a);
    for j in 0..d_out {
        let col = w.col(j);
        // Zero sensitivity would let k-means ignore a weight entirely; floor
        // it so every weight still rounds to a meaningful center.
        let ws: Vec<f32> = (0..d_in).map(|i| sensitivity.at(i, j).max(1e-12)).collect();
        let km = super::kmeans1d::lloyd(&col, &ws, m, cfg.iters, &mut rng);
        for q in 0..m {
            *codebooks.at_mut(j, q) = *km.centers.get(q).unwrap_or(km.centers.last().unwrap());
        }
        for i in 0..d_in {
            let q = km.assign[i];
            codes[i * d_out + j] = q;
            *w_hat.at_mut(i, j) = codebooks.at(j, q as usize);
        }
    }
    Ok(QuantResult {
        w_hat,
        codes: Some(codes),
        codebooks: Some(codebooks),
        avg_bits: avg_bits_scalar(d_in, d_out, cfg.bits),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::objective::weight_mse;
    use crate::testing;

    #[test]
    fn uniform_sensitivity_is_plain_kmeans() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(64, 2, 1.0, &mut rng);
        let s = Mat::from_fn(64, 2, |_, _| 1.0);
        let res = squeezellm_quantize(&w, &s, &SqueezeLlm::new(3)).unwrap();
        // 8 levels on 64 gaussians: small MSE.
        assert!(weight_mse(&w, &res.w_hat) < 0.05);
    }

    #[test]
    fn high_sensitivity_weights_are_prioritized() {
        testing::check("sqllm-sensitivity", 8, |rng| {
            let d = 48;
            let w = Mat::randn(d, 1, 1.0, rng);
            let mut s = Mat::from_fn(d, 1, |_, _| 1e-6);
            // Mark 4 weights as critical.
            for i in 0..4 {
                *s.at_mut(i * 10, 0) = 1e3;
            }
            let res = squeezellm_quantize(&w, &s, &SqueezeLlm::new(2)).unwrap();
            // Critical weights should have much lower error than average.
            let mut crit = 0.0f64;
            for i in 0..4 {
                crit += ((w.at(i * 10, 0) - res.w_hat.at(i * 10, 0)) as f64).powi(2);
            }
            let total = res.w_hat.sub(&w).frob_norm_sq();
            testing::ensure(
                crit / 4.0 <= total / d as f64 + 1e-9,
                format!("critical err {} vs avg {}", crit / 4.0, total / d as f64),
            )
        });
    }

    #[test]
    fn codes_decode_to_w_hat() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(16, 3, 1.0, &mut rng);
        let s = Mat::from_fn(16, 3, |_, _| 1.0);
        let res = squeezellm_quantize(&w, &s, &SqueezeLlm::new(2)).unwrap();
        let codes = res.codes.unwrap();
        let cbs = res.codebooks.unwrap();
        for i in 0..16 {
            for j in 0..3 {
                assert_eq!(res.w_hat.at(i, j), cbs.at(j, codes[i * 3 + j] as usize));
            }
        }
    }
}
