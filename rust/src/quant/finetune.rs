//! PV-tuning-lite: post-PTQ refinement toward the end-to-end loss
//! (paper Table 15 analog, substitution documented in DESIGN.md §2).
//!
//! Upstream PV-Tuning backpropagates through the quantized model to update
//! codebook values (V-step) and occasionally assignments (P-step). Without
//! a backward artifact for arbitrary quantized weights, we implement the
//! cascade variant used by AQLM-style pipelines: layers are refit in order
//! against activations recorded from the *quantized* model (so each layer
//! compensates the error the earlier quantized layers introduced — the
//! end-to-end signal), alternating the exact codebook LS step (V) and CD on
//! assignments (P).

use anyhow::Result;

use crate::model::{NativeModel, ParamStore};
use crate::tensor::ops::matmul_tn;
use crate::tensor::Mat;

use super::cd::{cd_inplace, CdConfig};
use super::grid::LutGrid;
use super::lnq::{codebook_ls_update, decode};

/// One quantized linear's mutable code state.
pub struct TunableLayer {
    pub name: String,
    pub codes: Vec<u16>,
    pub codebooks: Mat,
    pub d_in: usize,
}

/// Cascade fine-tune: for each layer (in forward order), recompute its
/// input Gram matrix from the current quantized model, then refit codebook
/// (exact LS) and assignments (CD). Returns the updated parameter store.
///
/// `base` holds the original fp weights for non-quantized params and the
/// *target* weights W for each quantized layer.
pub fn cascade_finetune(
    base: &ParamStore,
    layers: &mut [TunableLayer],
    tokens: &[u32],
    rounds: usize,
    cd: CdConfig,
) -> Result<ParamStore> {
    let mut current = base.clone();
    let specs = current.cfg.linear_specs();
    // Install current quantized weights (validating names up front).
    for layer in layers.iter() {
        anyhow::ensure!(
            specs.iter().any(|s| s.name == layer.name),
            "unknown layer {}",
            layer.name
        );
        current.set(&layer.name, decode(&layer.codes, &layer.codebooks, layer.d_in));
    }
    for _ in 0..rounds {
        for li in 0..layers.len() {
            // Record activations of the quantized-so-far model.
            let model = NativeModel::from_params(&current);
            let xs = model.record_linear_inputs(tokens);
            // Find this layer's flat index by name.
            let specs = current.cfg.linear_specs();
            let idx = specs
                .iter()
                .position(|s| s.name == layers[li].name)
                .ok_or_else(|| anyhow::anyhow!("unknown layer {}", layers[li].name))?;
            let x = &xs[idx];
            let h = matmul_tn(x, x);
            let w_target = base.get(&layers[li].name).clone();
            let layer = &mut layers[li];
            // V-step: exact codebook LS refit against the fresh H.
            codebook_ls_update(&h, &w_target, &layer.codes, &mut layer.codebooks)?;
            // P-step: CD on assignments.
            let mut w_hat = decode(&layer.codes, &layer.codebooks, layer.d_in);
            let grid = LutGrid::new(layer.codebooks.clone());
            cd_inplace(&h, &w_target, &mut w_hat, &mut layer.codes, &grid, cd);
            codebook_ls_update(&h, &w_target, &layer.codes, &mut layer.codebooks)?;
            let w_new = decode(&layer.codes, &layer.codebooks, layer.d_in);
            current.set(&layer.name, w_new);
        }
    }
    Ok(current)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::quant::lnq::{lnq_quantize, Lnq};
    use crate::util::Rng;

    #[test]
    fn cascade_finetune_does_not_hurt_loss() {
        let (cfg, _) = preset("tiny");
        let mut rng = Rng::new(0);
        let ps = ParamStore::init(&cfg, &mut rng);
        let toks: Vec<u32> = (0..32).map(|_| rng.below(cfg.vocab) as u32).collect();

        // Quantize the first two linears crudely at 2 bits.
        let model = NativeModel::from_params(&ps);
        let xs = model.record_linear_inputs(&toks);
        let mut layers = Vec::new();
        let mut quantized = ps.clone();
        for (i, spec) in cfg.linear_specs().into_iter().take(2).enumerate() {
            let h = matmul_tn(&xs[i], &xs[i]);
            let w = ps.get(&spec.name).clone();
            let res = lnq_quantize(&h, &w, &Lnq { t_iters: 1, ..Lnq::new(2) }).unwrap();
            quantized.set(&spec.name, res.w_hat.clone());
            layers.push(TunableLayer {
                name: spec.name.clone(),
                codes: res.codes.unwrap(),
                codebooks: res.codebooks.unwrap(),
                d_in: spec.d_in,
            });
        }
        let before = NativeModel::from_params(&quantized).loss_sum(&toks);
        let tuned = cascade_finetune(&ps, &mut layers, &toks, 1, CdConfig::default()).unwrap();
        let after = NativeModel::from_params(&tuned).loss_sum(&toks);
        // Fine-tuning on the same tokens should not make things worse
        // (allow small slack for CD tie-breaking noise).
        assert!(after <= before * 1.02, "finetune hurt: {before} -> {after}");
    }

    #[test]
    fn unknown_layer_name_errors() {
        let (cfg, _) = preset("tiny");
        let mut rng = Rng::new(1);
        let ps = ParamStore::init(&cfg, &mut rng);
        let mut layers = vec![TunableLayer {
            name: "layers.9.wq".into(),
            codes: vec![0; 4],
            codebooks: Mat::zeros(2, 2),
            d_in: 2,
        }];
        let toks = [0u32, 1];
        assert!(cascade_finetune(&ps, &mut layers, &toks, 1, CdConfig::default()).is_err());
    }
}
