//! Weighted 1-D k-means: Lloyd's algorithm with k-means++ seeding (what
//! SqueezeLLM uses) and an exact O(n·k) dynamic program (SMAWK-free variant
//! of Grønlund et al. 2017) for ground-truth comparisons at small n.
//!
//! Minimizes Σ_i w_i (x_i − c_{a(i)})² — the weighted k-means objective the
//! paper's Eq. (3) reduces to for non-uniform scalar quantization.

use crate::util::Rng;

/// Result: cluster centers (sorted ascending) and per-point assignment.
#[derive(Debug, Clone)]
pub struct KMeans1d {
    pub centers: Vec<f32>,
    pub assign: Vec<u16>,
    pub objective: f64,
}

fn objective(xs: &[f32], ws: &[f32], centers: &[f32], assign: &[u16]) -> f64 {
    xs.iter()
        .zip(ws)
        .zip(assign)
        .map(|((&x, &w), &a)| {
            let d = (x - centers[a as usize]) as f64;
            w as f64 * d * d
        })
        .sum()
}

fn assign_nearest(xs: &[f32], centers: &[f32]) -> Vec<u16> {
    xs.iter()
        .map(|&x| {
            let mut best = 0u16;
            let mut bd = f32::INFINITY;
            for (q, &c) in centers.iter().enumerate() {
                let d = (x - c) * (x - c);
                if d < bd {
                    bd = d;
                    best = q as u16;
                }
            }
            best
        })
        .collect()
}

/// k-means++ seeding over the weighted points.
fn seed_pp(xs: &[f32], ws: &[f32], k: usize, rng: &mut Rng) -> Vec<f32> {
    let mut centers = Vec::with_capacity(k);
    let wsum: Vec<f64> = ws.iter().map(|&w| w.max(0.0) as f64).collect();
    centers.push(xs[rng.weighted(&wsum)]);
    let mut d2: Vec<f64> = xs
        .iter()
        .zip(&wsum)
        .map(|(&x, &w)| w * ((x - centers[0]) as f64).powi(2))
        .collect();
    while centers.len() < k {
        let idx = rng.weighted(&d2);
        let c = xs[idx];
        centers.push(c);
        for (i, &x) in xs.iter().enumerate() {
            let nd = wsum[i] * ((x - c) as f64).powi(2);
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centers
}

/// Lloyd's algorithm with k-means++ init (SqueezeLLM's solver).
/// Zero-weight points are assigned but do not influence centers.
pub fn lloyd(xs: &[f32], ws: &[f32], k: usize, iters: usize, rng: &mut Rng) -> KMeans1d {
    assert_eq!(xs.len(), ws.len());
    assert!(k >= 1 && !xs.is_empty());
    let k = k.min(xs.len());
    let mut centers = seed_pp(xs, ws, k, rng);
    let mut assign = assign_nearest(xs, &centers);
    for _ in 0..iters {
        // Update step: weighted means.
        let mut num = vec![0.0f64; k];
        let mut den = vec![0.0f64; k];
        for ((&x, &w), &a) in xs.iter().zip(ws).zip(&assign) {
            num[a as usize] += (w as f64) * (x as f64);
            den[a as usize] += w as f64;
        }
        for q in 0..k {
            if den[q] > 0.0 {
                centers[q] = (num[q] / den[q]) as f32;
            }
        }
        let new_assign = assign_nearest(xs, &centers);
        if new_assign == assign {
            break;
        }
        assign = new_assign;
    }
    let mut centers_sorted: Vec<f32> = centers.clone();
    centers_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let assign = assign_nearest(xs, &centers_sorted);
    let objective = objective(xs, ws, &centers_sorted, &assign);
    KMeans1d { centers: centers_sorted, assign, objective }
}

/// Exact weighted 1-D k-means by dynamic programming over sorted points.
/// O(n²·k) — ground truth for tests and small problems.
pub fn exact_dp(xs: &[f32], ws: &[f32], k: usize) -> KMeans1d {
    let n = xs.len();
    assert!(n > 0 && k >= 1);
    let k = k.min(n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let sx: Vec<f64> = order.iter().map(|&i| xs[i] as f64).collect();
    let sw: Vec<f64> = order.iter().map(|&i| (ws[i] as f64).max(0.0)).collect();
    // Prefix sums for O(1) interval cost.
    let mut pw = vec![0.0; n + 1];
    let mut pwx = vec![0.0; n + 1];
    let mut pwx2 = vec![0.0; n + 1];
    for i in 0..n {
        pw[i + 1] = pw[i] + sw[i];
        pwx[i + 1] = pwx[i] + sw[i] * sx[i];
        pwx2[i + 1] = pwx2[i] + sw[i] * sx[i] * sx[i];
    }
    // cost of clustering sorted points [a, b) into one cluster at their mean
    let cost = |a: usize, b: usize| -> f64 {
        let w = pw[b] - pw[a];
        if w <= 0.0 {
            return 0.0;
        }
        let wx = pwx[b] - pwx[a];
        let wx2 = pwx2[b] - pwx2[a];
        (wx2 - wx * wx / w).max(0.0)
    };
    // dp[q][b] = best cost of first b points with q clusters.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; k + 1];
    let mut arg = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for q in 1..=k {
        for b in 1..=n {
            for a in (q - 1)..b {
                if dp[q - 1][a] == inf {
                    continue;
                }
                let c = dp[q - 1][a] + cost(a, b);
                if c < dp[q][b] {
                    dp[q][b] = c;
                    arg[q][b] = a;
                }
            }
        }
    }
    // Backtrack boundaries -> centers.
    let mut bounds = vec![n];
    let mut b = n;
    for q in (1..=k).rev() {
        b = arg[q][b];
        bounds.push(b);
    }
    bounds.reverse();
    let mut centers = Vec::with_capacity(k);
    for win in bounds.windows(2) {
        let (a, b) = (win[0], win[1]);
        let w = pw[b] - pw[a];
        let c = if w > 0.0 {
            ((pwx[b] - pwx[a]) / w) as f32
        } else if b > a {
            sx[a] as f32
        } else {
            0.0
        };
        centers.push(c);
    }
    centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let assign = assign_nearest(xs, &centers);
    let objective = objective(xs, ws, &centers, &assign);
    KMeans1d { centers, assign, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn lloyd_separates_obvious_clusters() {
        let xs = [0.0, 0.1, -0.1, 5.0, 5.2, 4.8];
        let ws = [1.0f32; 6];
        let km = lloyd(&xs, &ws, 2, 50, &mut Rng::new(0));
        assert!((km.centers[0] - 0.0).abs() < 0.2, "{:?}", km.centers);
        assert!((km.centers[1] - 5.0).abs() < 0.2, "{:?}", km.centers);
        assert_eq!(km.assign[0], km.assign[1]);
        assert_ne!(km.assign[0], km.assign[3]);
    }

    #[test]
    fn weights_pull_centers() {
        // A huge weight on one point should place a center on it exactly.
        let xs = [0.0, 1.0, 2.0];
        let ws = [1.0, 1000.0, 1.0];
        let km = lloyd(&xs, &ws, 2, 50, &mut Rng::new(1));
        assert!(km.centers.iter().any(|&c| (c - 1.0).abs() < 0.01), "{:?}", km.centers);
    }

    #[test]
    fn exact_dp_is_optimal_vs_lloyd() {
        testing::check("dp-beats-lloyd", 20, |rng| {
            let n = 8 + rng.below(24);
            let k = 2 + rng.below(3);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let ws: Vec<f32> = (0..n).map(|_| rng.f32() + 0.01).collect();
            let dp = exact_dp(&xs, &ws, k);
            let ll = lloyd(&xs, &ws, k, 100, rng);
            testing::ensure(
                dp.objective <= ll.objective + 1e-6 * (1.0 + ll.objective),
                format!("dp {} > lloyd {}", dp.objective, ll.objective),
            )
        });
    }

    #[test]
    fn exact_dp_zero_cost_when_k_equals_n() {
        let xs = [1.0, 2.0, 3.0];
        let ws = [1.0f32; 3];
        let dp = exact_dp(&xs, &ws, 3);
        assert!(dp.objective < 1e-12);
    }

    #[test]
    fn lloyd_objective_matches_manual() {
        let xs = [0.0, 1.0, 10.0, 11.0];
        let ws = [1.0f32; 4];
        let km = lloyd(&xs, &ws, 2, 50, &mut Rng::new(2));
        // centers 0.5 and 10.5, objective = 4 * 0.25
        assert!((km.objective - 1.0).abs() < 1e-6, "{}", km.objective);
    }

    #[test]
    fn zero_weights_handled() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ws = [0.0f32; 4];
        let km = lloyd(&xs, &ws, 2, 10, &mut Rng::new(3));
        assert_eq!(km.assign.len(), 4);
        assert!(km.objective == 0.0);
        let dp = exact_dp(&xs, &ws, 2);
        assert_eq!(dp.objective, 0.0);
    }
}
