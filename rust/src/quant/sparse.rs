//! Dense-and-sparse decomposition (SqueezeLLM §Dense-and-Sparse, paper
//! Table 17): keep a small fraction of weights in full precision (outliers
//! by magnitude or sensitivity), quantize the dense remainder with any
//! method, and overlay the sparse values at decode time.

use crate::tensor::Mat;

use super::{LayerQuantizer, QuantResult};

/// Bits charged per sparse outlier (fp16 value + 32-bit COO index), shared
/// by the dense-and-sparse wrapper and the pipeline's avg-bits accounting.
pub const SPARSE_OUTLIER_BITS: f64 = 48.0;

/// COO sparse overlay.
#[derive(Debug, Clone, Default)]
pub struct SparseOverlay {
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl SparseOverlay {
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn apply(&self, w: &mut Mat) {
        for ((&i, &j), &v) in self.rows.iter().zip(&self.cols).zip(&self.vals) {
            *w.at_mut(i as usize, j as usize) = v;
        }
    }
}

/// Select the top `frac` weights by score (|w| or a sensitivity matrix) and
/// split: returns (dense W with outliers zeroed, overlay of originals).
pub fn split_outliers(w: &Mat, score: Option<&Mat>, frac: f32) -> (Mat, SparseOverlay) {
    let total = w.rows * w.cols;
    let keep = ((total as f64) * frac as f64).round() as usize;
    if keep == 0 {
        return (w.clone(), SparseOverlay::default());
    }
    let mut idx: Vec<usize> = (0..total).collect();
    let key = |t: usize| -> f32 {
        let (i, j) = (t / w.cols, t % w.cols);
        match score {
            Some(s) => s.at(i, j).abs(),
            None => w.at(i, j).abs(),
        }
    };
    idx.select_nth_unstable_by(total - keep, |&a, &b| key(a).partial_cmp(&key(b)).unwrap());
    let chosen = &idx[total - keep..];
    let mut dense = w.clone();
    let mut ov = SparseOverlay::default();
    for &t in chosen {
        let (i, j) = (t / w.cols, t % w.cols);
        ov.rows.push(i as u32);
        ov.cols.push(j as u32);
        ov.vals.push(w.at(i, j));
        *dense.at_mut(i, j) = 0.0;
    }
    (dense, ov)
}

/// Dense-and-sparse wrapper around any layer quantizer.
pub struct DenseAndSparse<Q: LayerQuantizer> {
    pub inner: Q,
    pub frac: f32,
}

impl<Q: LayerQuantizer> DenseAndSparse<Q> {
    pub fn new(inner: Q, frac: f32) -> Self {
        DenseAndSparse { inner, frac }
    }
}

impl<Q: LayerQuantizer> LayerQuantizer for DenseAndSparse<Q> {
    fn quantize(&self, h: &Mat, w: &Mat) -> anyhow::Result<QuantResult> {
        let (dense, overlay) = split_outliers(w, None, self.frac);
        let mut res = self.inner.quantize(h, &dense)?;
        overlay.apply(&mut res.w_hat);
        let total = (w.rows * w.cols) as f64;
        res.avg_bits += overlay.len() as f64 * SPARSE_OUTLIER_BITS / total;
        Ok(res)
    }

    fn name(&self) -> &'static str {
        "dense+sparse"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::rtn_quantize;
    use crate::quant::objective::proxy_loss;
    use crate::tensor::ops::matmul_tn;
    use crate::util::Rng;

    #[test]
    fn split_extracts_exact_fraction() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(40, 10, 1.0, &mut rng);
        let (dense, ov) = split_outliers(&w, None, 0.01);
        assert_eq!(ov.len(), 4); // 1% of 400
        for ((&i, &j), &v) in ov.rows.iter().zip(&ov.cols).zip(&ov.vals) {
            assert_eq!(dense.at(i as usize, j as usize), 0.0);
            assert_eq!(v, w.at(i as usize, j as usize));
        }
    }

    #[test]
    fn outliers_are_the_largest_magnitudes() {
        let mut rng = Rng::new(1);
        let mut w = Mat::randn(20, 5, 0.1, &mut rng);
        *w.at_mut(3, 2) = 50.0;
        *w.at_mut(10, 0) = -40.0;
        let (_, ov) = split_outliers(&w, None, 0.02);
        assert_eq!(ov.len(), 2);
        let mut vals: Vec<f32> = ov.vals.iter().map(|v| v.abs()).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![40.0, 50.0]);
    }

    #[test]
    fn overlay_restores_exact_values() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(16, 4, 1.0, &mut rng);
        let (dense, ov) = split_outliers(&w, None, 0.1);
        let mut back = dense.clone();
        ov.apply(&mut back);
        for ((&i, &j), _) in ov.rows.iter().zip(&ov.cols).zip(&ov.vals) {
            assert_eq!(back.at(i as usize, j as usize), w.at(i as usize, j as usize));
        }
    }

    #[test]
    fn dense_and_sparse_improves_objective_with_heavy_outliers() {
        let mut rng = Rng::new(3);
        let x = Mat::randn(64, 24, 1.0, &mut rng);
        let h = matmul_tn(&x, &x);
        let mut w = Mat::randn(24, 6, 0.2, &mut rng);
        // Plant outliers that wreck a 2-bit grid.
        *w.at_mut(0, 0) = 8.0;
        *w.at_mut(5, 3) = -7.0;
        let plain = rtn_quantize(&w, 2);
        let plain_obj = proxy_loss(&h, &w, &plain.w_hat);
        let (dense, ov) = split_outliers(&w, None, 0.02);
        let mut ds = rtn_quantize(&dense, 2);
        ov.apply(&mut ds.w_hat);
        let ds_obj = proxy_loss(&h, &w, &ds.w_hat);
        assert!(ds_obj < plain_obj, "dense+sparse {ds_obj} !< plain {plain_obj}");
    }

    #[test]
    fn zero_fraction_is_noop() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(8, 3, 1.0, &mut rng);
        let (dense, ov) = split_outliers(&w, None, 0.0);
        assert!(ov.is_empty());
        assert_eq!(dense, w);
    }
}
