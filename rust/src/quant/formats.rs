//! Serving formats: fused dequant kernels implementing
//! [`model::forward::LinearOp`] so the decode engine can serve any format.
//!
//! These are the CPU analogs of the paper's CUDA kernels (Table 2):
//! * [`UniformScalarLinear`] — LUT-GEMM-style: packed codes + affine grid,
//! * [`LutLinear`]           — Any-Precision-LLM-style: packed codes +
//!                             per-channel codebook gather,
//! * [`AnyPrecisionLinear`]  — bit-plane codes ([`BitPlanes`]) + per-
//!                             precision LUT slices: ONE stored artifact
//!                             ([`AnyPrecArtifact`], `Arc`-shared between
//!                             views) decodes at any requested precision
//!                             `1..=bits`; the full-precision view is
//!                             bit-identical to [`LutLinear`],
//! * [`VqLinear`]            — vector codebook decode per dim-point,
//! * [`TrellisLinear`]       — QTIP-style stateful decode (extra ALU work
//!                             per weight → the paper's vector-quant decode
//!                             overhead shows up honestly).
//!
//! Every format provides three kernels with exactly equal per-element
//! results (the tile contract on [`LinearOp`]): the scalar `matvec`
//! reference, a row-at-a-time batched window kernel (`matmul_cols`, the
//! `GQ_TILE=0` fallback), and the decode-once hooks for the shared tiled
//! GEMM engine (`decode_tile` + `tile_epilogue`, `tensor::gemm`). All
//! code→value tables are pre-expanded to f32 at construction (no
//! per-element `as f32` converts or generator hashes in inner loops), all
//! staging buffers are thread-local scratch (warm kernels allocate
//! nothing), and constructors validate code/table shapes with clear errors
//! instead of debug-only assertions.
//!
//! The hot per-element loops — accumulate-into-lane FMAs, LUT gathers, and
//! the affine/scale epilogues — route through [`tensor::simd`]
//! (`crate::tensor::simd`), whose vector paths are bit-identical to their
//! scalar fallbacks, so every `GQ_SIMD` setting produces the same results.
//! [`LutLinear::with_f16_tables`] / [`VqLinear::with_f16_tables`] opt a
//! layer into f16 decode-table storage (half the resident table bytes,
//! widen-on-read): the f16 variant's kernels stay bit-identical to *each
//! other*, while its outputs are ULP-close — one RNE rounding of each
//! table entry — to the f32-table variant's.

use std::sync::Arc;

use crate::model::forward::{matmul_col_sharded, LinearOp};
use crate::tensor::gemm::{with_f32_scratch, with_u16_scratch, ColWindow};
use crate::tensor::{simd, Mat};
use crate::util::half::{f16_to_f32, narrow_slice};

use super::grid::UniformGrid;
use super::packing::{BitPlanes, PackedCodes};
use super::trellis::{Generator, Trellis, TrellisCode};

/// Gather one code row through an f16-stored per-channel table, widening on
/// read (`out[jj] = cb16[(lo+jj)*m + code]` as f32). Widening is exact
/// (f16 ⊂ f32), so this is the f16-table analog of [`simd::lut_gather`].
fn gather_widen_f16(cb16: &[u16], m: usize, lo: usize, codes: &[u16], out: &mut [f32]) {
    for (jj, (o, &code)) in out.iter_mut().zip(codes).enumerate() {
        *o = f16_to_f32(cb16[(lo + jj) * m + code as usize]);
    }
}

// ---------------------------------------------------------------------------
// Uniform scalar
// ---------------------------------------------------------------------------

pub struct UniformScalarLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub codes: PackedCodes, // row-major d_in × d_out
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    /// Pre-expanded code→f32 table (`levels[q] == q as f32`, 2^bits
    /// entries): inner decode loops gather through it instead of paying a
    /// per-element int→float convert.
    levels: Vec<f32>,
}

impl UniformScalarLinear {
    pub fn new(codes: &[u16], grid: &UniformGrid, d_in: usize, d_out: usize) -> Self {
        assert_eq!(
            codes.len(),
            d_in * d_out,
            "uniform format: {} codes for a {d_in}x{d_out} weight",
            codes.len()
        );
        assert_eq!(
            grid.scale.len(),
            d_out,
            "uniform format: grid has {} scale channels, weight has {d_out}",
            grid.scale.len()
        );
        assert_eq!(
            grid.zero.len(),
            d_out,
            "uniform format: grid has {} zero channels, weight has {d_out}",
            grid.zero.len()
        );
        let levels: Vec<f32> = (0..1u32 << grid.bits).map(|q| q as f32).collect();
        UniformScalarLinear {
            d_in,
            d_out,
            codes: PackedCodes::pack(codes, grid.bits),
            scale: grid.scale.clone(),
            zero: grid.zero.clone(),
            levels,
        }
    }
}

impl LinearOp for UniformScalarLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d_in);
        debug_assert_eq!(out.len(), self.d_out);
        // out_j = scale_j · Σ_i x_i q_ij + zero_j · Σ_i x_i
        out.fill(0.0);
        let mut xsum = 0.0f32;
        with_f32_scratch(self.d_out, |wrow| {
            for (i, &xi) in x.iter().enumerate() {
                xsum += xi;
                if xi == 0.0 {
                    continue;
                }
                self.codes.unpack_map_f32(i * self.d_out, &self.levels, wrow);
                simd::axpy(out, xi, wrow);
            }
        });
        simd::scale_affine(out, &self.scale, &self.zero, xsum);
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut ColWindow) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(xs.rows, out.rows());
        let (lo, w) = (out.lo(), out.width());
        let b = xs.rows;
        out.fill(0.0);
        with_f32_scratch(w + b, |scratch| {
            let (wrow, xsum) = scratch.split_at_mut(w);
            xsum.fill(0.0);
            for i in 0..self.d_in {
                // Decode this shard's slice of code row i once for the batch.
                let mut any = false;
                for (r, s) in xsum.iter_mut().enumerate() {
                    let xi = xs.at(r, i);
                    *s += xi;
                    any |= xi != 0.0;
                }
                if !any {
                    continue;
                }
                self.codes.unpack_map_f32(i * self.d_out + lo, &self.levels, wrow);
                for r in 0..b {
                    let xi = xs.at(r, i);
                    if xi == 0.0 {
                        continue;
                    }
                    simd::axpy(out.row_mut(r), xi, wrow);
                }
            }
            for r in 0..b {
                simd::scale_affine(
                    out.row_mut(r),
                    &self.scale[lo..lo + w],
                    &self.zero[lo..lo + w],
                    xsum[r],
                );
            }
        });
    }

    fn supports_decode_tile(&self) -> bool {
        true
    }

    fn decode_tile(&self, i0: usize, i1: usize, lo: usize, hi: usize, tile: &mut [f32]) {
        let w = hi - lo;
        for (i, trow) in (i0..i1).zip(tile.chunks_exact_mut(w)) {
            self.codes.unpack_map_f32(i * self.d_out + lo, &self.levels, trow);
        }
    }

    fn tile_epilogue(&self, x: &[f32], out_w: &mut [f32], lo: usize) {
        let xsum: f32 = x.iter().sum();
        let w = out_w.len();
        simd::scale_affine(out_w, &self.scale[lo..lo + w], &self.zero[lo..lo + w], xsum);
    }

    fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + (self.scale.len() + self.zero.len()) * 2 // fp16 grid
    }
}

// ---------------------------------------------------------------------------
// Non-uniform scalar (per-channel LUT)
// ---------------------------------------------------------------------------

pub struct LutLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub codes: PackedCodes, // row-major d_in × d_out
    /// d_out × m, row-contiguous per channel (already f32 — the format's
    /// pre-expanded decode table). Emptied when the f16 copy takes over.
    pub codebooks: Mat,
    /// Opt-in f16 storage of the same table ([`Self::with_f16_tables`]):
    /// gather sites widen on read instead of touching the f32 copy.
    codebooks_f16: Option<Box<[u16]>>,
}

impl LutLinear {
    pub fn new(codes: &[u16], codebooks: Mat, bits: u32, d_in: usize, d_out: usize) -> Self {
        assert_eq!(
            codes.len(),
            d_in * d_out,
            "lut format: {} codes for a {d_in}x{d_out} weight",
            codes.len()
        );
        assert_eq!(
            codebooks.rows, d_out,
            "lut format: {} codebook channels, weight has {d_out}",
            codebooks.rows
        );
        let m = codebooks.cols;
        if let Some(&c) = codes.iter().find(|&&c| c as usize >= m) {
            panic!("lut format: code {c} indexes past the {m}-entry per-channel codebook");
        }
        LutLinear {
            d_in,
            d_out,
            codes: PackedCodes::pack(codes, bits),
            codebooks,
            codebooks_f16: None,
        }
    }

    /// Re-store the decode table in f16, halving its resident bytes; the
    /// f32 copy is dropped and every gather site widens on read. Each table
    /// entry rounds once (RNE), so outputs are ULP-close — not bit-equal —
    /// to the f32-table variant, while all kernels of *this* variant remain
    /// bit-identical to each other. The fused word-walk matvec fast path
    /// (which reads the f32 table directly) stands down.
    pub fn with_f16_tables(mut self) -> Self {
        let mut t = vec![0u16; self.codebooks.data.len()].into_boxed_slice();
        narrow_slice(&self.codebooks.data, &mut t);
        self.codebooks_f16 = Some(t);
        self.codebooks.data = Vec::new(); // rows/cols still describe the table shape
        self
    }

    /// True when the decode table is stored as f16.
    pub fn f16_tables(&self) -> bool {
        self.codebooks_f16.is_some()
    }
}

impl LinearOp for LutLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let m = self.codebooks.cols;
        if self.codebooks_f16.is_none() && self.codes.rows_aligned(self.d_out) {
            // Fused decode+FMA: walk packed words directly, no staging buffer.
            let cb = &self.codebooks.data;
            let bits = self.codes.bits as usize;
            let per_word = 32 / bits;
            let mask = (1u32 << bits) - 1;
            let words = self.codes.words();
            let words_per_row = self.d_out / per_word;
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let row_words = &words[i * words_per_row..(i + 1) * words_per_row];
                let mut j = 0usize;
                for &w in row_words {
                    let mut word = w;
                    for _ in 0..per_word {
                        let q = (word & mask) as usize;
                        word >>= bits;
                        *unsafe { out.get_unchecked_mut(j) } +=
                            xi * unsafe { *cb.get_unchecked(j * m + q) };
                        j += 1;
                    }
                }
            }
            return;
        }
        with_u16_scratch(self.d_out, |row| {
            with_f32_scratch(self.d_out, |wrow| {
                for (i, &xi) in x.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    // Gather weight row i through the LUT (w_ij = cb[j][code])
                    // into staging, then one vectorized FMA over the row.
                    self.codes.unpack_range(i * self.d_out, row);
                    match &self.codebooks_f16 {
                        Some(cb16) => gather_widen_f16(cb16, m, 0, row, wrow),
                        None => simd::lut_gather(&self.codebooks.data, m, 0, row, wrow),
                    }
                    simd::axpy(out, xi, wrow);
                }
            })
        });
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut ColWindow) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(xs.rows, out.rows());
        let (lo, w) = (out.lo(), out.width());
        let b = xs.rows;
        out.fill(0.0);
        let m = self.codebooks.cols;
        let cb = &self.codebooks.data;
        with_u16_scratch(w, |row| {
            with_f32_scratch(w, |wrow| {
                for i in 0..self.d_in {
                    if (0..b).all(|r| xs.at(r, i) == 0.0) {
                        continue;
                    }
                    // Gather this shard's slice of weight row i through the
                    // LUT once, then FMA it into every lane — the decode
                    // cost is amortized across the batch.
                    self.codes.unpack_range(i * self.d_out + lo, row);
                    match &self.codebooks_f16 {
                        Some(cb16) => gather_widen_f16(cb16, m, lo, row, wrow),
                        None => simd::lut_gather(cb, m, lo, row, wrow),
                    }
                    for r in 0..b {
                        let xi = xs.at(r, i);
                        if xi == 0.0 {
                            continue;
                        }
                        simd::axpy(out.row_mut(r), xi, wrow);
                    }
                }
            })
        });
    }

    fn supports_decode_tile(&self) -> bool {
        true
    }

    fn decode_tile(&self, i0: usize, i1: usize, lo: usize, hi: usize, tile: &mut [f32]) {
        let w = hi - lo;
        let m = self.codebooks.cols;
        let cb = &self.codebooks.data;
        with_u16_scratch(w, |row| {
            for (i, trow) in (i0..i1).zip(tile.chunks_exact_mut(w)) {
                self.codes.unpack_range(i * self.d_out + lo, row);
                match &self.codebooks_f16 {
                    Some(cb16) => gather_widen_f16(cb16, m, lo, row, trow),
                    None => simd::lut_gather(cb, m, lo, row, trow),
                }
            }
        });
    }

    fn storage_bytes(&self) -> usize {
        // fp16 LUT either way: the f32 copy models a table that deploys as
        // half-precision, the f16 copy *is* one.
        self.codes.storage_bytes() + self.codebooks.rows * self.codebooks.cols * 2
    }
}

// ---------------------------------------------------------------------------
// Any-precision (bit-plane codes, one artifact for every width)
// ---------------------------------------------------------------------------

/// The shared any-precision weight artifact: bit-plane packed codes plus
/// one per-channel decode table per precision. Built ONCE per layer from
/// the same `(codes, codebooks)` a [`LutLinear`] takes, then shared
/// (`Arc`) by every [`AnyPrecisionLinear`] view — a 2-bit and a 4-bit
/// serving model of the same layer hold the same artifact.
///
/// Construction sorts each channel's codebook ascending and remaps the
/// codes through the sort permutation. Sorting changes nothing at full
/// precision (a gather through a permuted table with permuted indices
/// returns the same f32s, so the full-precision view stays bit-identical
/// to [`LutLinear`]), and it makes code *prefixes* meaningful: after
/// sorting, the codes whose top `p` bits equal `c` form a contiguous run
/// of neighboring codebook entries, so the precision-`p` table entry is
/// the (deterministic, f32) mean of its `2^(bits-p)` children — coarser
/// precisions collapse neighboring reconstruction levels, the
/// Any-Precision-LLM parent/child scheme.
pub struct AnyPrecArtifact {
    pub d_in: usize,
    pub d_out: usize,
    /// Full stored precision (number of planes).
    pub bits: u32,
    /// Bit-plane codes, row-major `d_in × d_out`, remapped to the sorted
    /// tables.
    planes: BitPlanes,
    /// `luts[p - 1]` is the `d_out × 2^p` decode table for precision `p`;
    /// `luts[bits - 1]` is the sorted parent codebook (exact).
    luts: Vec<Mat>,
}

impl AnyPrecArtifact {
    pub fn new(codes: &[u16], codebooks: &Mat, bits: u32, d_in: usize, d_out: usize) -> Self {
        assert!(bits >= 1 && bits <= 8, "anyprec format: bits {bits} outside 1..=8");
        assert_eq!(
            codes.len(),
            d_in * d_out,
            "anyprec format: {} codes for a {d_in}x{d_out} weight",
            codes.len()
        );
        assert_eq!(
            codebooks.rows, d_out,
            "anyprec format: {} codebook channels, weight has {d_out}",
            codebooks.rows
        );
        let m = 1usize << bits;
        assert_eq!(
            codebooks.cols, m,
            "anyprec format: {}-entry codebook for {bits}-bit codes",
            codebooks.cols
        );
        if let Some(&c) = codes.iter().find(|&&c| c as usize >= m) {
            panic!("anyprec format: code {c} indexes past the {m}-entry per-channel codebook");
        }
        // Per channel: sort the codebook ascending (total order — ties and
        // any degenerate values stay deterministic) and build the inverse
        // permutation that remaps old codes to sorted positions.
        let mut sorted = Mat::zeros(d_out, m);
        let mut inv = vec![0u16; d_out * m];
        let mut order: Vec<usize> = Vec::with_capacity(m);
        for j in 0..d_out {
            order.clear();
            order.extend(0..m);
            let row = codebooks.row(j);
            order.sort_by(|&a, &b| row[a].total_cmp(&row[b]));
            let srow = sorted.row_mut(j);
            for (k, &o) in order.iter().enumerate() {
                srow[k] = row[o];
                inv[j * m + o] = k as u16;
            }
        }
        let remapped: Vec<u16> = codes
            .iter()
            .enumerate()
            .map(|(idx, &c)| inv[(idx % d_out) * m + c as usize])
            .collect();
        // Per-precision tables: precision `bits` is the sorted codebook
        // itself; precision `p` averages each entry's 2^(bits-p) children.
        let mut luts = Vec::with_capacity(bits as usize);
        for p in 1..=bits {
            if p == bits {
                luts.push(sorted.clone());
                continue;
            }
            let group = 1usize << (bits - p);
            let mp = 1usize << p;
            let mut t = Mat::zeros(d_out, mp);
            for j in 0..d_out {
                let srow = sorted.row(j);
                let trow = t.row_mut(j);
                for c in 0..mp {
                    let kids = &srow[c * group..(c + 1) * group];
                    trow[c] = kids.iter().sum::<f32>() / group as f32;
                }
            }
            luts.push(t);
        }
        AnyPrecArtifact { d_in, d_out, bits, planes: BitPlanes::pack(&remapped, bits), luts }
    }

    /// The `d_out × 2^prec` decode table for one precision.
    pub fn lut(&self, prec: u32) -> &Mat {
        assert!(prec >= 1 && prec <= self.bits, "anyprec: precision {prec} outside stored planes");
        &self.luts[prec as usize - 1]
    }

    /// Bit-plane codes (all planes).
    pub fn planes(&self) -> &BitPlanes {
        &self.planes
    }

    /// Bytes of the full shared artifact: every code plane plus every
    /// precision's table at fp16 deployment width (matching the other
    /// formats' table accounting).
    pub fn storage_bytes(&self) -> usize {
        let table_entries: usize = self.luts.iter().map(|t| t.rows * t.cols).sum();
        self.planes.storage_bytes() + table_entries * 2
    }
}

/// A serving view of an [`AnyPrecArtifact`] at one requested precision.
/// Cheap to construct (an `Arc` clone + an integer), so a model set keeps
/// one view per supported precision over the same weights. Kernels mirror
/// [`LutLinear`]'s staged path — unpack a code run at the view's
/// precision, gather through that precision's table, FMA — and satisfy
/// the same tile contract (`matvec` ≡ `matmul` ≡ tiled GEMM per element
/// at every SIMD/shard/tile setting). At `precision == bits` the decode
/// table holds exactly the (sorted) [`LutLinear`] codebook values, so
/// outputs are bit-identical to the fixed-precision format.
pub struct AnyPrecisionLinear {
    art: Arc<AnyPrecArtifact>,
    precision: u32,
}

impl AnyPrecisionLinear {
    /// Build the artifact and return its full-precision view.
    pub fn new(codes: &[u16], codebooks: Mat, bits: u32, d_in: usize, d_out: usize) -> Self {
        let art = Arc::new(AnyPrecArtifact::new(codes, &codebooks, bits, d_in, d_out));
        AnyPrecisionLinear { precision: bits, art }
    }

    /// A view of an existing artifact at `precision` planes.
    pub fn from_artifact(art: Arc<AnyPrecArtifact>, precision: u32) -> Self {
        assert!(
            precision >= 1 && precision <= art.bits,
            "anyprec: precision {precision} outside the artifact's 1..={} planes",
            art.bits
        );
        AnyPrecisionLinear { art, precision }
    }

    /// The shared artifact (clone the `Arc` to build sibling views).
    pub fn artifact(&self) -> &Arc<AnyPrecArtifact> {
        &self.art
    }

    /// Decode precision of this view.
    pub fn precision(&self) -> u32 {
        self.precision
    }
}

impl LinearOp for AnyPrecisionLinear {
    fn d_in(&self) -> usize {
        self.art.d_in
    }

    fn d_out(&self) -> usize {
        self.art.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let d_out = self.art.d_out;
        let lut = self.art.lut(self.precision);
        let m = lut.cols;
        with_u16_scratch(d_out, |row| {
            with_f32_scratch(d_out, |wrow| {
                for (i, &xi) in x.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    self.art.planes.unpack_range(i * d_out, self.precision, row);
                    simd::lut_gather(&lut.data, m, 0, row, wrow);
                    simd::axpy(out, xi, wrow);
                }
            })
        });
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut ColWindow) {
        debug_assert_eq!(xs.cols, self.art.d_in);
        debug_assert_eq!(xs.rows, out.rows());
        let (lo, w) = (out.lo(), out.width());
        let b = xs.rows;
        out.fill(0.0);
        let d_out = self.art.d_out;
        let lut = self.art.lut(self.precision);
        let m = lut.cols;
        with_u16_scratch(w, |row| {
            with_f32_scratch(w, |wrow| {
                for i in 0..self.art.d_in {
                    if (0..b).all(|r| xs.at(r, i) == 0.0) {
                        continue;
                    }
                    // One plane-prefix unpack + gather per code row, shared
                    // by every lane of the batch.
                    self.art.planes.unpack_range(i * d_out + lo, self.precision, row);
                    simd::lut_gather(&lut.data, m, lo, row, wrow);
                    for r in 0..b {
                        let xi = xs.at(r, i);
                        if xi == 0.0 {
                            continue;
                        }
                        simd::axpy(out.row_mut(r), xi, wrow);
                    }
                }
            })
        });
    }

    fn supports_decode_tile(&self) -> bool {
        true
    }

    fn decode_tile(&self, i0: usize, i1: usize, lo: usize, hi: usize, tile: &mut [f32]) {
        let w = hi - lo;
        let d_out = self.art.d_out;
        let lut = self.art.lut(self.precision);
        let m = lut.cols;
        with_u16_scratch(w, |row| {
            for (i, trow) in (i0..i1).zip(tile.chunks_exact_mut(w)) {
                self.art.planes.unpack_range(i * d_out + lo, self.precision, row);
                simd::lut_gather(&lut.data, m, lo, row, trow);
            }
        });
    }

    /// Full shared-artifact bytes (every plane + every precision's fp16
    /// table). Views over one artifact each report the whole thing — the
    /// artifact IS the deployable unit; a per-view prefix figure is
    /// available as `artifact().planes().prefix_storage_bytes(prec)`.
    fn storage_bytes(&self) -> usize {
        self.art.storage_bytes()
    }
}

// ---------------------------------------------------------------------------
// Vector quantization
// ---------------------------------------------------------------------------

pub struct VqLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub dim: usize,
    /// codes: (d_in/dim) × d_out row-major per point.
    pub codes: PackedCodes,
    pub code_bits: u32,
    /// d_out × (k·dim) centroid table. Emptied when the f16 copy takes over.
    pub codebooks: Mat,
    /// Opt-in f16 storage of the centroid table
    /// ([`Self::with_f16_tables`]): decode sites widen on read.
    codebooks_f16: Option<Box<[u16]>>,
}

impl VqLinear {
    pub fn new(
        codes: &[u16],
        codebooks: Mat,
        dim: usize,
        code_bits: u32,
        d_in: usize,
        d_out: usize,
    ) -> Self {
        assert!(dim >= 1 && d_in % dim == 0, "vq format: dim {dim} must divide d_in {d_in}");
        assert_eq!(
            codes.len(),
            (d_in / dim) * d_out,
            "vq format: {} codes for {} points x {d_out} channels",
            codes.len(),
            d_in / dim
        );
        assert_eq!(
            codebooks.rows, d_out,
            "vq format: {} codebook channels, weight has {d_out}",
            codebooks.rows
        );
        let k = codebooks.cols / dim;
        if let Some(&c) = codes.iter().find(|&&c| c as usize >= k) {
            panic!("vq format: code {c} indexes past the {k}-centroid per-channel codebook");
        }
        VqLinear {
            d_in,
            d_out,
            dim,
            codes: PackedCodes::pack(codes, code_bits),
            code_bits,
            codebooks,
            codebooks_f16: None,
        }
    }

    /// Re-store the centroid table in f16, halving its resident bytes; the
    /// f32 copy is dropped and every decode site widens on read. Same
    /// contract as [`LutLinear::with_f16_tables`]: one RNE rounding per
    /// table entry, all kernels of the f16 variant bit-identical to each
    /// other.
    pub fn with_f16_tables(mut self) -> Self {
        let mut t = vec![0u16; self.codebooks.data.len()].into_boxed_slice();
        narrow_slice(&self.codebooks.data, &mut t);
        self.codebooks_f16 = Some(t);
        self.codebooks.data = Vec::new(); // rows/cols still describe the table shape
        self
    }

    /// True when the centroid table is stored as f16.
    pub fn f16_tables(&self) -> bool {
        self.codebooks_f16.is_some()
    }
}

impl LinearOp for VqLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let dim = self.dim;
        let n_pts = self.d_in / dim;
        let cbw = self.codebooks.cols;
        with_u16_scratch(self.d_out, |row| {
            for p in 0..n_pts {
                let xsp = &x[p * dim..(p + 1) * dim];
                self.codes.unpack_range(p * self.d_out, row);
                for (j, &code) in row.iter().enumerate() {
                    let c = code as usize * dim;
                    // Flat ascending-i accumulation (the tile contract):
                    // each centroid lane folds straight into out_j.
                    let o = &mut out[j];
                    match &self.codebooks_f16 {
                        Some(cb16) => {
                            let cent = &cb16[j * cbw + c..j * cbw + c + dim];
                            for (xv, &cv) in xsp.iter().zip(cent) {
                                *o += xv * f16_to_f32(cv);
                            }
                        }
                        None => {
                            let cent = &self.codebooks.data[j * cbw + c..j * cbw + c + dim];
                            for (xv, cv) in xsp.iter().zip(cent) {
                                *o += xv * cv;
                            }
                        }
                    }
                }
            }
        });
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut ColWindow) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(xs.rows, out.rows());
        let (lo, w) = (out.lo(), out.width());
        let b = xs.rows;
        out.fill(0.0);
        let dim = self.dim;
        let n_pts = self.d_in / dim;
        let cbw = self.codebooks.cols;
        with_u16_scratch(w, |row| {
            for p in 0..n_pts {
                // One code unpack + one centroid gather per (point, channel)
                // of this shard's column window, shared by all lanes.
                self.codes.unpack_range(p * self.d_out + lo, row);
                for r in 0..b {
                    let xsp = &xs.row(r)[p * dim..(p + 1) * dim];
                    let orow = out.row_mut(r);
                    for (jj, &code) in row.iter().enumerate() {
                        let base = (lo + jj) * cbw + code as usize * dim;
                        let o = &mut orow[jj];
                        match &self.codebooks_f16 {
                            Some(cb16) => {
                                let cent = &cb16[base..base + dim];
                                for (xv, &cv) in xsp.iter().zip(cent) {
                                    *o += xv * f16_to_f32(cv);
                                }
                            }
                            None => {
                                let cent = &self.codebooks.data[base..base + dim];
                                for (xv, cv) in xsp.iter().zip(cent) {
                                    *o += xv * cv;
                                }
                            }
                        }
                    }
                }
            }
        });
    }

    fn supports_decode_tile(&self) -> bool {
        true
    }

    fn decode_tile(&self, i0: usize, i1: usize, lo: usize, hi: usize, tile: &mut [f32]) {
        let w = hi - lo;
        let dim = self.dim;
        let cbw = self.codebooks.cols;
        with_u16_scratch(w, |row| {
            let p0 = i0 / dim;
            let p1 = (i1 - 1) / dim;
            for p in p0..=p1 {
                self.codes.unpack_range(p * self.d_out + lo, row);
                // Rows of this point that overlap the tile (tile heights
                // need not align to the vector dim).
                let r0 = (p * dim).max(i0);
                let r1 = ((p + 1) * dim).min(i1);
                for (jj, &code) in row.iter().enumerate() {
                    let base = (lo + jj) * cbw + code as usize * dim;
                    for i in r0..r1 {
                        tile[(i - i0) * w + jj] = match &self.codebooks_f16 {
                            Some(cb16) => f16_to_f32(cb16[base + (i - p * dim)]),
                            None => self.codebooks.data[base + (i - p * dim)],
                        };
                    }
                }
            }
        });
    }

    fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.codebooks.rows * self.codebooks.cols * 2
    }
}

// ---------------------------------------------------------------------------
// Trellis (QTIP-style stateful decode)
// ---------------------------------------------------------------------------

/// Rows between stored trellis walk states. Checkpoints let
/// `decode_tile` start a column's stateful walk at any tile boundary
/// without replaying from row 0 (at most `TRELLIS_CKPT - 1` replay steps
/// for tile heights that do not align).
const TRELLIS_CKPT: usize = 64;

pub struct TrellisLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub cfg: Trellis,
    pub gen: Generator,
    /// Per-column packed symbols, column-major: column j occupies
    /// [j*d_in, (j+1)*d_in).
    pub symbols: PackedCodes,
    pub initial_states: Vec<u32>,
    pub scales: Vec<f32>,
    /// Pre-expanded state→value table (2^state_bits entries): inner loops
    /// gather through it instead of recomputing the generator hash per
    /// weight.
    state_values: Vec<f32>,
    /// Walk states at row checkpoints, per column: entry `j * n_ckpts + t`
    /// is column j's state BEFORE absorbing the symbol of row
    /// `t * TRELLIS_CKPT`.
    state_ckpts: Vec<u32>,
    n_ckpts: usize,
}

impl TrellisLinear {
    pub fn new(codes: &[TrellisCode], gen: Generator, cfg: Trellis, d_in: usize) -> Self {
        assert!(d_in >= 1, "trellis format: empty input dimension");
        assert!(
            cfg.state_bits >= cfg.bits && cfg.state_bits <= 16,
            "trellis format: state_bits {} outside bits..=16",
            cfg.state_bits
        );
        let d_out = codes.len();
        let n_states = cfg.n_states();
        let state_values: Vec<f32> = (0..n_states as u32).map(|s| gen.value(s)).collect();
        let mask = (1u32 << cfg.state_bits) - 1;
        let bits = cfg.bits;
        let n_ckpts = d_in.div_ceil(TRELLIS_CKPT);
        let mut flat = Vec::with_capacity(d_in * d_out);
        let mut state_ckpts = Vec::with_capacity(d_out * n_ckpts);
        for code in codes {
            assert_eq!(
                code.symbols.len(),
                d_in,
                "trellis format: column has {} symbols, weight has {d_in} rows",
                code.symbols.len()
            );
            let mut state = code.initial_state;
            for (i, &sym) in code.symbols.iter().enumerate() {
                if i % TRELLIS_CKPT == 0 {
                    state_ckpts.push(state);
                }
                state = ((state << bits) | sym as u32) & mask;
            }
            flat.extend_from_slice(&code.symbols);
        }
        TrellisLinear {
            d_in,
            d_out,
            symbols: PackedCodes::pack(&flat, cfg.bits),
            initial_states: codes.iter().map(|c| c.initial_state).collect(),
            scales: codes.iter().map(|c| c.scale).collect(),
            state_values,
            state_ckpts,
            n_ckpts,
            gen,
            cfg,
        }
    }
}

impl LinearOp for TrellisLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        let mask = (1u32 << self.cfg.state_bits) - 1;
        let bits = self.cfg.bits;
        with_u16_scratch(self.d_in, |syms| {
            for j in 0..self.d_out {
                let mut state = self.initial_states[j];
                self.symbols.unpack_range(j * self.d_in, syms);
                let mut acc = 0.0f32;
                for (i, &sym) in syms.iter().enumerate() {
                    state = ((state << bits) | sym as u32) & mask;
                    acc += x[i] * self.state_values[state as usize];
                }
                out[j] = acc * self.scales[j];
            }
        });
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut ColWindow) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(xs.rows, out.rows());
        let (lo, hi) = (out.lo(), out.hi());
        let b = xs.rows;
        let mask = (1u32 << self.cfg.state_bits) - 1;
        let bits = self.cfg.bits;
        with_u16_scratch(self.d_in, |syms| {
            with_f32_scratch(b, |acc| {
                for j in lo..hi {
                    // The stateful trellis walk — the expensive part of
                    // QTIP-style decode — runs once per column and feeds
                    // every lane. Columns are decode-independent, so the
                    // window shards cleanly.
                    let mut state = self.initial_states[j];
                    self.symbols.unpack_range(j * self.d_in, syms);
                    acc.fill(0.0);
                    for (i, &sym) in syms.iter().enumerate() {
                        state = ((state << bits) | sym as u32) & mask;
                        let wv = self.state_values[state as usize];
                        for (r, a) in acc.iter_mut().enumerate() {
                            *a += xs.at(r, i) * wv;
                        }
                    }
                    for (r, &a) in acc.iter().enumerate() {
                        out.row_mut(r)[j - lo] = a * self.scales[j];
                    }
                }
            })
        });
    }

    fn supports_decode_tile(&self) -> bool {
        true
    }

    fn decode_tile(&self, i0: usize, i1: usize, lo: usize, hi: usize, tile: &mut [f32]) {
        let w = hi - lo;
        let mask = (1u32 << self.cfg.state_bits) - 1;
        let bits = self.cfg.bits;
        let t = i0 / TRELLIS_CKPT;
        let start = t * TRELLIS_CKPT;
        with_u16_scratch(i1 - start, |syms| {
            for j in lo..hi {
                // Resume the walk from the nearest checkpoint at or before
                // the tile, replay up to the tile's first row, then decode
                // the tile's rows through the pre-expanded value table.
                let mut state = self.state_ckpts[j * self.n_ckpts + t];
                self.symbols.unpack_range(j * self.d_in + start, syms);
                for &sym in &syms[..i0 - start] {
                    state = ((state << bits) | sym as u32) & mask;
                }
                let jj = j - lo;
                for (i, &sym) in syms[i0 - start..].iter().enumerate() {
                    state = ((state << bits) | sym as u32) & mask;
                    tile[i * w + jj] = self.state_values[state as usize];
                }
            }
        });
    }

    fn tile_epilogue(&self, _x: &[f32], out_w: &mut [f32], lo: usize) {
        let w = out_w.len();
        simd::scale_inplace(out_w, &self.scales[lo..lo + w]);
    }

    fn storage_bytes(&self) -> usize {
        self.symbols.storage_bytes() + self.d_out * (2 + 4) // fp16 scale + init state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::{round_all, rtn_quantize, UniformGrid};
    use crate::quant::trellis::trellis_quantize;
    use crate::tensor::gemm::matmul_tiled_with;
    use crate::tensor::ops::{matmul_tn, matvec};
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn uniform_format_matches_dense_dequant() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 3);
        let (w_hat, codes) = round_all(&w, &grid);
        let lin = UniformScalarLinear::new(&codes, &grid, 24, 10);
        let x: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
        let want = matvec(&w_hat.transpose(), &x);
        let mut got = vec![0.0; 10];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
        assert!(lin.storage_bytes() < 24 * 10 * 4 / 2);
    }

    #[test]
    fn lut_format_matches_dense_dequant() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(16, 8, 1.0, &mut rng);
        let res = rtn_quantize(&w, 4);
        let lin =
            LutLinear::new(&res.codes.clone().unwrap(), res.codebooks.clone().unwrap(), 4, 16, 8);
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let want = matvec(&res.w_hat.transpose(), &x);
        let mut got = vec![0.0; 8];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
    }

    /// A VQ-coded weight matrix built directly (throughput-shaped tests
    /// need no quantizer run).
    fn vq_fixture(seed: u64) -> (VqLinear, Mat) {
        let mut rng = Rng::new(seed);
        let (d_in, d_out, dim, k) = (12usize, 6usize, 2usize, 4usize);
        let codebooks = Mat::randn(d_out, k * dim, 1.0, &mut rng);
        let n_pts = d_in / dim;
        let codes: Vec<u16> = (0..n_pts * d_out).map(|_| rng.below(k) as u16).collect();
        let mut w_hat = Mat::zeros(d_in, d_out);
        for p in 0..n_pts {
            for j in 0..d_out {
                let c = codes[p * d_out + j] as usize * dim;
                for t in 0..dim {
                    *w_hat.at_mut(p * dim + t, j) = codebooks.at(j, c + t);
                }
            }
        }
        (VqLinear::new(&codes, codebooks, dim, 2, d_in, d_out), w_hat)
    }

    #[test]
    fn vq_format_matches_dense_dequant() {
        let (lin, w_hat) = vq_fixture(2);
        let mut rng = Rng::new(20);
        let x: Vec<f32> = (0..lin.d_in).map(|_| rng.normal_f32()).collect();
        let want = matvec(&w_hat.transpose(), &x);
        let mut got = vec![0.0; lin.d_out];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
    }

    fn trellis_fixture(seed: u64) -> (TrellisLinear, Mat) {
        let mut rng = Rng::new(seed);
        let x_cal = Mat::randn(64, 32, 1.0, &mut rng);
        let h = matmul_tn(&x_cal, &x_cal);
        let w = Mat::randn(32, 4, 1.0, &mut rng);
        let cfg = Trellis::new(2, crate::cfg::TrellisVariant::Hyb);
        let (qr, codes, gen) = trellis_quantize(&h, &w, &cfg).unwrap();
        (TrellisLinear::new(&codes, gen, cfg, 32), qr.w_hat)
    }

    #[test]
    fn trellis_format_matches_dense_dequant() {
        let (lin, w_hat) = trellis_fixture(3);
        let mut rng = Rng::new(30);
        let x: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let want = matvec(&w_hat.transpose(), &x);
        let mut got = vec![0.0; 4];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-3, 1e-3).unwrap();
    }

    /// Batched `matmul` must equal looping `matvec` over the rows EXACTLY
    /// (per-element f32 `==`) — at every column-shard count (including ones
    /// that do not divide d_out) and at every tiled-GEMM tile height
    /// (including ones that do not divide d_in): the continuous-batching
    /// engine relies on this to keep greedy decode identical to the
    /// per-sequence path no matter how the worker pool splits the output
    /// channels or how the engine tiles the input rows.
    fn assert_matmul_is_looped_matvec(lin: &dyn LinearOp, b: usize, seed: u64) {
        use crate::model::forward::matmul_col_sharded_with;
        let mut rng = Rng::new(seed);
        let mut xs = Mat::randn(b, lin.d_in(), 1.0, &mut rng);
        for r in 0..b {
            xs.row_mut(r)[r % lin.d_in()] = 0.0; // exercise zero-skip paths
        }
        // One all-zero lane exercises the all-lanes-zero row skip.
        if b > 1 {
            xs.row_mut(b - 1).fill(0.0);
        }
        let mut want = Mat::zeros(b, lin.d_out());
        for r in 0..b {
            lin.matvec(xs.row(r), want.row_mut(r));
        }
        let mut got = Mat::zeros(b, lin.d_out());
        lin.matmul(&xs, &mut got);
        assert_eq!(got.data, want.data, "batched matmul != looped matvec");
        // Row-at-a-time window kernel (the GQ_TILE=0 fallback).
        let mut row_kernel = Mat::zeros(b, lin.d_out());
        lin.matmul_cols(&xs, &mut ColWindow::full(&mut row_kernel));
        assert_eq!(row_kernel.data, want.data, "row-at-a-time kernel != looped matvec");
        // Tiled engine at several heights: 1 (degenerate), a prime that
        // divides nothing here, the exact d_in, and one past it.
        assert!(lin.supports_decode_tile(), "serving formats must support tile decode");
        for tile in [1usize, 3, 5, lin.d_in(), lin.d_in() + 3] {
            let mut tiled = Mat::zeros(b, lin.d_out());
            matmul_tiled_with(lin, &xs, &mut ColWindow::full(&mut tiled), tile);
            assert_eq!(tiled.data, want.data, "tiled GEMM (tile={tile}) != looped matvec");
        }
        // Tiled engine on a column window (a shard's view).
        if lin.d_out() >= 3 {
            let (lo, hi) = (1usize, lin.d_out() - 1);
            let mut windowed = Mat::zeros(b, lin.d_out());
            matmul_tiled_with(lin, &xs, &mut ColWindow::window(&mut windowed, lo, hi), 5);
            for r in 0..b {
                assert_eq!(
                    windowed.row(r)[lo..hi],
                    want.row(r)[lo..hi],
                    "tiled window row {r} != matvec columns"
                );
            }
        }
        // 3 never divides the test d_outs evenly; d_out + 1 over-shards.
        for shards in [1usize, 2, 3, lin.d_out(), lin.d_out() + 1] {
            let mut sharded = Mat::zeros(b, lin.d_out());
            matmul_col_sharded_with(lin, &xs, &mut sharded, shards);
            assert_eq!(
                sharded.data, want.data,
                "column-sharded matmul (shards={shards}) != looped matvec"
            );
        }
    }

    #[test]
    fn uniform_matmul_exactly_matches_matvec() {
        let mut rng = Rng::new(10);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 3);
        let (_, codes) = round_all(&w, &grid);
        let lin = UniformScalarLinear::new(&codes, &grid, 24, 10);
        assert_matmul_is_looped_matvec(&lin, 5, 100);
    }

    #[test]
    fn lut_matmul_exactly_matches_matvec_aligned_and_not() {
        let mut rng = Rng::new(11);
        // d_out = 8 at 4 bits: word-aligned rows (fused matvec path).
        let w = Mat::randn(16, 8, 1.0, &mut rng);
        let res = rtn_quantize(&w, 4);
        let lin = LutLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 4, 16, 8);
        assert_matmul_is_looped_matvec(&lin, 6, 101);
        // d_out = 10 at 3 bits: unaligned rows (staged matvec path).
        let w = Mat::randn(12, 10, 1.0, &mut rng);
        let res = rtn_quantize(&w, 3);
        let lin = LutLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 3, 12, 10);
        assert_matmul_is_looped_matvec(&lin, 3, 102);
    }

    #[test]
    fn vq_matmul_exactly_matches_matvec() {
        let (lin, _) = vq_fixture(12);
        // dim = 2 with tile heights 1/3/5: tiles split vector points.
        assert_matmul_is_looped_matvec(&lin, 7, 103);
    }

    #[test]
    fn trellis_matmul_exactly_matches_matvec() {
        let (lin, _) = trellis_fixture(13);
        assert_matmul_is_looped_matvec(&lin, 4, 104);
    }

    #[test]
    fn fp32_matmul_exactly_matches_matvec() {
        let mut rng = Rng::new(14);
        let w = Mat::randn(20, 9, 1.0, &mut rng);
        assert_matmul_is_looped_matvec(&w, 5, 105);
    }

    #[test]
    fn trellis_checkpointed_tiles_cross_checkpoint_boundaries() {
        // d_in = 150 spans three TRELLIS_CKPT(=64) checkpoint windows;
        // tile heights around and past the checkpoint stride must all
        // resume the walk exactly.
        let mut rng = Rng::new(40);
        let d_in = 150usize;
        let d_out = 5usize;
        let variant = crate::cfg::TrellisVariant::ThreeInst;
        let cfg = Trellis::new(2, variant);
        let gen = Generator::new(variant, cfg.state_bits, &[], &mut rng);
        let codes: Vec<TrellisCode> = (0..d_out)
            .map(|_| TrellisCode {
                initial_state: rng.below(cfg.n_states()) as u32,
                symbols: (0..d_in).map(|_| rng.below(1usize << cfg.bits) as u16).collect(),
                scale: 0.5 + rng.f32(),
            })
            .collect();
        let lin = TrellisLinear::new(&codes, gen, cfg, d_in);
        let xs = Mat::randn(3, d_in, 1.0, &mut rng);
        let mut want = Mat::zeros(3, d_out);
        for r in 0..3 {
            lin.matvec(xs.row(r), want.row_mut(r));
        }
        for tile in [1usize, 63, 64, 65, 100, 128, d_in] {
            let mut got = Mat::zeros(3, d_out);
            matmul_tiled_with(&lin, &xs, &mut ColWindow::full(&mut got), tile);
            assert_eq!(got.data, want.data, "tile={tile}");
        }
    }

    #[test]
    fn warm_format_kernels_are_allocation_free() {
        // Satellite: the per-call decode buffers are gone — matvec, the
        // row-at-a-time window kernel, and the tiled engine all run on
        // thread-local scratch once warm.
        use crate::testing::alloc_count::count_allocs;
        let mut rng = Rng::new(41);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 3);
        let (_, codes) = round_all(&w, &grid);
        let uni = UniformScalarLinear::new(&codes, &grid, 24, 10);
        let res = rtn_quantize(&w, 3);
        let lut = LutLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 3, 24, 10);
        let (vq, _) = vq_fixture(42);
        let (tre, _) = trellis_fixture(43);
        let res4 = rtn_quantize(&w, 4);
        let anyp =
            AnyPrecisionLinear::new(&res4.codes.unwrap(), res4.codebooks.unwrap(), 4, 24, 10);
        let anyp2 = AnyPrecisionLinear::from_artifact(anyp.artifact().clone(), 2);
        for lin in [&uni as &dyn LinearOp, &lut, &vq, &tre, &anyp, &anyp2] {
            let xs = Mat::randn(3, lin.d_in(), 1.0, &mut rng);
            let mut out = Mat::zeros(3, lin.d_out());
            let mut y = vec![0.0f32; lin.d_out()];
            // Warm every path's scratch.
            lin.matvec(xs.row(0), &mut y);
            lin.matmul_cols(&xs, &mut ColWindow::full(&mut out));
            matmul_tiled_with(lin, &xs, &mut ColWindow::full(&mut out), 7);
            let ((), n) = count_allocs(|| {
                lin.matvec(xs.row(0), &mut y);
                lin.matmul_cols(&xs, &mut ColWindow::full(&mut out));
                matmul_tiled_with(lin, &xs, &mut ColWindow::full(&mut out), 7);
            });
            assert_eq!(n, 0, "warm kernels allocated {n} time(s)");
        }
    }

    #[test]
    #[should_panic(expected = "indexes past")]
    fn lut_rejects_out_of_table_codes() {
        let mut rng = Rng::new(44);
        let codebooks = Mat::randn(4, 8, 1.0, &mut rng);
        let codes = vec![9u16; 8]; // 9 >= 8-entry codebook
        LutLinear::new(&codes, codebooks, 4, 2, 4);
    }

    #[test]
    #[should_panic(expected = "must divide d_in")]
    fn vq_rejects_misaligned_dim() {
        let mut rng = Rng::new(45);
        let codebooks = Mat::randn(4, 8, 1.0, &mut rng);
        VqLinear::new(&[0u16; 8], codebooks, 3, 2, 10, 4);
    }

    #[test]
    fn format_kernels_are_bit_identical_across_simd_levels() {
        // The bit-identity half of the SIMD contract, per serving format:
        // forcing the scalar fallback and forcing the vector paths must
        // produce exactly equal bytes from matvec, the sharded matmul, and
        // the tiled engine.
        use crate::tensor::simd;
        let mut rng = Rng::new(50);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 3);
        let (_, codes) = round_all(&w, &grid);
        let uni = UniformScalarLinear::new(&codes, &grid, 24, 10);
        let res = rtn_quantize(&w, 3);
        let lut = LutLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 3, 24, 10);
        let (vq, _) = vq_fixture(51);
        let (tre, _) = trellis_fixture(52);
        let res4 = rtn_quantize(&w, 4);
        let anyp =
            AnyPrecisionLinear::new(&res4.codes.unwrap(), res4.codebooks.unwrap(), 4, 24, 10);
        let anyp3 = AnyPrecisionLinear::from_artifact(anyp.artifact().clone(), 3);
        for lin in [&uni as &dyn LinearOp, &lut, &vq, &tre, &anyp, &anyp3] {
            let xs = Mat::randn(5, lin.d_in(), 1.0, &mut rng);
            let mut run = |simd_on: bool| {
                simd::force(Some(simd_on));
                let mut mv = vec![0.0f32; lin.d_out()];
                lin.matvec(xs.row(0), &mut mv);
                let mut mm = Mat::zeros(5, lin.d_out());
                lin.matmul(&xs, &mut mm);
                let mut tiled = Mat::zeros(5, lin.d_out());
                matmul_tiled_with(lin, &xs, &mut ColWindow::full(&mut tiled), 7);
                simd::force(None);
                (mv, mm.data, tiled.data)
            };
            assert_eq!(run(false), run(true), "scalar vs SIMD kernels differ");
        }
    }

    #[test]
    fn lut_f16_tables_track_f32_within_ulp_budget() {
        let mut rng = Rng::new(60);
        let w = Mat::randn(12, 10, 1.0, &mut rng);
        let res = rtn_quantize(&w, 3);
        let codes = res.codes.unwrap();
        let cbs = res.codebooks.unwrap();
        let f32_lin = LutLinear::new(&codes, cbs.clone(), 3, 12, 10);
        let f16_lin = LutLinear::new(&codes, cbs, 3, 12, 10).with_f16_tables();
        assert!(f16_lin.f16_tables() && !f32_lin.f16_tables());
        assert_eq!(f16_lin.storage_bytes(), f32_lin.storage_bytes());
        let x: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
        let mut want = vec![0.0f32; 10];
        f32_lin.matvec(&x, &mut want);
        let mut got = vec![0.0f32; 10];
        f16_lin.matvec(&x, &mut got);
        // One RNE rounding per table entry ≈ 2^-11 relative = 2^13 f32
        // ulps; the atol floor covers outputs that land near zero.
        testing::assert_close_ulp(&got, &want, 1 << 14, 1e-3).unwrap();
        assert_ne!(got, want, "f16 narrowing should round at least one table entry");
        // The f16 variant's kernels still agree with each other exactly.
        assert_matmul_is_looped_matvec(&f16_lin, 4, 106);
        // Word-aligned rows: the fused f32 fast path must stand down.
        let w = Mat::randn(16, 8, 1.0, &mut rng);
        let res = rtn_quantize(&w, 4);
        let aligned16 =
            LutLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 4, 16, 8).with_f16_tables();
        assert_matmul_is_looped_matvec(&aligned16, 3, 107);
    }

    #[test]
    fn vq_f16_tables_track_f32_within_ulp_budget() {
        let (f32_lin, _) = vq_fixture(61);
        let (rebuilt, _) = vq_fixture(61); // same seed → identical weights
        let f16_lin = rebuilt.with_f16_tables();
        assert!(f16_lin.f16_tables());
        assert_eq!(f16_lin.storage_bytes(), f32_lin.storage_bytes());
        let mut rng = Rng::new(62);
        let x: Vec<f32> = (0..f32_lin.d_in).map(|_| rng.normal_f32()).collect();
        let mut want = vec![0.0f32; f32_lin.d_out];
        f32_lin.matvec(&x, &mut want);
        let mut got = vec![0.0f32; f16_lin.d_out];
        f16_lin.matvec(&x, &mut got);
        testing::assert_close_ulp(&got, &want, 1 << 14, 1e-3).unwrap();
        assert_ne!(got, want, "f16 narrowing should round at least one centroid");
        assert_matmul_is_looped_matvec(&f16_lin, 5, 108);
    }

    #[test]
    fn anyprec_full_precision_is_bit_identical_to_lut() {
        // Tentpole acceptance: the full-precision view of the shared
        // artifact must reproduce LutLinear EXACTLY on the same codes —
        // sorting the tables and remapping the codes is a pure
        // permutation of the gather, and FMA order is unchanged. Checked
        // on a word-aligned shape (LutLinear's fused matvec path) and an
        // unaligned one (its staged path).
        let mut rng = Rng::new(70);
        for (d_in, d_out, bits) in [(16usize, 8usize, 4u32), (12, 10, 3)] {
            let w = Mat::randn(d_in, d_out, 1.0, &mut rng);
            let res = rtn_quantize(&w, bits);
            let codes = res.codes.unwrap();
            let cbs = res.codebooks.unwrap();
            let lut = LutLinear::new(&codes, cbs.clone(), bits, d_in, d_out);
            let anyp = AnyPrecisionLinear::new(&codes, cbs, bits, d_in, d_out);
            assert_eq!(anyp.precision(), bits);
            let xs = Mat::randn(4, d_in, 1.0, &mut rng);
            let mut want = vec![0.0f32; d_out];
            let mut got = vec![0.0f32; d_out];
            lut.matvec(xs.row(0), &mut want);
            anyp.matvec(xs.row(0), &mut got);
            assert_eq!(got, want, "full-precision matvec != LutLinear");
            let mut want_mm = Mat::zeros(4, d_out);
            let mut got_mm = Mat::zeros(4, d_out);
            lut.matmul(&xs, &mut want_mm);
            anyp.matmul(&xs, &mut got_mm);
            assert_eq!(got_mm.data, want_mm.data, "full-precision matmul != LutLinear");
        }
    }

    #[test]
    fn anyprec_matmul_exactly_matches_matvec_at_every_precision() {
        // Every view of one artifact satisfies the full serving-kernel
        // contract (matvec ≡ matmul ≡ tiled GEMM ≡ sharded, exactly).
        let mut rng = Rng::new(71);
        let w = Mat::randn(12, 10, 1.0, &mut rng);
        let res = rtn_quantize(&w, 4);
        let anyp = AnyPrecisionLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 4, 12, 10);
        let art = anyp.artifact().clone();
        for prec in 1..=4u32 {
            let view = AnyPrecisionLinear::from_artifact(art.clone(), prec);
            assert!(Arc::ptr_eq(view.artifact(), &art), "views must share one artifact");
            // Every view reports the whole deployable artifact.
            assert_eq!(view.storage_bytes(), art.storage_bytes());
            assert_matmul_is_looped_matvec(&view, 5, 112 + prec as u64);
        }
        // Coarser views decode through smaller tables but the SAME planes:
        // a 2-bit decode reads a strict prefix of the 4-bit plane bytes.
        assert!(art.planes().prefix_storage_bytes(2) < art.planes().storage_bytes());
    }

    #[test]
    fn anyprec_coarse_tables_are_sorted_prefix_means() {
        // Hand-checkable construction: one channel, bits = 2, codebook
        // [0.5, -1.0, 2.0, 0.0] sorts to [-1.0, 0.0, 0.5, 2.0]; the 1-bit
        // table averages adjacent pairs. Codes remap through the sort.
        let mut cbs = Mat::zeros(1, 4);
        cbs.row_mut(0).copy_from_slice(&[0.5, -1.0, 2.0, 0.0]);
        let codes = [0u16, 1, 2, 3]; // d_in = 4, d_out = 1
        let art = AnyPrecArtifact::new(&codes, &cbs, 2, 4, 1);
        assert_eq!(art.lut(2).row(0), &[-1.0, 0.0, 0.5, 2.0]);
        assert_eq!(art.lut(1).row(0), &[-0.5, 1.25]);
        // Remap: 0.5 → slot 2, -1.0 → 0, 2.0 → 3, 0.0 → 1.
        assert_eq!(art.planes().to_vec(2), vec![2, 0, 3, 1]);
        // 1-bit prefix keeps the high plane: codes >> 1.
        assert_eq!(art.planes().to_vec(1), vec![1, 0, 1, 0]);
        // End to end: x = e0 picks element (0,0) → code 2 → 0.5 at full
        // precision, prefix 1 → 1.25 at 1 bit.
        let full = AnyPrecisionLinear::from_artifact(Arc::new(art), 2);
        let coarse = AnyPrecisionLinear::from_artifact(full.artifact().clone(), 1);
        let x = [1.0f32, 0.0, 0.0, 0.0];
        let mut y = [0.0f32];
        full.matvec(&x, &mut y);
        assert_eq!(y, [0.5]);
        coarse.matvec(&x, &mut y);
        assert_eq!(y, [1.25]);
    }

    #[test]
    #[should_panic(expected = "indexes past")]
    fn anyprec_rejects_out_of_table_codes() {
        let mut rng = Rng::new(72);
        let codebooks = Mat::randn(4, 16, 1.0, &mut rng);
        AnyPrecisionLinear::new(&[16u16; 8], codebooks, 4, 2, 4);
    }

    #[test]
    fn storage_ordering_uniform_vs_fp32() {
        // 2-bit packed should be ~16x smaller than fp32.
        let mut rng = Rng::new(4);
        let w = Mat::randn(128, 64, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 2);
        let (_, codes) = round_all(&w, &grid);
        let lin = UniformScalarLinear::new(&codes, &grid, 128, 64);
        let fp32 = 128 * 64 * 4;
        assert!(lin.storage_bytes() * 10 < fp32, "{} vs {}", lin.storage_bytes(), fp32);
    }
}
