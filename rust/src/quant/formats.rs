//! Serving formats: fused dequant-matvec kernels implementing
//! [`model::forward::LinearOp`] so the decode engine can serve any format.
//!
//! These are the CPU analogs of the paper's CUDA kernels (Table 2):
//! * [`UniformScalarLinear`] — LUT-GEMM-style: packed codes + affine grid,
//! * [`LutLinear`]           — Any-Precision-LLM-style: packed codes +
//!                             per-channel codebook gather,
//! * [`VqLinear`]            — vector codebook decode per dim-point,
//! * [`TrellisLinear`]       — QTIP-style stateful decode (extra ALU work
//!                             per weight → the paper's vector-quant decode
//!                             overhead shows up honestly).

use crate::model::forward::{matmul_col_sharded, LinearOp};
use crate::tensor::Mat;

use super::grid::UniformGrid;
use super::packing::PackedCodes;
use super::trellis::{Generator, Trellis, TrellisCode};

// ---------------------------------------------------------------------------
// Uniform scalar
// ---------------------------------------------------------------------------

pub struct UniformScalarLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub codes: PackedCodes, // row-major d_in × d_out
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
}

impl UniformScalarLinear {
    pub fn new(codes: &[u16], grid: &UniformGrid, d_in: usize, d_out: usize) -> Self {
        assert_eq!(codes.len(), d_in * d_out);
        UniformScalarLinear {
            d_in,
            d_out,
            codes: PackedCodes::pack(codes, grid.bits),
            scale: grid.scale.clone(),
            zero: grid.zero.clone(),
        }
    }
}

impl LinearOp for UniformScalarLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d_in);
        debug_assert_eq!(out.len(), self.d_out);
        // out_j = scale_j · Σ_i x_i q_ij + zero_j · Σ_i x_i
        out.fill(0.0);
        let mut row = vec![0u16; self.d_out];
        let mut xsum = 0.0f32;
        for (i, &xi) in x.iter().enumerate() {
            xsum += xi;
            if xi == 0.0 {
                continue;
            }
            self.codes.unpack_range(i * self.d_out, &mut row);
            for (o, &q) in out.iter_mut().zip(&row) {
                *o += xi * q as f32;
            }
        }
        for j in 0..self.d_out {
            out[j] = out[j] * self.scale[j] + xsum * self.zero[j];
        }
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut Mat, lo: usize, hi: usize) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(out.cols, hi - lo);
        debug_assert_eq!(xs.rows, out.rows);
        let b = xs.rows;
        out.data.fill(0.0);
        let mut row = vec![0u16; hi - lo];
        let mut xsum = vec![0.0f32; b];
        for i in 0..self.d_in {
            // Unpack this shard's slice of code row i once for the batch.
            let mut any = false;
            for (r, s) in xsum.iter_mut().enumerate() {
                let xi = xs.at(r, i);
                *s += xi;
                any |= xi != 0.0;
            }
            if !any {
                continue;
            }
            self.codes.unpack_range(i * self.d_out + lo, &mut row);
            for r in 0..b {
                let xi = xs.at(r, i);
                if xi == 0.0 {
                    continue;
                }
                for (o, &q) in out.row_mut(r).iter_mut().zip(&row) {
                    *o += xi * q as f32;
                }
            }
        }
        for r in 0..b {
            let orow = out.row_mut(r);
            for (jj, o) in orow.iter_mut().enumerate() {
                *o = *o * self.scale[lo + jj] + xsum[r] * self.zero[lo + jj];
            }
        }
    }

    fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + (self.scale.len() + self.zero.len()) * 2 // fp16 grid
    }
}

// ---------------------------------------------------------------------------
// Non-uniform scalar (per-channel LUT)
// ---------------------------------------------------------------------------

pub struct LutLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub codes: PackedCodes, // row-major d_in × d_out
    /// d_out × m, row-contiguous per channel.
    pub codebooks: Mat,
}

impl LutLinear {
    pub fn new(codes: &[u16], codebooks: Mat, bits: u32, d_in: usize, d_out: usize) -> Self {
        assert_eq!(codes.len(), d_in * d_out);
        assert_eq!(codebooks.rows, d_out);
        LutLinear { d_in, d_out, codes: PackedCodes::pack(codes, bits), codebooks }
    }
}

impl LinearOp for LutLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let m = self.codebooks.cols;
        let cb = &self.codebooks.data;
        let bits = self.codes.bits as usize;
        if self.codes.rows_aligned(self.d_out) {
            // Fused decode+FMA: walk packed words directly, no staging buffer.
            let per_word = 32 / bits;
            let mask = (1u32 << bits) - 1;
            let words = self.codes.words();
            let words_per_row = self.d_out / per_word;
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let row_words = &words[i * words_per_row..(i + 1) * words_per_row];
                let mut j = 0usize;
                for &w in row_words {
                    let mut word = w;
                    for _ in 0..per_word {
                        let q = (word & mask) as usize;
                        word >>= bits;
                        *unsafe { out.get_unchecked_mut(j) } +=
                            xi * unsafe { *cb.get_unchecked(j * m + q) };
                        j += 1;
                    }
                }
            }
            return;
        }
        let mut row = vec![0u16; self.d_out];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            self.codes.unpack_range(i * self.d_out, &mut row);
            for j in 0..self.d_out {
                // gather: w_ij = cb[j][code]
                *unsafe { out.get_unchecked_mut(j) } +=
                    xi * unsafe { *cb.get_unchecked(j * m + row[j] as usize) };
            }
        }
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut Mat, lo: usize, hi: usize) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(out.cols, hi - lo);
        debug_assert_eq!(xs.rows, out.rows);
        let b = xs.rows;
        out.data.fill(0.0);
        let m = self.codebooks.cols;
        let cb = &self.codebooks.data;
        let mut row = vec![0u16; hi - lo];
        let mut wrow = vec![0.0f32; hi - lo];
        for i in 0..self.d_in {
            if (0..b).all(|r| xs.at(r, i) == 0.0) {
                continue;
            }
            // Gather this shard's slice of weight row i through the LUT
            // once, then FMA it into every lane — the decode cost is
            // amortized across the batch.
            self.codes.unpack_range(i * self.d_out + lo, &mut row);
            for (jj, w) in wrow.iter_mut().enumerate() {
                *w = cb[(lo + jj) * m + row[jj] as usize];
            }
            for r in 0..b {
                let xi = xs.at(r, i);
                if xi == 0.0 {
                    continue;
                }
                for (o, &w) in out.row_mut(r).iter_mut().zip(&wrow) {
                    *o += xi * w;
                }
            }
        }
    }

    fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.codebooks.data.len() * 2 // fp16 LUT
    }
}

// ---------------------------------------------------------------------------
// Vector quantization
// ---------------------------------------------------------------------------

pub struct VqLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub dim: usize,
    /// codes: (d_in/dim) × d_out row-major per point.
    pub codes: PackedCodes,
    pub code_bits: u32,
    /// d_out × (k·dim) centroid table.
    pub codebooks: Mat,
}

impl VqLinear {
    pub fn new(
        codes: &[u16],
        codebooks: Mat,
        dim: usize,
        code_bits: u32,
        d_in: usize,
        d_out: usize,
    ) -> Self {
        assert_eq!(codes.len(), (d_in / dim) * d_out);
        VqLinear {
            d_in,
            d_out,
            dim,
            codes: PackedCodes::pack(codes, code_bits),
            code_bits,
            codebooks,
        }
    }
}

impl LinearOp for VqLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let dim = self.dim;
        let n_pts = self.d_in / dim;
        let cbw = self.codebooks.cols;
        let mut row = vec![0u16; self.d_out];
        for p in 0..n_pts {
            let xs = &x[p * dim..(p + 1) * dim];
            self.codes.unpack_range(p * self.d_out, &mut row);
            for j in 0..self.d_out {
                let c = row[j] as usize * dim;
                let cent = &self.codebooks.data[j * cbw + c..j * cbw + c + dim];
                let mut acc = 0.0f32;
                for t in 0..dim {
                    acc += xs[t] * cent[t];
                }
                out[j] += acc;
            }
        }
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut Mat, lo: usize, hi: usize) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(out.cols, hi - lo);
        debug_assert_eq!(xs.rows, out.rows);
        let b = xs.rows;
        out.data.fill(0.0);
        let dim = self.dim;
        let n_pts = self.d_in / dim;
        let cbw = self.codebooks.cols;
        let mut row = vec![0u16; hi - lo];
        for p in 0..n_pts {
            // One code unpack + one centroid gather per (point, channel)
            // of this shard's column window, shared by all lanes.
            self.codes.unpack_range(p * self.d_out + lo, &mut row);
            for (jj, &code) in row.iter().enumerate() {
                let j = lo + jj;
                let c = code as usize * dim;
                let cent = &self.codebooks.data[j * cbw + c..j * cbw + c + dim];
                for r in 0..b {
                    let xsr = &xs.row(r)[p * dim..(p + 1) * dim];
                    let mut acc = 0.0f32;
                    for t in 0..dim {
                        acc += xsr[t] * cent[t];
                    }
                    *out.at_mut(r, jj) += acc;
                }
            }
        }
    }

    fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.codebooks.data.len() * 2
    }
}

// ---------------------------------------------------------------------------
// Trellis (QTIP-style stateful decode)
// ---------------------------------------------------------------------------

pub struct TrellisLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub cfg: Trellis,
    pub gen: Generator,
    /// Per-column packed symbols, column-major: column j occupies
    /// [j*d_in, (j+1)*d_in).
    pub symbols: PackedCodes,
    pub initial_states: Vec<u32>,
    pub scales: Vec<f32>,
}

impl TrellisLinear {
    pub fn new(codes: &[TrellisCode], gen: Generator, cfg: Trellis, d_in: usize) -> Self {
        let d_out = codes.len();
        let mut flat = Vec::with_capacity(d_in * d_out);
        for code in codes {
            assert_eq!(code.symbols.len(), d_in);
            flat.extend_from_slice(&code.symbols);
        }
        TrellisLinear {
            d_in,
            d_out,
            symbols: PackedCodes::pack(&flat, cfg.bits),
            initial_states: codes.iter().map(|c| c.initial_state).collect(),
            scales: codes.iter().map(|c| c.scale).collect(),
            gen,
            cfg,
        }
    }
}

impl LinearOp for TrellisLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn matvec(&self, x: &[f32], out: &mut [f32]) {
        let mask = (1u32 << self.cfg.state_bits) - 1;
        let bits = self.cfg.bits;
        let mut syms = vec![0u16; self.d_in];
        for j in 0..self.d_out {
            let mut state = self.initial_states[j];
            self.symbols.unpack_range(j * self.d_in, &mut syms);
            let mut acc = 0.0f32;
            for (i, &sym) in syms.iter().enumerate() {
                state = ((state << bits) | sym as u32) & mask;
                acc += x[i] * self.gen.value(state);
            }
            out[j] = acc * self.scales[j];
        }
    }

    fn matmul(&self, xs: &Mat, out: &mut Mat) {
        matmul_col_sharded(self, xs, out);
    }

    fn matmul_cols(&self, xs: &Mat, out: &mut Mat, lo: usize, hi: usize) {
        debug_assert_eq!(xs.cols, self.d_in);
        debug_assert_eq!(out.cols, hi - lo);
        debug_assert_eq!(xs.rows, out.rows);
        let b = xs.rows;
        let mask = (1u32 << self.cfg.state_bits) - 1;
        let bits = self.cfg.bits;
        let mut syms = vec![0u16; self.d_in];
        let mut acc = vec![0.0f32; b];
        for j in lo..hi {
            // The stateful trellis walk — the expensive part of QTIP-style
            // decode — runs once per column and feeds every lane. Columns
            // are decode-independent, so the window shards cleanly.
            let mut state = self.initial_states[j];
            self.symbols.unpack_range(j * self.d_in, &mut syms);
            acc.fill(0.0);
            for (i, &sym) in syms.iter().enumerate() {
                state = ((state << bits) | sym as u32) & mask;
                let w = self.gen.value(state);
                for (r, a) in acc.iter_mut().enumerate() {
                    *a += xs.at(r, i) * w;
                }
            }
            for (r, &a) in acc.iter().enumerate() {
                *out.at_mut(r, j - lo) = a * self.scales[j];
            }
        }
    }

    fn storage_bytes(&self) -> usize {
        self.symbols.storage_bytes() + self.d_out * (2 + 4) // fp16 scale + init state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::{round_all, rtn_quantize, UniformGrid};
    use crate::quant::trellis::trellis_quantize;
    use crate::tensor::ops::{matmul_tn, matvec};
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn uniform_format_matches_dense_dequant() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 3);
        let (w_hat, codes) = round_all(&w, &grid);
        let lin = UniformScalarLinear::new(&codes, &grid, 24, 10);
        let x: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
        let want = matvec(&w_hat.transpose(), &x);
        let mut got = vec![0.0; 10];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
        assert!(lin.storage_bytes() < 24 * 10 * 4 / 2);
    }

    #[test]
    fn lut_format_matches_dense_dequant() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(16, 8, 1.0, &mut rng);
        let res = rtn_quantize(&w, 4);
        let lin = LutLinear::new(&res.codes.clone().unwrap(), res.codebooks.clone().unwrap(), 4, 16, 8);
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let want = matvec(&res.w_hat.transpose(), &x);
        let mut got = vec![0.0; 8];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn vq_format_matches_dense_dequant() {
        let mut rng = Rng::new(2);
        let (d_in, d_out, dim, k) = (12, 6, 2, 4);
        // Build a VQ-coded weight matrix directly.
        let codebooks = Mat::randn(d_out, k * dim, 1.0, &mut rng);
        let n_pts = d_in / dim;
        let codes: Vec<u16> = (0..n_pts * d_out).map(|_| rng.below(k) as u16).collect();
        let mut w_hat = Mat::zeros(d_in, d_out);
        for p in 0..n_pts {
            for j in 0..d_out {
                let c = codes[p * d_out + j] as usize * dim;
                for t in 0..dim {
                    *w_hat.at_mut(p * dim + t, j) = codebooks.at(j, c + t);
                }
            }
        }
        let lin = VqLinear::new(&codes, codebooks, dim, 2, d_in, d_out);
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal_f32()).collect();
        let want = matvec(&w_hat.transpose(), &x);
        let mut got = vec![0.0; d_out];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn trellis_format_matches_dense_dequant() {
        let mut rng = Rng::new(3);
        let x_cal = Mat::randn(64, 32, 1.0, &mut rng);
        let h = matmul_tn(&x_cal, &x_cal);
        let w = Mat::randn(32, 4, 1.0, &mut rng);
        let cfg = Trellis::new(2, crate::cfg::TrellisVariant::Hyb);
        let (qr, codes, gen) = trellis_quantize(&h, &w, &cfg).unwrap();
        let lin = TrellisLinear::new(&codes, gen, cfg, 32);
        let x: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let want = matvec(&qr.w_hat.transpose(), &x);
        let mut got = vec![0.0; 4];
        lin.matvec(&x, &mut got);
        testing::assert_close(&got, &want, 1e-3, 1e-3).unwrap();
    }

    /// Batched `matmul` must equal looping `matvec` over the rows EXACTLY
    /// (bitwise) — at every column-shard count, including ones that do not
    /// divide d_out: the continuous-batching engine relies on this to keep
    /// greedy decode identical to the per-sequence path no matter how the
    /// worker pool splits the output channels.
    fn assert_matmul_is_looped_matvec(lin: &dyn LinearOp, b: usize, seed: u64) {
        use crate::model::forward::matmul_col_sharded_with;
        let mut rng = Rng::new(seed);
        let mut xs = Mat::randn(b, lin.d_in(), 1.0, &mut rng);
        for r in 0..b {
            xs.row_mut(r)[r % lin.d_in()] = 0.0; // exercise zero-skip paths
        }
        // One all-zero lane exercises the all-lanes-zero row skip.
        if b > 1 {
            xs.row_mut(b - 1).fill(0.0);
        }
        let mut want = Mat::zeros(b, lin.d_out());
        for r in 0..b {
            lin.matvec(xs.row(r), want.row_mut(r));
        }
        let mut got = Mat::zeros(b, lin.d_out());
        lin.matmul(&xs, &mut got);
        assert_eq!(got.data, want.data, "batched matmul != looped matvec");
        // 3 never divides the test d_outs evenly; d_out + 1 over-shards.
        for shards in [1usize, 2, 3, lin.d_out(), lin.d_out() + 1] {
            let mut sharded = Mat::zeros(b, lin.d_out());
            matmul_col_sharded_with(lin, &xs, &mut sharded, shards);
            assert_eq!(
                sharded.data, want.data,
                "column-sharded matmul (shards={shards}) != looped matvec"
            );
        }
    }

    #[test]
    fn uniform_matmul_exactly_matches_matvec() {
        let mut rng = Rng::new(10);
        let w = Mat::randn(24, 10, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 3);
        let (_, codes) = round_all(&w, &grid);
        let lin = UniformScalarLinear::new(&codes, &grid, 24, 10);
        assert_matmul_is_looped_matvec(&lin, 5, 100);
    }

    #[test]
    fn lut_matmul_exactly_matches_matvec_aligned_and_not() {
        let mut rng = Rng::new(11);
        // d_out = 8 at 4 bits: word-aligned rows (fused matvec path).
        let w = Mat::randn(16, 8, 1.0, &mut rng);
        let res = rtn_quantize(&w, 4);
        let lin = LutLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 4, 16, 8);
        assert_matmul_is_looped_matvec(&lin, 6, 101);
        // d_out = 10 at 3 bits: unaligned rows (staged matvec path).
        let w = Mat::randn(12, 10, 1.0, &mut rng);
        let res = rtn_quantize(&w, 3);
        let lin = LutLinear::new(&res.codes.unwrap(), res.codebooks.unwrap(), 3, 12, 10);
        assert_matmul_is_looped_matvec(&lin, 3, 102);
    }

    #[test]
    fn vq_matmul_exactly_matches_matvec() {
        let mut rng = Rng::new(12);
        let (d_in, d_out, dim, k) = (12, 6, 2, 4);
        let codebooks = Mat::randn(d_out, k * dim, 1.0, &mut rng);
        let n_pts = d_in / dim;
        let codes: Vec<u16> = (0..n_pts * d_out).map(|_| rng.below(k) as u16).collect();
        let lin = VqLinear::new(&codes, codebooks, dim, 2, d_in, d_out);
        assert_matmul_is_looped_matvec(&lin, 7, 103);
    }

    #[test]
    fn trellis_matmul_exactly_matches_matvec() {
        let mut rng = Rng::new(13);
        let x_cal = Mat::randn(64, 32, 1.0, &mut rng);
        let h = matmul_tn(&x_cal, &x_cal);
        let w = Mat::randn(32, 4, 1.0, &mut rng);
        let cfg = Trellis::new(2, crate::cfg::TrellisVariant::Hyb);
        let (_, codes, gen) = trellis_quantize(&h, &w, &cfg).unwrap();
        let lin = TrellisLinear::new(&codes, gen, cfg, 32);
        assert_matmul_is_looped_matvec(&lin, 4, 104);
    }

    #[test]
    fn fp32_matmul_exactly_matches_matvec() {
        let mut rng = Rng::new(14);
        let w = Mat::randn(20, 9, 1.0, &mut rng);
        assert_matmul_is_looped_matvec(&w, 5, 105);
    }

    #[test]
    fn storage_ordering_uniform_vs_fp32() {
        // 2-bit packed should be ~16x smaller than fp32.
        let mut rng = Rng::new(4);
        let w = Mat::randn(128, 64, 1.0, &mut rng);
        let grid = UniformGrid::fit(&w, 2);
        let (_, codes) = round_all(&w, &grid);
        let lin = UniformScalarLinear::new(&codes, &grid, 128, 64);
        let fp32 = 128 * 64 * 4;
        assert!(lin.storage_bytes() * 10 < fp32, "{} vs {}", lin.storage_bytes(), fp32);
    }
}
