//! The layer-wise quadratic objective (paper Eq. 6/7) and helpers.
//!
//! proxy_loss(H, W, Ŵ) = Σ_j (ŵ_j − w_j)^T H (ŵ_j − w_j)
//!
//! Every solver in this module is judged against this value; LNQ's descent
//! guarantee (Prop 4.1) and CD's monotonicity are property-tested on it.

use crate::tensor::{ops::matmul, Mat};

/// Σ_j Δ_j^T H Δ_j with Δ = Ŵ − W, computed as Σ elementwise(Δ ⊙ (H Δ)).
pub fn proxy_loss(h: &Mat, w: &Mat, w_hat: &Mat) -> f64 {
    assert_eq!(h.rows, h.cols);
    assert_eq!(h.rows, w.rows);
    assert_eq!((w.rows, w.cols), (w_hat.rows, w_hat.cols));
    let delta = w_hat.sub(w);
    let hd = matmul(h, &delta);
    delta
        .data
        .iter()
        .zip(&hd.data)
        .map(|(&d, &hd)| d as f64 * hd as f64)
        .sum()
}

/// Per-column objective values (diagnostics for group-level analysis).
pub fn proxy_loss_per_col(h: &Mat, w: &Mat, w_hat: &Mat) -> Vec<f64> {
    let delta = w_hat.sub(w);
    let hd = matmul(h, &delta);
    let mut out = vec![0.0; w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            out[j] += delta.at(i, j) as f64 * hd.at(i, j) as f64;
        }
    }
    out
}

/// Output MSE ‖XW − XŴ‖_F² given precomputed activations X.
pub fn output_mse(x: &Mat, w: &Mat, w_hat: &Mat) -> f64 {
    let z = matmul(x, w);
    let z_hat = matmul(x, w_hat);
    z.sub(&z_hat).frob_norm_sq()
}

/// Plain weight-space MSE (what RTN minimizes).
pub fn weight_mse(w: &Mat, w_hat: &Mat) -> f64 {
    w.sub(w_hat).frob_norm_sq() / (w.rows * w.cols) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_tn;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn proxy_loss_zero_iff_exact() {
        let mut rng = Rng::new(0);
        let x = Mat::randn(32, 8, 1.0, &mut rng);
        let h = matmul_tn(&x, &x);
        let w = Mat::randn(8, 5, 1.0, &mut rng);
        assert_eq!(proxy_loss(&h, &w, &w), 0.0);
        let mut w2 = w.clone();
        w2.data[3] += 0.1;
        assert!(proxy_loss(&h, &w, &w2) > 0.0);
    }

    #[test]
    fn proxy_loss_equals_output_mse_for_gram_h() {
        // When H = X^T X, the quadratic form equals ‖XW − XŴ‖² exactly.
        testing::check("proxy-vs-output-mse", 10, |rng| {
            let n = 8 + rng.below(24);
            let d = 2 + rng.below(10);
            let c = 1 + rng.below(6);
            let x = Mat::randn(n, d, 1.0, rng);
            let h = matmul_tn(&x, &x);
            let w = Mat::randn(d, c, 1.0, rng);
            let mut w_hat = w.clone();
            for v in w_hat.data.iter_mut() {
                *v += 0.05 * rng.normal_f32();
            }
            let a = proxy_loss(&h, &w, &w_hat);
            let b = output_mse(&x, &w, &w_hat);
            testing::ensure((a - b).abs() < 1e-2 * (1.0 + b), format!("{a} vs {b}"))
        });
    }

    #[test]
    fn per_col_sums_to_total() {
        let mut rng = Rng::new(1);
        let x = Mat::randn(20, 6, 1.0, &mut rng);
        let h = matmul_tn(&x, &x);
        let w = Mat::randn(6, 4, 1.0, &mut rng);
        let mut w_hat = w.clone();
        for v in w_hat.data.iter_mut() {
            *v += 0.1;
        }
        let per = proxy_loss_per_col(&h, &w, &w_hat);
        let total = proxy_loss(&h, &w, &w_hat);
        assert!((per.iter().sum::<f64>() - total).abs() < 1e-6 * (1.0 + total));
        assert!(per.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn weight_mse_basic() {
        let w = Mat::zeros(2, 2);
        let mut w2 = Mat::zeros(2, 2);
        w2.data = vec![1.0, 1.0, 1.0, 1.0];
        assert!((weight_mse(&w, &w2) - 1.0).abs() < 1e-12);
    }
}
