//! Quantization grids: per-output-channel uniform scalar grids and the
//! shared `ColGrid` rounding abstraction used by CD / GPTQ / LNQ.
//!
//! A `ColGrid` answers "round value v in column j to the nearest grid point"
//! — the only operation the solvers need — so the same CD/GPTQ code runs on
//! uniform grids (GPTQ baseline, SpinQuant W-step) and per-channel LUT
//! codebooks (LNQ, SqueezeLLM, GPTVQ 1D).

use crate::tensor::Mat;

use super::QuantResult;

/// Column-wise rounding grid.
pub trait ColGrid: Send + Sync {
    /// Number of representable levels m.
    fn levels(&self) -> usize;
    /// Nearest grid point for value `v` in column `j`: (decoded, code).
    fn round(&self, j: usize, v: f32) -> (f32, u16);
    /// Decode a code in column `j`.
    fn decode(&self, j: usize, code: u16) -> f32;
}

/// Per-column asymmetric uniform grid: v ≈ scale_j * q + zero_j, q ∈ [0, m).
#[derive(Debug, Clone)]
pub struct UniformGrid {
    pub bits: u32,
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
}

impl UniformGrid {
    /// Min/max calibrated per column of `w` ([d_in, d_out]).
    pub fn fit(w: &Mat, bits: u32) -> Self {
        let m = (1usize << bits) as f32;
        let mut scale = vec![0.0f32; w.cols];
        let mut zero = vec![0.0f32; w.cols];
        for j in 0..w.cols {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in 0..w.rows {
                let v = w.at(i, j);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if !lo.is_finite() || !hi.is_finite() {
                lo = 0.0;
                hi = 1e-8;
            } else if hi <= lo {
                // Constant column: a degenerate grid anchored at the value.
                hi = lo + 1e-8;
            }
            scale[j] = (hi - lo) / (m - 1.0);
            zero[j] = lo;
        }
        UniformGrid { bits, scale, zero }
    }
}

impl ColGrid for UniformGrid {
    fn levels(&self) -> usize {
        1 << self.bits
    }

    fn round(&self, j: usize, v: f32) -> (f32, u16) {
        let m = (1u32 << self.bits) - 1;
        let q = ((v - self.zero[j]) / self.scale[j]).round().clamp(0.0, m as f32) as u16;
        (self.decode(j, q), q)
    }

    fn decode(&self, j: usize, code: u16) -> f32 {
        self.scale[j] * code as f32 + self.zero[j]
    }
}

/// Per-column LUT grid backed by a (d_out × m) codebook matrix. Codebook
/// values need not be sorted; rounding is a linear scan over m (m ≤ 16 in
/// every paper setting, so this is branch-free fast in practice).
#[derive(Debug, Clone)]
pub struct LutGrid {
    /// d_out × m.
    pub codebooks: Mat,
}

impl LutGrid {
    pub fn new(codebooks: Mat) -> Self {
        LutGrid { codebooks }
    }
}

impl ColGrid for LutGrid {
    fn levels(&self) -> usize {
        self.codebooks.cols
    }

    fn round(&self, j: usize, v: f32) -> (f32, u16) {
        let row = self.codebooks.row(j);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (q, &c) in row.iter().enumerate() {
            let d = (v - c) * (v - c);
            if d < best_d {
                best_d = d;
                best = q;
            }
        }
        (row[best], best as u16)
    }

    fn decode(&self, j: usize, code: u16) -> f32 {
        self.codebooks.at(j, code as usize)
    }
}

/// Round-to-nearest baseline: fit a uniform grid per column and round every
/// weight independently (ignores H entirely).
pub fn rtn_quantize(w: &Mat, bits: u32) -> QuantResult {
    let grid = UniformGrid::fit(w, bits);
    let mut w_hat = Mat::zeros(w.rows, w.cols);
    let mut codes = vec![0u16; w.rows * w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            let (dec, code) = grid.round(j, w.at(i, j));
            *w_hat.at_mut(i, j) = dec;
            codes[i * w.cols + j] = code;
        }
    }
    // Decoded codebook matrix for LUT-style serving of the uniform format.
    let m = 1usize << bits;
    let codebooks = Mat::from_fn(w.cols, m, |j, q| grid.decode(j, q as u16));
    QuantResult {
        w_hat,
        codes: Some(codes),
        codebooks: Some(codebooks),
        avg_bits: avg_bits_scalar(w.rows, w.cols, bits),
    }
}

/// Average bits/weight for per-channel scalar formats: b plus the per-column
/// grid/codebook overhead amortized over the column (matches the paper's
/// 2.01 / 3.03 / 4.05-style accounting with fp16 codebook entries).
pub fn avg_bits_scalar(d_in: usize, _d_out: usize, bits: u32) -> f64 {
    let m = 1usize << bits;
    bits as f64 + (m as f64 * 16.0) / d_in as f64
}

/// Encode `w` against an arbitrary `ColGrid` by independent rounding.
pub fn round_all(w: &Mat, grid: &dyn ColGrid) -> (Mat, Vec<u16>) {
    let mut w_hat = Mat::zeros(w.rows, w.cols);
    let mut codes = vec![0u16; w.rows * w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            let (dec, code) = grid.round(j, w.at(i, j));
            *w_hat.at_mut(i, j) = dec;
            codes[i * w.cols + j] = code;
        }
    }
    (w_hat, codes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn uniform_grid_covers_range() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(64, 3, 1.0, &mut rng);
        let g = UniformGrid::fit(&w, 4);
        for j in 0..3 {
            let lo = w.col(j).iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = w.col(j).iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!((g.decode(j, 0) - lo).abs() < 1e-5);
            assert!((g.decode(j, 15) - hi).abs() < 1e-4);
        }
    }

    #[test]
    fn rtn_error_bounded_by_half_step() {
        testing::check("rtn-halfstep", 10, |rng| {
            let w = Mat::randn(32, 4, 1.0, rng);
            let bits = 2 + rng.below(3) as u32;
            let grid = UniformGrid::fit(&w, bits);
            let res = rtn_quantize(&w, bits);
            for j in 0..w.cols {
                let half = grid.scale[j] / 2.0;
                for i in 0..w.rows {
                    let err = (w.at(i, j) - res.w_hat.at(i, j)).abs();
                    testing::ensure(err <= half + 1e-5, format!("err {err} > half {half}"))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rtn_16_levels_distinct_codes() {
        let w = Mat::from_fn(16, 1, |i, _| i as f32);
        let res = rtn_quantize(&w, 4);
        let codes = res.codes.unwrap();
        let mut uniq = codes.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 16);
        testing::assert_close(&res.w_hat.data, &w.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn lut_grid_rounds_to_nearest() {
        let cb = Mat::from_vec(1, 4, vec![-1.0, 0.0, 0.5, 2.0]);
        let g = LutGrid::new(cb);
        assert_eq!(g.round(0, 0.26), (0.5, 2));
        assert_eq!(g.round(0, -3.0), (-1.0, 0));
        assert_eq!(g.round(0, 10.0), (2.0, 3));
        assert_eq!(g.decode(0, 1), 0.0);
    }

    #[test]
    fn avg_bits_accounting() {
        // 2-bit, d_in=512: 2 + 4*16/512 = 2.125; paper's 2.01 comes from
        // d_in≈4096: 2 + 64/4096 = 2.016.
        assert!((avg_bits_scalar(4096, 4096, 2) - 2.015625).abs() < 1e-9);
        assert!(avg_bits_scalar(128, 128, 4) > 4.0);
    }

    #[test]
    fn constant_column_does_not_nan() {
        let w = Mat::from_vec(4, 1, vec![3.0; 4]);
        let res = rtn_quantize(&w, 2);
        assert!(res.w_hat.data.iter().all(|v| v.is_finite()));
        testing::assert_close(&res.w_hat.data, &w.data, 1e-3, 1e-3).unwrap();
    }
}
