//! Cyclic coordinate descent on the layer-wise quadratic objective —
//! Algorithms 3 (precomputation) and 4 (precomputation + lazy batch
//! updates), plus the slower strategies of Appendix B.3 for the speedup
//! ablation (`exhaustive` → `closed-form` → `precompute` → `lazy`).
//!
//! All four strategies compute the *same* iterates (coordinate order is
//! fixed), so tests pin exact agreement; they differ only in how the
//! correction term Σ_{k≠i} H_ik (Ŵ_k − W_k) is maintained.
//!
//! We maintain R = H·(Ŵ − W) (an equivalent reformulation of the paper's
//! B = StrictUpper(H̃)(Ŵ−W) bookkeeping that is symmetric-safe):
//!   target_i = W_i − R_i / H_ii + (Ŵ_i − W_i)
//! which is exactly Eq. (12)'s closed form.

use crate::tensor::{ops::matmul, Mat};

use super::grid::ColGrid;

/// Update-propagation strategy (Appendix B.3 ladder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CdStrategy {
    /// Evaluate the objective delta for every candidate code explicitly.
    Exhaustive,
    /// Closed-form target per coordinate, correction recomputed on demand.
    ClosedForm,
    /// Algorithm 3: maintain R incrementally (row updates after each step).
    Precompute,
    /// Algorithm 4: lazy batch updates with block size `b`.
    Lazy { block: usize },
}

/// One CD pass configuration.
#[derive(Debug, Clone, Copy)]
pub struct CdConfig {
    pub cycles: usize,
    pub strategy: CdStrategy,
}

impl Default for CdConfig {
    fn default() -> Self {
        CdConfig { cycles: 4, strategy: CdStrategy::Lazy { block: 32 } }
    }
}

/// Run cyclic CD in place. `w_hat`/`codes` hold the current feasible iterate
/// (every entry on the grid) and are updated to the improved iterate.
pub fn cd_inplace(
    h: &Mat,
    w: &Mat,
    w_hat: &mut Mat,
    codes: &mut [u16],
    grid: &dyn ColGrid,
    cfg: CdConfig,
) {
    let d_in = w.rows;
    let d_out = w.cols;
    assert_eq!((h.rows, h.cols), (d_in, d_in));
    assert_eq!((w_hat.rows, w_hat.cols), (d_in, d_out));
    assert_eq!(codes.len(), d_in * d_out);

    match cfg.strategy {
        CdStrategy::Exhaustive => cd_exhaustive(h, w, w_hat, codes, grid, cfg.cycles),
        CdStrategy::ClosedForm => cd_closed_form(h, w, w_hat, codes, grid, cfg.cycles),
        CdStrategy::Precompute => cd_resident(h, w, w_hat, codes, grid, cfg.cycles, 1),
        CdStrategy::Lazy { block } => {
            cd_resident(h, w, w_hat, codes, grid, cfg.cycles, block.max(1))
        }
    }
}

/// Round row `i` given its correction row; returns true if anything changed.
#[inline]
fn round_row(
    i: usize,
    w: &Mat,
    w_hat: &mut Mat,
    codes: &mut [u16],
    grid: &dyn ColGrid,
    corr: &[f32], // Σ_{k≠i} H_ik (Ŵ_k − W_k), length d_out
    h_ii: f32,
    delta: &mut [f32],
) -> bool {
    let d_out = w.cols;
    let hii = if h_ii.abs() < 1e-20 { 1e-20 } else { h_ii };
    let mut changed = false;
    for j in 0..d_out {
        let target = w.at(i, j) - corr[j] / hii;
        let (dec, code) = grid.round(j, target);
        let old = w_hat.at(i, j);
        delta[j] = dec - old;
        if dec != old {
            changed = true;
            *w_hat.at_mut(i, j) = dec;
            codes[i * d_out + j] = code;
        }
    }
    changed
}

/// Strategy 1: per-coordinate, per-candidate objective evaluation.
fn cd_exhaustive(
    h: &Mat,
    w: &Mat,
    w_hat: &mut Mat,
    codes: &mut [u16],
    grid: &dyn ColGrid,
    cycles: usize,
) {
    let d_in = w.rows;
    let d_out = w.cols;
    let m = grid.levels();
    for _ in 0..cycles {
        for i in 0..d_in {
            let h_ii = h.at(i, i).max(1e-20);
            for j in 0..d_out {
                // corr = Σ_{k≠i} H_ik (Ŵ_kj − W_kj), recomputed per candidate
                // set (the deliberately-naive baseline of Appendix B.3).
                let mut corr = 0.0f32;
                for k in 0..d_in {
                    if k != i {
                        corr += h.at(i, k) * (w_hat.at(k, j) - w.at(k, j));
                    }
                }
                let mut best_q = codes[i * d_out + j];
                let mut best_val = w_hat.at(i, j);
                let mut best_obj = f32::INFINITY;
                for q in 0..m {
                    let c = grid.decode(j, q as u16);
                    let d = c - w.at(i, j);
                    // Δ objective as a function of this coordinate only:
                    let obj = h_ii * d * d + 2.0 * d * corr;
                    if obj < best_obj {
                        best_obj = obj;
                        best_q = q as u16;
                        best_val = c;
                    }
                }
                *w_hat.at_mut(i, j) = best_val;
                codes[i * d_out + j] = best_q;
            }
        }
    }
}

/// Strategy 2: closed-form target, correction recomputed per row.
fn cd_closed_form(
    h: &Mat,
    w: &Mat,
    w_hat: &mut Mat,
    codes: &mut [u16],
    grid: &dyn ColGrid,
    cycles: usize,
) {
    let d_in = w.rows;
    let d_out = w.cols;
    let mut corr = vec![0.0f32; d_out];
    let mut delta = vec![0.0f32; d_out];
    for _ in 0..cycles {
        for i in 0..d_in {
            corr.fill(0.0);
            for k in 0..d_in {
                if k == i {
                    continue;
                }
                let hik = h.at(i, k);
                if hik == 0.0 {
                    continue;
                }
                let wk = w_hat.row(k);
                let wok = w.row(k);
                for j in 0..d_out {
                    corr[j] += hik * (wk[j] - wok[j]);
                }
            }
            round_row(i, w, w_hat, codes, grid, &corr, h.at(i, i), &mut delta);
        }
    }
}

/// Strategies 3 & 4: R = H(Ŵ−W) resident; block = 1 gives Algorithm 3,
/// block > 1 gives Algorithm 4's lazy batch updates.
fn cd_resident(
    h: &Mat,
    w: &Mat,
    w_hat: &mut Mat,
    codes: &mut [u16],
    grid: &dyn ColGrid,
    cycles: usize,
    block: usize,
) {
    let d_in = w.rows;
    let d_out = w.cols;
    let mut corr = vec![0.0f32; d_out];
    let mut delta = vec![0.0f32; d_out];
    for _ in 0..cycles {
        // R = H (Ŵ − W), recomputed once per cycle.
        let diff = w_hat.sub(w);
        let mut r = matmul(h, &diff);
        // Block-level delta accumulator for the deferred global update.
        let mut block_delta = Mat::zeros(block, d_out);
        let mut s = 0;
        while s < d_in {
            let e = (s + block).min(d_in);
            for row in block_delta.data.iter_mut() {
                *row = 0.0;
            }
            for i in s..e {
                let h_ii = h.at(i, i);
                // corr_j = R_ij − H_ii (Ŵ_ij − W_ij)  (exclude self term)
                let r_row = r.row(i);
                for j in 0..d_out {
                    corr[j] = r_row[j] - h_ii * (w_hat.at(i, j) - w.at(i, j));
                }
                if round_row(i, w, w_hat, codes, grid, &corr, h_ii, &mut delta) {
                    // Immediate propagation inside the block only.
                    for k in (i + 1)..e {
                        let hki = h.at(k, i);
                        if hki == 0.0 {
                            continue;
                        }
                        let rk = r.row_mut(k);
                        for j in 0..d_out {
                            rk[j] += hki * delta[j];
                        }
                    }
                    let bd = block_delta.row_mut(i - s);
                    for j in 0..d_out {
                        bd[j] += delta[j];
                    }
                }
            }
            // Deferred global correction for the remaining rows:
            // R[e.., :] += H[e.., s..e] @ Δ_block
            for k in e..d_in {
                let rk_ptr = k * d_out;
                for (bi, i) in (s..e).enumerate() {
                    let hki = h.at(k, i);
                    if hki == 0.0 {
                        continue;
                    }
                    let bd = block_delta.row(bi);
                    let rk = &mut r.data[rk_ptr..rk_ptr + d_out];
                    for j in 0..d_out {
                        rk[j] += hki * bd[j];
                    }
                }
            }
            s = e;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::{round_all, LutGrid, UniformGrid};
    use crate::quant::objective::proxy_loss;
    use crate::tensor::ops::matmul_tn;
    use crate::testing;
    use crate::util::Rng;

    fn problem(rng: &mut Rng, d_in: usize, d_out: usize) -> (Mat, Mat) {
        let x = Mat::randn(d_in + 16, d_in, 1.0, rng);
        let h = matmul_tn(&x, &x);
        let w = Mat::randn(d_in, d_out, 1.0, rng);
        (h, w)
    }

    fn run(strategy: CdStrategy, h: &Mat, w: &Mat, grid: &UniformGrid, cycles: usize) -> (Mat, Vec<u16>) {
        let (mut w_hat, mut codes) = round_all(w, grid);
        cd_inplace(h, w, &mut w_hat, &mut codes, grid, CdConfig { cycles, strategy });
        (w_hat, codes)
    }

    #[test]
    fn all_strategies_agree_exactly() {
        testing::check("cd-strategy-agreement", 8, |rng| {
            let d_in = 6 + rng.below(18);
            let d_out = 1 + rng.below(6);
            let (h, w) = problem(rng, d_in, d_out);
            let grid = UniformGrid::fit(&w, 2 + rng.below(2) as u32);
            let base = run(CdStrategy::ClosedForm, &h, &w, &grid, 2);
            for strat in [
                CdStrategy::Exhaustive,
                CdStrategy::Precompute,
                CdStrategy::Lazy { block: 4 },
                CdStrategy::Lazy { block: 7 },
            ] {
                let got = run(strat, &h, &w, &grid, 2);
                testing::ensure(got.1 == base.1, format!("{strat:?} codes differ"))?;
                testing::assert_close(&got.0.data, &base.0.data, 1e-6, 1e-6)?;
            }
            Ok(())
        });
    }

    #[test]
    fn cd_monotonically_decreases_objective() {
        testing::check("cd-descent", 10, |rng| {
            let d_in = 10 + rng.below(14);
            let d_out = 1 + rng.below(4);
            let (h, w) = problem(rng, d_in, d_out);
            let grid = UniformGrid::fit(&w, 2);
            let (mut w_hat, mut codes) = round_all(&w, &grid);
            let mut prev = proxy_loss(&h, &w, &w_hat);
            for _ in 0..3 {
                cd_inplace(
                    &h,
                    &w,
                    &mut w_hat,
                    &mut codes,
                    &grid,
                    CdConfig { cycles: 1, strategy: CdStrategy::Lazy { block: 8 } },
                );
                let cur = proxy_loss(&h, &w, &w_hat);
                testing::ensure(
                    cur <= prev + 1e-3 * (1.0 + prev.abs()),
                    format!("objective rose: {prev} -> {cur}"),
                )?;
                prev = cur;
            }
            Ok(())
        });
    }

    #[test]
    fn cd_improves_over_rtn() {
        let mut rng = Rng::new(42);
        let (h, w) = problem(&mut rng, 24, 8);
        let grid = UniformGrid::fit(&w, 2);
        let (rtn_hat, _) = round_all(&w, &grid);
        let rtn_obj = proxy_loss(&h, &w, &rtn_hat);
        let (cd_hat, _) = run(CdStrategy::Lazy { block: 8 }, &h, &w, &grid, 4);
        let cd_obj = proxy_loss(&h, &w, &cd_hat);
        assert!(cd_obj < rtn_obj, "cd {cd_obj} !< rtn {rtn_obj}");
        // Typical gains are substantial at 2 bits:
        assert!(cd_obj < 0.9 * rtn_obj, "cd {cd_obj} vs rtn {rtn_obj}");
    }

    #[test]
    fn codes_stay_consistent_with_w_hat() {
        let mut rng = Rng::new(7);
        let (h, w) = problem(&mut rng, 16, 4);
        let cb = Mat::from_fn(4, 4, |_, q| q as f32 - 1.5);
        let grid = LutGrid::new(cb);
        let (mut w_hat, mut codes) = round_all(&w, &grid);
        cd_inplace(&h, &w, &mut w_hat, &mut codes, &grid, CdConfig::default());
        for i in 0..16 {
            for j in 0..4 {
                assert_eq!(w_hat.at(i, j), grid.decode(j, codes[i * 4 + j]));
            }
        }
    }

    #[test]
    fn diagonal_h_reduces_to_rtn() {
        // With H = I there are no interactions: CD must keep the RTN result.
        let mut rng = Rng::new(9);
        let w = Mat::randn(12, 3, 1.0, &mut rng);
        let h = Mat::eye(12);
        let grid = UniformGrid::fit(&w, 3);
        let (rtn_hat, rtn_codes) = round_all(&w, &grid);
        let mut w_hat = rtn_hat.clone();
        let mut codes = rtn_codes.clone();
        cd_inplace(&h, &w, &mut w_hat, &mut codes, &grid, CdConfig::default());
        assert_eq!(codes, rtn_codes);
    }
}
