//! The paper's algorithms and every baseline they are compared against.
//!
//! All layer-wise output-based methods share the interface
//! `fn quantize(H, W) -> QuantResult` where `H` is a `d_in × d_in` proxy
//! Hessian (plain `X^T X`, or GuidedQuant's group-averaged `H̄_k`) and
//! `W: [d_in, d_out]`. [`guided::GuidedQuant`] (Algorithm 1) wraps any of
//! them, splitting output channels into saliency groups and dispatching with
//! the per-group Hessian.

pub mod cd;
pub mod finetune;
pub mod formats;
pub mod gptq;
pub mod gptvq;
pub mod grid;
pub mod guided;
pub mod kmeans1d;
pub mod lnq;
pub mod objective;
pub mod packing;
pub mod rotation;
pub mod sparse;
pub mod spinquant;
pub mod squeezellm;
pub mod trellis;
pub mod vq;

use crate::tensor::Mat;

/// The decoded result of quantizing one weight matrix, plus enough structure
/// to build a serving format (codes + per-channel codebooks when they exist).
#[derive(Debug, Clone)]
pub struct QuantResult {
    /// Dequantized weights, same shape as the input `W`.
    pub w_hat: Mat,
    /// Per-weight code indices (d_in × d_out row-major), if LUT-coded.
    pub codes: Option<Vec<u16>>,
    /// Per-output-channel codebooks (d_out × m), if LUT-coded.
    pub codebooks: Option<Mat>,
    /// Average bits per weight actually spent (incl. codebook overhead).
    pub avg_bits: f64,
}

impl QuantResult {
    pub fn dense(w_hat: Mat, avg_bits: f64) -> Self {
        QuantResult { w_hat, codes: None, codebooks: None, avg_bits }
    }
}

/// A layer-wise output-based quantization algorithm Q (paper notation).
pub trait LayerQuantizer: Send + Sync {
    /// Quantize `w` against proxy Hessian `h` (must be d_in × d_in).
    fn quantize(&self, h: &Mat, w: &Mat) -> anyhow::Result<QuantResult>;
    fn name(&self) -> &'static str;
}
