//! Continuous-batching request scheduler.
//!
//! Requests enter an admission queue (`max_queued` back-pressure) and are
//! spliced into decode lanes up to `max_batch` wide. Each engine step runs
//! ONE batched model step over all active lanes ([`NativeModel::step_batch`],
//! which decodes every quantized weight tile once per step and fans the
//! (lane, head) attention items across the worker pool), finished sequences
//! are evicted mid-flight — their KV pages return to a [`KvArena`] slab —
//! and queued requests take over the freed lanes at the next step. Per-lane
//! arithmetic is bit-identical to the scalar [`NativeModel::step`] path, so
//! greedy outputs match per-sequence decode exactly regardless of batch
//! composition. Lane KV caches live in a contiguous slab passed straight to
//! the model, and per-step buffers are reused, so a warm steady-state step
//! performs no heap allocation; each step's tokens are exposed through
//! [`Scheduler::step_tokens`] for streaming consumers.
//!
//! Prefill is chunked: all freshly admitted lanes advance through their
//! prompts (all but the last token) together, one batched
//! [`NativeModel::step_batch_with`] call per prompt depth — weight tiles
//! are decoded once per chunk and the matmuls column-shard across the
//! worker pool. Lanes drop out of the chunk as their prompts end; the last
//! prompt token is the lane's first batched decode step, which produces
//! its first logits. [`ServeConfig::scalar_prefill`] keeps the per-lane
//! scalar reference path (pool-parallel across lanes) as the bit-identity
//! baseline.
//!
//! When [`ServeConfig::kv_budget_bytes`] is set, admission becomes
//! **cost-aware memory governance**: each queued request's worst-case KV
//! page cost (prompt length + `max_tokens`) must fit under
//! [`KV_HIGH_WATERMARK`] of the budget on top of what active lanes hold.
//! Above [`KV_LOW_WATERMARK`] the scheduler *brownouts* — admissions are
//! clamped to [`BROWNOUT_MAX_TOKENS`] (`degraded: true` in the response)
//! and the prefill chunk shrinks to one lane — and above the high
//! watermark the supervisor *preempts* the youngest lane
//! ([`Scheduler::preempt_youngest`]: pages deallocated, request requeued
//! under its original id with replay suppression). The measured per-step
//! drain rate ([`Scheduler::predicted_wait_ms`]) feeds honest
//! `Retry-After` values ([`retry_after_secs`]) and deadline-aware
//! shedding at the HTTP layer. With the budget at 0 (the default) every
//! governance branch is skipped and the engine behaves exactly as before.
//!
//! With [`ServeConfig::prefix_cache`] on (the default), finished lanes
//! donate their page-aligned prompt-prefix KV pages to a
//! [`PrefixIndex`] instead of just releasing them, and admission maps
//! the longest cached prefix of each new prompt read-only into the fresh
//! lane (copy-on-write pages, charged once to the cache in the
//! governance cost model) so chunked prefill starts *after* the cached
//! positions — a warm-template hit skips its prefill compute entirely.
//! Under KV pressure, cached-but-unreferenced pages are the first thing
//! shed ([`Scheduler::shed_cached_prefixes`]), before any brownout,
//! preemption, or 429. Greedy outputs are bit-identical with the cache
//! on or off: cached pages hold exactly the values the lane's own
//! prefill would have produced (deterministic arithmetic, per dtype).
//!
//! **Per-request precision.** [`Scheduler::with_bank`] accepts a bank of
//! `(precision, model)` pairs — e.g. the 2/3/4-bit views of one
//! any-precision artifact — and every request carries a decode precision
//! (its lane steps through that precision's model). Uniform-precision
//! steps keep the contiguous zero-allocation slab path; a mixed batch
//! decodes per precision group (gathered `&mut` refs — the documented
//! allocation cost of mixing). Between prefix-cache shedding and
//! brownout sits a milder governance rung: above the low watermark,
//! un-pinned admissions are *downshifted* to
//! [`ServeConfig::precision_floor`] — full token budget, no `degraded`
//! flag, counted in [`Scheduler::precision_downshifts`] — trading decode
//! quality for full-length answers before any clamping. Prefix caches
//! are kept per precision: KV pages produced by different-precision
//! models never mix, so bit-identity holds per precision.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cfg::ServeConfig;
use crate::coordinator::run_jobs;
use crate::model::{BatchScratch, DecodeState, KvArena, NativeModel};
use crate::serve::prefix::PrefixIndex;
use crate::util::{fault, percentile};

/// Greedy sampling: index of the max logit under IEEE total order
/// (`f32::total_cmp`), so degenerate logits — NaN, ±inf — still pick a
/// deterministic token instead of panicking the engine (`partial_cmp`
/// on NaN used to `unwrap` a `None`). Ties resolve to the highest index
/// (`Iterator::max_by` keeps the last maximum) — the same rule the
/// per-sequence engine has always used, so both paths pick identical
/// tokens. Positive NaN sorts above +inf, so any positively-signed NaN in
/// the row wins the argmax — which is what lets the decode step *detect*
/// a poisoned row and fail that lane instead of serving garbage.
pub fn greedy_argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap()
}

/// Millisecond knob → `Duration`; 0 means "disabled" everywhere a timeout
/// knob appears ([`ServeConfig::request_timeout_ms`] and friends).
fn ms_duration(ms: u64) -> Option<Duration> {
    (ms > 0).then(|| Duration::from_millis(ms))
}

/// KV pressure fraction above which the scheduler *brownouts*: new
/// admissions have their `max_tokens` clamped (responses carry
/// `degraded: true`) and the prefill chunk shrinks to one lane per step.
pub const KV_LOW_WATERMARK: f64 = 0.70;
/// KV pressure fraction above which admission refuses to start new lanes
/// and the supervisor preempts the youngest active lane (its pages are
/// deallocated and the request requeued under its original id/deadline).
/// The 10% headroom above the high watermark absorbs the page-boundary
/// growth of already-running lanes, which is how `kv_allocated_bytes`
/// stays under the budget at all times.
pub const KV_HIGH_WATERMARK: f64 = 0.90;
/// Effective `max_tokens` cap while browned out.
pub const BROWNOUT_MAX_TOKENS: usize = 32;

/// Honest `Retry-After`: seconds (rounded up) of the predicted queue wait,
/// clamped to a sane 1–60s range — never the hardcoded `1` that tells an
/// overloaded fleet to hammer again immediately.
pub fn retry_after_secs(predicted_wait_ms: u64) -> u64 {
    predicted_wait_ms.div_ceil(1000).clamp(1, 60)
}

/// Per-request service metrics (milliseconds).
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    /// Time spent waiting in the admission queue before prefill started.
    pub queue_wait_ms: f64,
    /// Submit → first generated token.
    pub ttft_ms: f64,
    /// Per-token decode latency percentiles for this request.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// KV cache size at completion (before the cache returned to the arena).
    pub kv_bytes: usize,
    /// Raw per-token decode latencies, for cross-request pooling.
    pub token_ms: Vec<f64>,
}

impl RequestMetrics {
    /// All-zero metrics, for requests that finished without decoding
    /// (expired in the queue, failed by an engine fault).
    pub fn empty() -> Self {
        RequestMetrics {
            queue_wait_ms: 0.0,
            ttft_ms: 0.0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            kv_bytes: 0,
            token_ms: Vec::new(),
        }
    }
}

/// Why a request left the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full `max_tokens` budget (the normal completion).
    Length,
    /// Evicted at its deadline (`request_timeout_ms` / per-request
    /// `timeout_ms` / `queue_timeout_ms`) with whatever it had generated.
    Timeout,
    /// Cancelled — client disconnect or an explicit
    /// [`Scheduler::cancel`]; partial output is returned.
    Cancelled,
    /// Killed by an engine fault attributed to this request (panic in its
    /// single-lane step, panic mid-prefill, non-finite logits, or a
    /// fail-fast engine restart). HTTP maps this to a 500.
    Failed,
}

impl FinishReason {
    /// Wire name (`finish_reason` in HTTP responses).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Length => "length",
            Self::Timeout => "timeout",
            Self::Cancelled => "cancelled",
            Self::Failed => "error",
        }
    }
}

/// A finished request: generated tokens (possibly partial), metrics, and
/// why it finished.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub metrics: RequestMetrics,
    pub finish: FinishReason,
    /// The request was admitted under brownout and its `max_tokens` was
    /// clamped below what was asked for ([`BROWNOUT_MAX_TOKENS`]); HTTP
    /// responses surface this as `"degraded": true`.
    pub degraded: bool,
    /// Decode precision the request was actually served at (bank label;
    /// 0 on a single-model engine = the native model). Differs from the
    /// requested precision when the downshift rung fired.
    pub precision: u8,
}

/// Per-request knobs for [`Scheduler::submit_opts`].
#[derive(Debug, Default, Clone, Copy)]
pub struct SubmitOpts {
    /// Overall wall-clock budget (submit → completion). `None` falls back
    /// to [`ServeConfig::request_timeout_ms`] (0 there = no deadline).
    pub timeout: Option<Duration>,
    /// Absolute deadline override — takes precedence over `timeout`. The
    /// supervisor's requeue path uses this so a request's original
    /// deadline survives an engine restart.
    pub deadline: Option<Instant>,
    /// Explicit request id (supervisor requeue after a restart: the
    /// consumer already holds this id). Explicit-id submissions bypass the
    /// queue-full check — they were admitted once already — and bump
    /// `next_id` past the id so fresh submissions never collide.
    pub id: Option<u64>,
    /// Decode precision (a bank label from [`Scheduler::with_bank`]).
    /// `None` or `Some(0)` takes the engine default. An explicit nonzero
    /// precision is *pinned*: the adaptive downshift rung never moves it
    /// (per-request choice is honored, and the supervisor's requeue path
    /// relies on pinning for bit-identical replay after a preemption).
    pub precision: Option<u8>,
}

struct Queued {
    id: u64,
    prompt: Vec<u32>,
    gen_tokens: usize,
    submitted: f64,
    /// Overall deadline (absolute); checked while queued and per-lane.
    deadline: Option<Instant>,
    /// Admission deadline ([`ServeConfig::queue_timeout_ms`]).
    queue_deadline: Option<Instant>,
    /// Brownout clamped `gen_tokens` below the requested budget.
    degraded: bool,
    /// Prompt positions covered by cached prefix pages mapped at
    /// admission ([`PrefixIndex::lookup_into`]); prefill starts here.
    cached: usize,
    /// Decode precision this request will be served at (bank label).
    precision: u8,
    /// Explicitly requested precision — exempt from the downshift rung.
    pinned: bool,
}

struct Lane {
    id: u64,
    /// The request's prompt, kept so the finished lane can donate its
    /// page-aligned prefix KV pages to the [`PrefixIndex`].
    prompt: Vec<u32>,
    /// Next token to feed (last prompt token, then each generated token).
    pending: u32,
    out: Vec<u32>,
    gen_tokens: usize,
    submitted: f64,
    admitted: f64,
    first_token: Option<f64>,
    token_ms: Vec<f64>,
    /// Overall deadline; expired lanes are evicted with partial output.
    deadline: Option<Instant>,
    /// The last step produced non-finite logits for this lane; evict it
    /// with [`FinishReason::Failed`] instead of serving a garbage token.
    poisoned: bool,
    /// Admitted under brownout with a clamped token budget.
    degraded: bool,
    /// Decode precision: this lane steps through the bank model carrying
    /// this label (and donates its prefix KV only to that precision's
    /// cache).
    precision: u8,
}

/// The continuous-batching engine: admission queue + decode lane slab.
///
/// Lane metadata (`lanes`) and KV caches (`states`) are parallel vectors
/// kept index-aligned (both `swap_remove` on eviction): the decode step
/// passes the contiguous `&mut [DecodeState]` slab straight to
/// [`NativeModel::step_batch_with`], so a steady-state step gathers no
/// per-step reference vector and performs no heap allocation once the
/// token/emission buffers are warm.
pub struct Scheduler<'m> {
    /// The default-precision model (vocab checks, arena geometry — every
    /// bank entry shares the same `ModelConfig`).
    model: &'m NativeModel,
    /// Precision bank, ascending by label. Single-model engines hold one
    /// entry labelled 0 ("native"); any-precision engines hold the
    /// 2/3/4-bit views of one shared artifact.
    models: Vec<(u8, &'m NativeModel)>,
    /// Bank label requests decode at when they don't ask for one.
    default_prec: u8,
    /// Downshift target under KV pressure (0 = rung disabled).
    floor_prec: u8,
    pub cfg: ServeConfig,
    /// Worker threads for the scalar-prefill reference path (chunked
    /// prefill and decode steps are batched and column-shard on the pool
    /// instead).
    workers: usize,
    epoch: Instant,
    queue: VecDeque<Queued>,
    lanes: Vec<Lane>,
    states: Vec<DecodeState>,
    arena: KvArena,
    scratch: BatchScratch,
    prefill_scratch: BatchScratch,
    /// Reused per-step pending-token buffer (cleared, never shrunk).
    token_buf: Vec<u32>,
    /// Tokens emitted by the most recent step, in lane order at the time
    /// of the step — the streaming drain ([`Scheduler::step_tokens`]).
    emitted: Vec<(u64, u32)>,
    /// Reused admission scratch: freshly admitted request metadata and
    /// their KV states, kept as index-aligned parallel vectors (drained
    /// into `lanes`/`states` each admission, capacity retained).
    fresh_meta: Vec<Queued>,
    fresh_states: Vec<DecodeState>,
    /// Recycled [`Lane`] shells: finished lanes return here with their
    /// token/latency buffer capacity intact, so a warm admission performs
    /// no heap allocation (bounded — see [`LANE_POOL_MAX`]).
    lane_pool: Vec<Lane>,
    /// Prompt-prefix KV page caches, one per bank precision (KV pages
    /// produced by different-precision models hold different values, so
    /// they must never be mapped across precisions). Empty when
    /// [`ServeConfig::prefix_cache`] is off — every prefix branch
    /// collapses to the uncached path.
    prefix: Vec<(u8, PrefixIndex)>,
    next_id: u64,
    steps: usize,
    lane_steps: usize,
    /// Requests admitted with a brownout-clamped token budget.
    brownouts: u64,
    /// Lanes preempted under KV pressure ([`Scheduler::preempt_youngest`]).
    preemptions: u64,
    /// Admissions downshifted to the floor precision under pressure.
    precision_downshifts: u64,
    /// EWMA of the batched decode step's wall time (ms) — the measured
    /// service rate behind `Retry-After` and predicted queue wait.
    step_ms_ewma: f64,
    /// EWMA of requests finishing per decode step (the drain rate's
    /// numerator; pairs with `step_ms_ewma`).
    finished_per_step_ewma: f64,
}

/// Most recycled lane shells worth keeping (covers any realistic
/// `max_batch`; beyond it, shells are dropped rather than pinned).
const LANE_POOL_MAX: usize = 256;

impl<'m> Scheduler<'m> {
    /// Engine with the config's worker count (`ServeConfig::workers`,
    /// 0 = the shared pool width).
    pub fn new(model: &'m NativeModel, cfg: ServeConfig) -> Self {
        let workers = cfg.resolved_workers();
        Self::with_workers(model, cfg, workers)
    }

    pub fn with_workers(model: &'m NativeModel, cfg: ServeConfig, workers: usize) -> Self {
        // Single-model engine: one bank entry labelled 0 ("native"), no
        // downshift floor — precision is a no-op and every path behaves
        // exactly as before the bank existed.
        Self::build(vec![(0, model)], cfg, workers, 0, 0)
    }

    /// Engine over a precision bank: `(label, model)` pairs — typically
    /// the 2/3/4-bit views of one any-precision artifact. Requests decode
    /// at `default_prec` unless they ask for another bank label; under KV
    /// pressure un-pinned admissions downshift to `floor_prec` (0
    /// disables the rung). Every bank model must share the default
    /// model's config (same vocab / KV geometry — one arena serves all
    /// lanes).
    ///
    /// Panics on an empty bank or on a default/floor label absent from
    /// the bank — programmer errors the config layer rejects earlier.
    pub fn with_bank(
        bank: Vec<(u8, &'m NativeModel)>,
        cfg: ServeConfig,
        default_prec: u8,
        floor_prec: u8,
    ) -> Self {
        let workers = cfg.resolved_workers();
        Self::build(bank, cfg, workers, default_prec, floor_prec)
    }

    fn build(
        mut models: Vec<(u8, &'m NativeModel)>,
        mut cfg: ServeConfig,
        workers: usize,
        default_prec: u8,
        floor_prec: u8,
    ) -> Self {
        assert!(!models.is_empty(), "scheduler needs at least one model");
        models.sort_by_key(|(p, _)| *p);
        let model = models
            .iter()
            .find(|(p, _)| *p == default_prec)
            .map(|(_, m)| *m)
            .expect("default precision must be a bank label");
        assert!(
            floor_prec == 0 || models.iter().any(|(p, _)| *p == floor_prec),
            "floor precision must be a bank label"
        );
        // Zero-width knobs are meaningless and (for max_queued) would make
        // every submit fail; config file / CLI layers reject them, and the
        // library layer clamps so a hand-built ServeConfig cannot wedge the
        // engine.
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.max_queued = cfg.max_queued.max(1);
        let prefix = if cfg.prefix_cache {
            models.iter().map(|(p, _)| (*p, PrefixIndex::new())).collect()
        } else {
            Vec::new()
        };
        Scheduler {
            arena: model.new_arena_with(cfg.kv_dtype),
            prefix,
            model,
            models,
            default_prec,
            floor_prec,
            cfg,
            workers: workers.max(1),
            epoch: Instant::now(),
            queue: VecDeque::new(),
            lanes: Vec::new(),
            states: Vec::new(),
            scratch: BatchScratch::new(),
            prefill_scratch: BatchScratch::new(),
            token_buf: Vec::new(),
            emitted: Vec::new(),
            fresh_meta: Vec::new(),
            fresh_states: Vec::new(),
            lane_pool: Vec::new(),
            next_id: 0,
            steps: 0,
            lane_steps: 0,
            brownouts: 0,
            preemptions: 0,
            precision_downshifts: 0,
            step_ms_ewma: 0.0,
            finished_per_step_ewma: 0.0,
        }
    }

    /// Pre-allocate `pages` KV pages in the arena's shared slab so decode
    /// page grabs (one per lane per [`crate::model::KV_PAGE_POS`] tokens)
    /// never hit the system allocator mid-serve. Clamped to the
    /// `kv_budget_bytes` ceiling: pre-warm must not allocate past the
    /// budget the admission path enforces.
    pub fn reserve_kv_pages(&self, pages: usize) {
        self.arena.reserve_pages_capped(pages, self.cfg.kv_budget_bytes);
    }

    /// Worker threads backing the scalar-prefill reference path.
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Bank lookup as an associated fn so call sites can borrow just the
    /// `models` field while other fields are mutably borrowed. The `&'m`
    /// refs are `Copy`, so the returned model outlives the field borrow.
    fn model_in(models: &[(u8, &'m NativeModel)], prec: u8) -> &'m NativeModel {
        models
            .iter()
            .find(|(p, _)| *p == prec)
            .map(|(_, m)| *m)
            .unwrap_or_else(|| models.last().expect("bank is never empty").1)
    }

    fn model_for(&self, prec: u8) -> &'m NativeModel {
        Self::model_in(&self.models, prec)
    }

    /// Bank labels served by this engine, ascending.
    pub fn precisions(&self) -> Vec<u8> {
        self.models.iter().map(|(p, _)| *p).collect()
    }

    /// The bank label unspecified requests decode at.
    pub fn default_precision(&self) -> u8 {
        self.default_prec
    }

    /// The downshift target (0 = rung disabled).
    pub fn floor_precision(&self) -> u8 {
        self.floor_prec
    }

    /// Cached-prefix positions matched for `prompt` in `prec`'s cache.
    /// Associated fn for the same disjoint-borrow reason as `model_in`.
    fn matched_in(prefix: &[(u8, PrefixIndex)], prec: u8, prompt: &[u32]) -> usize {
        prefix
            .iter()
            .find(|(p, _)| *p == prec)
            .map_or(0, |(_, pi)| pi.matched_positions(prompt))
    }

    fn prefix_idx_mut(&mut self, prec: u8) -> Option<&mut PrefixIndex> {
        self.prefix.iter_mut().find(|(p, _)| *p == prec).map(|(_, pi)| pi)
    }

    /// Evict up to `need` cached pages, walking the per-precision caches
    /// in bank order. Returns pages actually evicted (node granularity
    /// can overshoot `need` slightly, never undershoot while pages
    /// remain).
    fn trim_caches(prefix: &mut [(u8, PrefixIndex)], need: usize) -> usize {
        let mut evicted = 0;
        for (_, pi) in prefix.iter_mut() {
            if evicted >= need {
                break;
            }
            let have = pi.cached_pages();
            let take = (need - evicted).min(have);
            evicted += pi.trim_to(have - take);
        }
        evicted
    }

    /// Enqueue a request. Errors on an empty prompt (prefill needs at least
    /// one token — the old engine silently decoded token 0 from zeroed
    /// logits), on out-of-vocab tokens, and when the queue is full.
    pub fn submit(&mut self, prompt: &[u32], gen_tokens: usize) -> Result<u64> {
        self.submit_opts(prompt, gen_tokens, SubmitOpts::default())
    }

    /// [`Scheduler::submit`] with per-request deadline/id knobs.
    pub fn submit_opts(
        &mut self,
        prompt: &[u32],
        gen_tokens: usize,
        opts: SubmitOpts,
    ) -> Result<u64> {
        if prompt.is_empty() {
            bail!("empty prompt: prefill needs at least one (BOS) token");
        }
        let (precision, pinned) = match opts.precision {
            Some(p) if p != 0 => {
                if !self.models.iter().any(|(bp, _)| *bp == p) {
                    bail!(
                        "precision {p} not served (supported: {:?})",
                        self.precisions()
                    );
                }
                (p, true)
            }
            _ => (self.default_prec, false),
        };
        let vocab = self.model.cfg.vocab;
        if let Some(&t) = prompt.iter().find(|&&t| t as usize >= vocab) {
            bail!("prompt token {t} out of range for vocab {vocab}");
        }
        if opts.id.is_none() && self.queue.len() >= self.cfg.max_queued {
            bail!(
                "admission queue full ({} waiting, max_queued = {})",
                self.queue.len(),
                self.cfg.max_queued
            );
        }
        let id = match opts.id {
            Some(id) => {
                self.next_id = self.next_id.max(id + 1);
                id
            }
            None => {
                let id = self.next_id;
                self.next_id += 1;
                id
            }
        };
        let now = Instant::now();
        let timeout = opts.timeout.or_else(|| ms_duration(self.cfg.request_timeout_ms));
        let deadline = opts.deadline.or_else(|| timeout.map(|t| now + t));
        let queue_deadline = ms_duration(self.cfg.queue_timeout_ms).map(|t| now + t);
        self.queue.push_back(Queued {
            id,
            prompt: prompt.to_vec(),
            gen_tokens,
            submitted: self.now(),
            deadline,
            queue_deadline,
            degraded: false,
            cached: 0,
            precision,
            pinned,
        });
        Ok(id)
    }

    /// Should admission refuse this request outright on KV-budget grounds?
    /// True when its worst-case page cost (prompt + `max_tokens`, see
    /// [`KvArena::request_cost_bytes`]) exceeds the high watermark — it
    /// could *never* be admitted, so queueing it would only wedge the
    /// queue — or when the `kv-exhaust` fault site fires (the simulated
    /// out-of-memory refusal chaos scenarios inject).
    ///
    /// The prompt variant ([`Scheduler::kv_submit_refused_for`]) discounts
    /// a cached prefix — a warm-template request whose shared pages make
    /// it feasible must not 429. (If those pages are evicted before the
    /// request reaches admission, the infeasible-head path fails it there
    /// instead of wedging the queue.)
    pub fn kv_submit_refused(&self, prompt_len: usize, gen_tokens: usize) -> bool {
        if fault::hit(fault::KV_EXHAUST) {
            return true;
        }
        let budget = self.cfg.kv_budget_bytes;
        if budget == 0 {
            return false;
        }
        let high = (KV_HIGH_WATERMARK * budget as f64) as usize;
        self.arena.request_cost_bytes(prompt_len + gen_tokens) > high
    }

    /// [`Scheduler::kv_submit_refused`] with the prefix-cache discount:
    /// pages the prompt would borrow from the cache are charged once (to
    /// the cache), so they don't count against this request's cost. The
    /// discount reads the cache of the precision the request would decode
    /// at (`None`/`Some(0)` = the engine default).
    pub fn kv_submit_refused_for(
        &self,
        prompt: &[u32],
        gen_tokens: usize,
        precision: Option<u8>,
    ) -> bool {
        if fault::hit(fault::KV_EXHAUST) {
            return true;
        }
        let budget = self.cfg.kv_budget_bytes;
        if budget == 0 {
            return false;
        }
        let prec = match precision {
            Some(p) if p != 0 => p,
            _ => self.default_prec,
        };
        let cached = Self::matched_in(&self.prefix, prec, prompt);
        let high = (KV_HIGH_WATERMARK * budget as f64) as usize;
        self.arena.request_cost_bytes_shared(prompt.len() + gen_tokens, cached) > high
    }

    /// Cancel a queued or in-flight request: a queued one leaves the
    /// admission queue, an active one is evicted through the splicing path
    /// (its KV pages return to the arena slab). Returns the partial result
    /// (reason [`FinishReason::Cancelled`]) or `None` when the id is
    /// unknown — already finished, or never submitted.
    pub fn cancel(&mut self, id: u64) -> Option<FinishedRequest> {
        if let Some(qi) = self.queue.iter().position(|q| q.id == id) {
            let qr = self.queue.remove(qi).unwrap();
            return Some(self.finish_queued(qr, FinishReason::Cancelled));
        }
        if let Some(r) = self.lanes.iter().position(|l| l.id == id) {
            let lane = self.lanes.swap_remove(r);
            let state = self.states.swap_remove(r);
            return Some(self.finish_with(lane, state, FinishReason::Cancelled));
        }
        None
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.lanes.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.lanes.is_empty()
    }

    /// Mean number of active lanes per decode step (batch utilization).
    pub fn mean_occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.steps as f64
        }
    }

    /// KV caches currently pooled in the arena (freed by evicted lanes).
    pub fn pooled_kv(&self) -> usize {
        self.arena.pooled()
    }

    /// KV pages currently pooled in the arena's shared slab (whole pages
    /// returned by evicted lanes, less pages re-taken by growing lanes).
    pub fn pooled_kv_pages(&self) -> usize {
        self.arena.pooled_pages()
    }

    /// Storage dtype of every lane's KV cache ([`ServeConfig::kv_dtype`]).
    pub fn kv_dtype(&self) -> crate::cfg::KvDtype {
        self.arena.kv_dtype()
    }

    /// Bytes of K/V actually stored across all active lanes (grows with
    /// each lane's position; halves under f16 KV storage).
    pub fn kv_bytes(&self) -> usize {
        self.states.iter().map(DecodeState::kv_bytes).sum()
    }

    /// Bytes of KV page storage held by the engine: active lanes' pages
    /// plus pages pooled in the arena's shared slab.
    pub fn kv_allocated_bytes(&self) -> usize {
        self.kv_live_bytes() + self.arena.pooled_page_bytes()
    }

    /// Bytes of KV page storage held by *active lanes* plus the prefix
    /// cache (excludes the arena's idle pool, which growing lanes drain
    /// before allocating fresh pages) — the quantity the memory governor
    /// budgets. Shared pages are charged ONCE: each lane counts only the
    /// pages it owns ([`DecodeState::kv_owned_bytes`]); pages it borrows
    /// from the prefix index are counted by the cache term. (Pages still
    /// borrowed after a forced cache clear — the `prefix-evict` chaos
    /// site — are charged to nobody until their lanes finish; the window
    /// is one lane lifetime and only ever *under*-counts.)
    pub fn kv_live_bytes(&self) -> usize {
        self.states.iter().map(DecodeState::kv_owned_bytes).sum::<usize>()
            + self.prefix_cached_bytes()
    }

    /// Admissions that mapped at least one cached prefix chunk (summed
    /// over the per-precision caches).
    pub fn prefix_hits(&self) -> u64 {
        self.prefix.iter().map(|(_, pi)| pi.hits()).sum()
    }

    /// Prompt positions whose prefill compute was skipped by prefix
    /// hits, cumulative over the per-precision caches.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.prefix.iter().map(|(_, pi)| pi.tokens_saved()).sum()
    }

    /// KV pages currently held across the per-precision prefix caches.
    pub fn prefix_cached_pages(&self) -> usize {
        self.prefix.iter().map(|(_, pi)| pi.cached_pages()).sum()
    }

    /// Bytes of KV page storage held by the prefix cache (the charged-once
    /// term of [`Scheduler::kv_live_bytes`]).
    pub fn prefix_cached_bytes(&self) -> usize {
        self.prefix_cached_pages() * self.arena.page_bytes()
    }

    /// Shed cached-but-unreferenced prefix pages until live KV is back
    /// under the low watermark — the FIRST rung of the pressure ladder,
    /// tried before any brownout, preemption, or 429 (cached pages nobody
    /// references are the cheapest memory in the engine). Runs at the top
    /// of every governed admission and from the supervisor's governance
    /// sweep. Returns pages evicted; no-op when governance or the cache
    /// is off, or pressure is below the low watermark.
    pub fn shed_cached_prefixes(&mut self) -> usize {
        let budget = self.cfg.kv_budget_bytes;
        if budget == 0 || self.prefix.is_empty() {
            return 0;
        }
        let low = (KV_LOW_WATERMARK * budget as f64) as usize;
        let live = self.kv_live_bytes();
        if live <= low {
            return 0;
        }
        let page_bytes = self.arena.page_bytes().max(1);
        Self::trim_caches(&mut self.prefix, (live - low).div_ceil(page_bytes))
    }

    /// Worst-case KV bytes a request spanning `total_pos` positions would
    /// hold (admission-time cost estimation, exposed for tests and the
    /// HTTP layer's feasibility check).
    pub fn kv_request_cost_bytes(&self, total_pos: usize) -> usize {
        self.arena.request_cost_bytes(total_pos)
    }

    /// Live-KV pressure against the budget, 0.0 when governance is off.
    /// Published as the `kv_pressure` gauge; crosses [`KV_LOW_WATERMARK`]
    /// into brownout and [`KV_HIGH_WATERMARK`] into preemption.
    pub fn kv_pressure(&self) -> f64 {
        let budget = self.cfg.kv_budget_bytes;
        if budget == 0 {
            0.0
        } else {
            self.kv_live_bytes() as f64 / budget as f64
        }
    }

    /// True when live KV sits above the high watermark — the supervisor's
    /// cue to preempt the youngest lane.
    pub fn kv_over_high(&self) -> bool {
        let budget = self.cfg.kv_budget_bytes;
        budget > 0 && self.kv_live_bytes() as f64 > KV_HIGH_WATERMARK * budget as f64
    }

    /// Requests admitted with a brownout-clamped token budget so far.
    pub fn brownouts(&self) -> u64 {
        self.brownouts
    }

    /// Lanes preempted under KV pressure so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Admissions downshifted to the floor precision so far — the rung
    /// between prefix-cache shedding and brownout.
    pub fn precision_downshifts(&self) -> u64 {
        self.precision_downshifts
    }

    /// Predicted wait (ms) for a request joining the queue now, from the
    /// measured per-step drain rate: `queue depth × step time ÷ finishes
    /// per step`. Optimistically floored at one finish per `max_batch`
    /// steps so a cold or quiet window never predicts infinity; 0 before
    /// any step has been measured. Feeds `Retry-After` on 429s and the
    /// deadline-aware shed decision.
    pub fn predicted_wait_ms(&self) -> u64 {
        let depth = self.queue.len();
        if depth == 0 || self.step_ms_ewma <= 0.0 {
            return 0;
        }
        let rate = self.finished_per_step_ewma.max(1.0 / self.cfg.max_batch.max(1) as f64);
        (depth as f64 * self.step_ms_ewma / rate).ceil() as u64
    }

    /// Splice queued requests into free lanes and prefill their prompts.
    ///
    /// A warm admission (recycled arena states, recycled lane shells,
    /// reused `fresh_*` scratch, insertion co-sort in place of an
    /// allocating stable sort) performs no heap allocation — together with
    /// the batched prefill steps below, a warm chunked-prefill engine step
    /// stays off the allocator entirely (enforced by
    /// `warm_chunked_prefill_step_is_allocation_free`).
    fn admit(&mut self, finished: &mut Vec<FinishedRequest>) {
        debug_assert!(self.fresh_meta.is_empty() && self.fresh_states.is_empty());
        // Memory governance (all of it behind `kv_budget_bytes > 0`, so the
        // default config takes one branch and stays allocation-free):
        // admission is cost-aware — each queued request's worst-case page
        // bytes (prompt + max_tokens) must fit under the high watermark on
        // top of what active lanes already hold plus what this call has
        // admitted. Above the low watermark admissions brown out: the
        // token budget clamps to BROWNOUT_MAX_TOKENS (the response will
        // carry `degraded: true`) and the prefill chunk shrinks to one
        // lane per step.
        let budget = self.cfg.kv_budget_bytes;
        if budget > 0 {
            // Mildest relief first: cached-unreferenced prefix pages are
            // shed BEFORE the live reading that decides brownout, so a
            // page the cache can give back never degrades an admission.
            self.shed_cached_prefixes();
        }
        let mut live = if budget > 0 { self.kv_live_bytes() } else { 0 };
        let brownout = budget > 0 && live as f64 >= KV_LOW_WATERMARK * budget as f64;
        let high = (KV_HIGH_WATERMARK * budget as f64) as usize;
        let mut admitted_cost = 0usize;
        while self.lanes.len() + self.fresh_meta.len() < self.cfg.max_batch.max(1) {
            if brownout && !self.fresh_meta.is_empty() {
                break;
            }
            let Some(front) = self.queue.front() else { break };
            let (front_gen, front_prompt) = (front.gen_tokens, front.prompt.len());
            let (front_prec, front_pinned) = (front.precision, front.pinned);
            if front_gen == 0 {
                // Nothing to generate; completes at admission.
                let qr = self.queue.pop_front().unwrap();
                finished.push(self.finish_queued(qr, FinishReason::Length));
                continue;
            }
            let mut eff_gen = front_gen;
            let mut eff_prec = front_prec;
            if budget > 0 {
                if brownout {
                    // The rung between prefix shedding and brownout:
                    // downshift an un-pinned admission to the floor
                    // precision — full token budget, no `degraded` flag —
                    // trading decode quality for a full-length answer.
                    // Pinned (explicitly requested) precisions and
                    // requests already at/below the floor fall through to
                    // the brownout clamp.
                    if self.floor_prec != 0 && !front_pinned && eff_prec > self.floor_prec {
                        eff_prec = self.floor_prec;
                    } else {
                        eff_gen = eff_gen.min(BROWNOUT_MAX_TOKENS);
                    }
                }
                // Shared pages are charged once: the cached-prefix pages
                // this request would borrow are already counted in `live`
                // (the cache term), so its marginal cost excludes them.
                // The discount reads the cache of the precision the lane
                // will decode at.
                let cached = Self::matched_in(&self.prefix, eff_prec, &front.prompt);
                let mut cost =
                    self.arena.request_cost_bytes_shared(front_prompt + eff_gen, cached);
                if live + admitted_cost + cost > high {
                    // Rung 0 again, at request grain: memory the cache
                    // could give back must never cause a deferral or
                    // refusal, so evict just enough cached-unreferenced
                    // pages for this request to fit. Trimming may take the
                    // request's own matched prefix (its donor node can be
                    // the LRU victim), so the discount is re-derived.
                    let page_bytes = self.arena.page_bytes().max(1);
                    let need = (live + admitted_cost + cost - high).div_ceil(page_bytes);
                    let evicted = Self::trim_caches(&mut self.prefix, need);
                    if evicted > 0 {
                        live = self.kv_live_bytes();
                        let cached = Self::matched_in(&self.prefix, eff_prec, &front.prompt);
                        cost = self
                            .arena
                            .request_cost_bytes_shared(front_prompt + eff_gen, cached);
                    }
                }
                if live + admitted_cost + cost > high && eff_prec != front_prec {
                    // The downshift alone doesn't fit under the high
                    // watermark: escalate to brownout on top of it (the
                    // rungs stack rather than one masking the next).
                    eff_gen = eff_gen.min(BROWNOUT_MAX_TOKENS);
                    let cached = Self::matched_in(&self.prefix, eff_prec, &front.prompt);
                    cost =
                        self.arena.request_cost_bytes_shared(front_prompt + eff_gen, cached);
                }
                if live + admitted_cost + cost > high {
                    if self.lanes.is_empty() && self.fresh_meta.is_empty() {
                        // Alone in an empty engine and still over the
                        // watermark: this request can never run. Fail it
                        // rather than wedge the queue head forever (the
                        // HTTP layer refuses these before they queue;
                        // this guards direct scheduler users).
                        let qr = self.queue.pop_front().unwrap();
                        finished.push(self.finish_queued(qr, FinishReason::Failed));
                        continue;
                    }
                    // Over the high watermark: leave the queue intact and
                    // let running lanes drain (or the supervisor preempt).
                    break;
                }
                admitted_cost += cost;
            }
            let mut qr = self.queue.pop_front().unwrap();
            if eff_prec != qr.precision {
                qr.precision = eff_prec;
                self.precision_downshifts += 1;
            }
            if eff_gen < qr.gen_tokens {
                qr.gen_tokens = eff_gen;
                qr.degraded = true;
                self.brownouts += 1;
            }
            // Map the longest cached page-aligned prefix read-only into
            // the fresh lane (refcount bumps, no copy); prefill below
            // starts after the mapped positions. A zero-match walk is
            // allocation-free, so the uncached warm path stays off the
            // heap. Only the lane's own precision's cache is consulted —
            // pages from another precision's model hold different values.
            let mut state = self.arena.acquire();
            qr.cached = match self.prefix_idx_mut(eff_prec) {
                Some(pi) => pi.lookup_into(&qr.prompt, &mut state),
                None => 0,
            };
            self.fresh_meta.push(qr);
            self.fresh_states.push(state);
        }
        if self.fresh_meta.is_empty() {
            return;
        }
        // Injection point: the panic lands with freshly admitted requests
        // sitting in the fresh_* scratch, exactly the state
        // [`Scheduler::recover_admission`] must clean up.
        fault::maybe_panic(fault::PREFILL_PANIC);
        let admitted = self.now();
        if self.cfg.scalar_prefill {
            // Reference path: per-lane scalar prefill, parallel across
            // lanes on the worker pool. Jobs BORROW the fresh scratch
            // (disjoint field borrows: `&Queued` meta, `&mut DecodeState`)
            // rather than moving requests into closures, so a panicking
            // prefill leaves every admitted request identifiable in
            // `fresh_meta` for [`Scheduler::recover_admission`].
            let models = &self.models;
            let jobs: Vec<_> = self
                .fresh_meta
                .iter()
                .zip(self.fresh_states.iter_mut())
                .map(|(qr, state)| {
                    // Each job prefills through its request's own
                    // precision model (`&'m` refs are Copy, so the move
                    // closure captures the model, not the bank borrow).
                    let model = Self::model_in(models, qr.precision);
                    move || {
                        // Cached positions are already in the state's
                        // borrowed pages; scalar prefill resumes after
                        // them (rope comes from the state's position).
                        for &t in &qr.prompt[qr.cached..qr.prompt.len() - 1] {
                            model.step(state, t);
                        }
                    }
                })
                .collect();
            run_jobs(jobs, self.workers);
            let mut metas = std::mem::take(&mut self.fresh_meta);
            let mut states = std::mem::take(&mut self.fresh_states);
            for (qr, state) in metas.drain(..).zip(states.drain(..)) {
                self.push_lane(qr, state, admitted);
            }
            self.fresh_meta = metas;
            self.fresh_states = states;
            return;
        }
        // Chunked prefill: every fresh lane advances through its prompt in
        // lockstep, one batched step per prompt depth — each quantized
        // weight tile is decoded once per chunk (and the matmuls shard
        // their output columns across the pool) instead of once per lane.
        // Lanes whose prompts end drop out of the chunk; prefill logits are
        // discarded. Per-lane arithmetic is bit-identical to scalar
        // `step` prefill because `step_batch` is bit-identical per lane.
        //
        // Grouped by precision (ascending bank label), then longest
        // REMAINING prefill first within each group (prompt length minus
        // cached prefix positions), via an in-place stable insertion
        // co-sort of the two parallel scratch vectors (admissions are
        // max_batch-bounded, and equal keys keep submission order): each
        // precision group is then a CONTIGUOUS RANGE of the state slab,
        // and the lanes still in a group's chunk at any depth are a
        // prefix of that range — so each depth passes a contiguous
        // sub-slice and the reused token buffer to the group's own model,
        // with no per-depth gathering of `&mut` refs (the mixed-precision
        // prefill stays allocation-free). On a uniform-precision batch
        // the key reduces to remaining-descending and this is exactly the
        // single-group behavior the engine always had. Lanes at mixed
        // start depths batch naturally: each lane's rope position comes
        // from its own state, so a prefix-hit lane that resumes at
        // position 64 steps next to a cold lane at position 0. Lane order
        // never affects per-lane results.
        let remaining = |q: &Queued| q.prompt.len() - 1 - q.cached;
        let key = |q: &Queued| (q.precision, usize::MAX - remaining(q));
        for k in 1..self.fresh_meta.len() {
            let mut i = k;
            while i > 0 && key(&self.fresh_meta[i - 1]) > key(&self.fresh_meta[i]) {
                self.fresh_meta.swap(i - 1, i);
                self.fresh_states.swap(i - 1, i);
                i -= 1;
            }
        }
        let mut g0 = 0;
        while g0 < self.fresh_meta.len() {
            let prec = self.fresh_meta[g0].precision;
            let mut g1 = g0 + 1;
            while g1 < self.fresh_meta.len() && self.fresh_meta[g1].precision == prec {
                g1 += 1;
            }
            let model = Self::model_in(&self.models, prec);
            let max_pre = remaining(&self.fresh_meta[g0]);
            for t in 0..max_pre {
                self.token_buf.clear();
                for q in &self.fresh_meta[g0..g1] {
                    if q.cached + t + 1 < q.prompt.len() {
                        self.token_buf.push(q.prompt[q.cached + t]);
                    } else {
                        break;
                    }
                }
                let active = self.token_buf.len();
                model.step_batch_with(
                    &mut self.prefill_scratch,
                    &mut self.fresh_states[g0..g0 + active],
                    &self.token_buf,
                );
            }
            g0 = g1;
        }
        // Drain the scratch into live lanes, handing capacity back to the
        // fields afterwards (`mem::take` + restore keeps the buffers warm).
        let mut metas = std::mem::take(&mut self.fresh_meta);
        let mut states = std::mem::take(&mut self.fresh_states);
        for (qr, state) in metas.drain(..).zip(states.drain(..)) {
            self.push_lane(qr, state, admitted);
        }
        self.fresh_meta = metas;
        self.fresh_states = states;
    }

    fn push_lane(&mut self, qr: Queued, state: DecodeState, admitted: f64) {
        let pending = *qr.prompt.last().unwrap();
        // Reserve the known-bounded output/latency capacity up front so
        // steady-state pushes never reallocate (capped so an absurd
        // gen_tokens request cannot pre-pin memory). Recycled shells keep
        // their buffers, so a warm admission's reserve is a no-op.
        let reserve = qr.gen_tokens.min(1 << 16);
        let mut lane = self.lane_pool.pop().unwrap_or_else(|| Lane {
            id: 0,
            prompt: Vec::new(),
            pending: 0,
            out: Vec::new(),
            gen_tokens: 0,
            submitted: 0.0,
            admitted: 0.0,
            first_token: None,
            token_ms: Vec::new(),
            deadline: None,
            poisoned: false,
            degraded: false,
            precision: 0,
        });
        lane.id = qr.id;
        // Moved, not cloned: the prompt buffer rides along for the
        // finished lane's prefix donation (replacing a recycled shell's
        // old prompt only deallocates).
        lane.prompt = qr.prompt;
        lane.pending = pending;
        lane.out.clear();
        lane.out.reserve(reserve);
        lane.gen_tokens = qr.gen_tokens;
        lane.submitted = qr.submitted;
        lane.admitted = admitted;
        lane.first_token = None;
        lane.token_ms.clear();
        lane.token_ms.reserve(reserve);
        lane.deadline = qr.deadline;
        lane.poisoned = false;
        lane.degraded = qr.degraded;
        lane.precision = qr.precision;
        self.lanes.push(lane);
        self.states.push(state);
    }

    /// Tokens generated by the most recent [`Scheduler::step`], one
    /// `(request id, token)` per lane that decoded (including lanes that
    /// finished during that step), in lane order (precision-group order
    /// for a mixed-precision step — consumers key on the id, never the
    /// position). This is the streaming drain: callers can forward tokens
    /// after every step instead of waiting for sequence completion.
    pub fn step_tokens(&self) -> &[(u64, u32)] {
        &self.emitted
    }

    /// One engine step: admit queued requests, run one batched decode step
    /// over all lanes, evict finished sequences. Returns the requests that
    /// completed during this step; per-lane tokens of the step are exposed
    /// via [`Scheduler::step_tokens`] for streaming consumers.
    ///
    /// Internally this is [`Scheduler::admit_phase`] followed by
    /// [`Scheduler::decode_phase`] — the supervisor calls the two phases
    /// separately (each under its own `catch_unwind`) so a panic can be
    /// attributed to admission vs. decode.
    pub fn step(&mut self) -> Vec<FinishedRequest> {
        let mut finished = self.admit_phase();
        finished.extend(self.decode_phase());
        finished
    }

    /// Phase 1 of a step: sweep expired deadlines, then splice queued
    /// requests into free lanes and prefill them. A panic in here is
    /// recoverable via [`Scheduler::recover_admission`] — in-flight decode
    /// lanes are untouched by this phase.
    pub fn admit_phase(&mut self) -> Vec<FinishedRequest> {
        let mut finished = Vec::new();
        self.sweep_deadlines(&mut finished);
        self.admit(&mut finished);
        finished
    }

    /// Phase 2 of a step: one batched decode step over all active lanes,
    /// then eviction of finished / poisoned lanes.
    pub fn decode_phase(&mut self) -> Vec<FinishedRequest> {
        self.emitted.clear();
        let mut finished = Vec::new();
        if self.lanes.is_empty() {
            return finished;
        }
        fault::maybe_panic(fault::STEP_PANIC);
        if fault::hit(fault::PREFIX_EVICT) {
            // Chaos: force-drop the whole prefix cache while dependent
            // lanes are mid-decode. Their own page references keep the
            // shared storage alive, so they must complete bit-identically
            // — this site proves eviction can never corrupt a borrower.
            for (_, pi) in self.prefix.iter_mut() {
                pi.clear();
            }
        }
        debug_assert_eq!(self.lanes.len(), self.states.len());
        let t0 = Instant::now();
        // Inside the timed window: a stalled step IS a slow step, and the
        // measured step time feeds the drain-rate EWMA behind Retry-After
        // and predicted queue wait — the stall must be visible to both.
        fault::maybe_stall(fault::ENGINE_STALL, Duration::from_millis(1500));
        match self.uniform_precision() {
            Some(prec) => {
                // Uniform-precision batch (every single-model engine and
                // the common bank case): the contiguous state slab goes
                // straight to one model — no gathering, no allocation.
                self.token_buf.clear();
                self.token_buf.extend(self.lanes.iter().map(|l| l.pending));
                let model = Self::model_in(&self.models, prec);
                model.step_batch_with(&mut self.scratch, &mut self.states, &self.token_buf);
                if fault::hit(fault::NAN_LOGITS) {
                    // Corrupt lane 0's logits in place — models the
                    // degenerate outputs extreme quantization can produce.
                    for v in self.scratch.logits_mut().row_mut(0) {
                        *v = f32::NAN;
                    }
                }
                let scratch = &self.scratch;
                let emitted = &mut self.emitted;
                for (r, lane) in self.lanes.iter_mut().enumerate() {
                    Self::emit_lane(scratch.logits().row(r), lane, emitted);
                }
            }
            None => self.decode_mixed(),
        }
        self.steps += 1;
        self.lane_steps += self.lanes.len();
        // Per-token latency covers step + sampling, matching what the
        // per-sequence path times per token.
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        let now = self.now();
        for lane in self.lanes.iter_mut() {
            lane.token_ms.push(step_ms);
            if lane.first_token.is_none() {
                lane.first_token = Some(now);
            }
        }
        // Evict finished lanes; their KV pages go back to the arena slab so
        // admitted and growing lanes reuse them. Poisoned lanes leave as
        // Failed with the tokens generated before the fault.
        let mut r = 0;
        while r < self.lanes.len() {
            let reason = if self.lanes[r].poisoned {
                Some(FinishReason::Failed)
            } else if self.lanes[r].out.len() >= self.lanes[r].gen_tokens {
                Some(FinishReason::Length)
            } else {
                None
            };
            match reason {
                Some(reason) => {
                    let lane = self.lanes.swap_remove(r);
                    let state = self.states.swap_remove(r);
                    finished.push(self.finish_with(lane, state, reason));
                }
                None => r += 1,
            }
        }
        // Drain-rate bookkeeping: EWMA the step's wall time and how many
        // requests it finished. Plain float math — the steady-state step
        // stays off the allocator.
        const ALPHA: f64 = 0.2;
        self.step_ms_ewma = if self.step_ms_ewma == 0.0 {
            step_ms
        } else {
            (1.0 - ALPHA) * self.step_ms_ewma + ALPHA * step_ms
        };
        self.finished_per_step_ewma =
            (1.0 - ALPHA) * self.finished_per_step_ewma + ALPHA * finished.len() as f64;
        finished
    }

    /// The single precision every active lane shares, or `None` for a
    /// mixed batch. O(lanes), allocation-free — the steady-state check.
    fn uniform_precision(&self) -> Option<u8> {
        let p0 = self.lanes.first()?.precision;
        self.lanes.iter().all(|l| l.precision == p0).then_some(p0)
    }

    /// Greedy-sample one lane from its logits row. A non-finite max logit
    /// poisons the lane (Failed eviction below) instead of emitting the
    /// garbage token.
    fn emit_lane(row: &[f32], lane: &mut Lane, emitted: &mut Vec<(u64, u32)>) {
        let next = greedy_argmax(row);
        if !row[next as usize].is_finite() {
            lane.poisoned = true;
            return;
        }
        lane.out.push(next);
        lane.pending = next;
        emitted.push((lane.id, next));
    }

    /// Mixed-precision decode: one batched sub-step per precision group
    /// (bank order), gathering `&mut` state refs per group — the
    /// documented allocation cost of mixing precisions in one batch;
    /// uniform batches never come here. Per-lane arithmetic is identical
    /// to a uniform batch at the same precision: grouping only changes
    /// which lanes share a step, never what any lane computes.
    fn decode_mixed(&mut self) {
        for bi in 0..self.models.len() {
            let (prec, model) = self.models[bi];
            self.token_buf.clear();
            self.token_buf
                .extend(self.lanes.iter().filter(|l| l.precision == prec).map(|l| l.pending));
            if self.token_buf.is_empty() {
                continue;
            }
            {
                // Both filters run the same predicate over the same
                // index-aligned vectors, so group row g lines up with the
                // g-th matching lane in the emit loop below.
                let lanes = &self.lanes;
                let mut group: Vec<&mut DecodeState> = self
                    .states
                    .iter_mut()
                    .zip(lanes.iter())
                    .filter(|(_, l)| l.precision == prec)
                    .map(|(s, _)| s)
                    .collect();
                model.step_batch_with(&mut self.scratch, &mut group, &self.token_buf);
            }
            let scratch = &self.scratch;
            let emitted = &mut self.emitted;
            for (g, lane) in self.lanes.iter_mut().filter(|l| l.precision == prec).enumerate()
            {
                Self::emit_lane(scratch.logits().row(g), lane, emitted);
            }
        }
    }

    /// Preempt the youngest active lane (most recently admitted; ties go
    /// to the higher id): its KV pages are **deallocated** — pooling them
    /// would keep the bytes resident, defeating the point — and its
    /// `(id, precision)` is returned so the supervisor can resubmit the
    /// request under its original id/deadline — and pinned to the
    /// precision it was serving at, so replay suppression stays
    /// bit-identical even if the downshift rung had moved it. Refuses
    /// when fewer than two lanes are active: preempting the only lane
    /// could never make progress (admission would bounce it straight
    /// back).
    pub fn preempt_youngest(&mut self) -> Option<(u64, u8)> {
        if self.lanes.len() < 2 {
            return None;
        }
        let mut idx = 0;
        for r in 1..self.lanes.len() {
            let (cand, best) = (&self.lanes[r], &self.lanes[idx]);
            if cand.admitted > best.admitted
                || (cand.admitted == best.admitted && cand.id > best.id)
            {
                idx = r;
            }
        }
        let mut lane = self.lanes.swap_remove(idx);
        let state = self.states.swap_remove(idx);
        self.arena.discard(state);
        self.preemptions += 1;
        let (id, precision) = (lane.id, lane.precision);
        if self.lane_pool.len() < LANE_POOL_MAX {
            lane.out.clear();
            lane.token_ms.clear();
            self.lane_pool.push(lane);
        }
        Some((id, precision))
    }

    /// Evict every request (queued or active) whose deadline has passed.
    /// Expired active lanes return partial output ([`FinishReason::Timeout`]);
    /// expired queued requests never decoded. Allocation-free when nothing
    /// has expired (the common case on the steady-state path).
    fn sweep_deadlines(&mut self, finished: &mut Vec<FinishedRequest>) {
        let now = Instant::now();
        let mut qi = 0;
        while qi < self.queue.len() {
            let q = &self.queue[qi];
            let expired = q.deadline.map_or(false, |d| now >= d)
                || q.queue_deadline.map_or(false, |d| now >= d);
            if expired {
                let qr = self.queue.remove(qi).unwrap();
                finished.push(self.finish_queued(qr, FinishReason::Timeout));
            } else {
                qi += 1;
            }
        }
        let mut r = 0;
        while r < self.lanes.len() {
            if self.lanes[r].deadline.map_or(false, |d| now >= d) {
                let lane = self.lanes.swap_remove(r);
                let state = self.states.swap_remove(r);
                finished.push(self.finish_with(lane, state, FinishReason::Timeout));
            } else {
                r += 1;
            }
        }
    }

    /// Finish a request that never reached a decode lane (zero-gen
    /// completion, queue timeout, cancellation while queued).
    fn finish_queued(&mut self, qr: Queued, finish: FinishReason) -> FinishedRequest {
        let now = self.now();
        FinishedRequest {
            id: qr.id,
            tokens: Vec::new(),
            metrics: RequestMetrics {
                queue_wait_ms: (now - qr.submitted) * 1e3,
                ..RequestMetrics::empty()
            },
            finish,
            degraded: qr.degraded,
            precision: qr.precision,
        }
    }

    fn finish_with(
        &mut self,
        mut lane: Lane,
        state: DecodeState,
        finish: FinishReason,
    ) -> FinishedRequest {
        let kv_bytes = state.kv_bytes();
        // Donate the lane's page-aligned prompt-prefix pages to its OWN
        // precision's prefix index before releasing the state (release
        // pools only pages nobody else references, so donated pages stay
        // alive in the cache); a different-precision model's pages would
        // hold different values. Failed lanes don't donate — their
        // numerics are suspect by definition.
        if finish != FinishReason::Failed {
            if let Some(pi) = self.prefix_idx_mut(lane.precision) {
                pi.donate(&lane.prompt, state.pos, &state);
            }
        }
        self.arena.release(state);
        // When the shell is recycled, the result takes copies so the
        // shell keeps its buffers (and their capacity) for the next
        // admission, which must not allocate once warm; otherwise the
        // buffers just move out.
        let recycle = self.lane_pool.len() < LANE_POOL_MAX;
        let (tokens, token_ms) = if recycle {
            (lane.out.clone(), lane.token_ms.clone())
        } else {
            (std::mem::take(&mut lane.out), std::mem::take(&mut lane.token_ms))
        };
        let metrics = RequestMetrics {
            queue_wait_ms: (lane.admitted - lane.submitted) * 1e3,
            ttft_ms: (lane.first_token.unwrap_or(lane.admitted) - lane.submitted) * 1e3,
            p50_ms: percentile(&token_ms, 50.0),
            p99_ms: percentile(&token_ms, 99.0),
            kv_bytes,
            token_ms,
        };
        let fr = FinishedRequest {
            id: lane.id,
            tokens,
            metrics,
            finish,
            degraded: lane.degraded,
            precision: lane.precision,
        };
        if recycle {
            lane.out.clear();
            lane.token_ms.clear();
            self.lane_pool.push(lane);
        }
        fr
    }

    /// Recover from a panic inside [`Scheduler::admit_phase`]: requests
    /// caught mid-prefill are failed (their KV states go back to the
    /// arena) and the admission scratch is reset so the next step starts
    /// clean. In-flight decode lanes are untouched.
    pub fn recover_admission(&mut self) -> Vec<FinishedRequest> {
        // Lengths can differ if the panic hit between pushing a meta and
        // acquiring its state, so drain the two vectors independently.
        let metas = std::mem::take(&mut self.fresh_meta);
        let states = std::mem::take(&mut self.fresh_states);
        for state in states {
            self.arena.release(state);
        }
        metas
            .into_iter()
            .map(|qr| self.finish_queued(qr, FinishReason::Failed))
            .collect()
    }

    /// Fail every active lane ([`FinishReason::Failed`], partial tokens),
    /// releasing their KV pages. The supervisor's single-lane fault
    /// attribution path.
    pub fn fail_all_active(&mut self) -> Vec<FinishedRequest> {
        let mut finished = Vec::new();
        while let Some(lane) = self.lanes.pop() {
            let state = self.states.pop().expect("lanes/states parallel");
            finished.push(self.finish_with(lane, state, FinishReason::Failed));
        }
        finished
    }

    /// Ids of the currently active (decoding) lanes.
    pub fn lane_ids(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.id).collect()
    }

    /// `(id, precision)` of the currently active lanes — the supervisor's
    /// restart path snapshots these so requeued lanes replay at the
    /// precision they were serving at.
    pub fn lane_infos(&self) -> Vec<(u64, u8)> {
        self.lanes.iter().map(|l| (l.id, l.precision)).collect()
    }

    /// The id the next plain [`Scheduler::submit`] would take.
    pub fn next_request_id(&self) -> u64 {
        self.next_id
    }

    /// Raise the id floor (a restarted engine continues its predecessor's
    /// id sequence so ids never collide across restarts).
    pub fn set_next_id(&mut self, id: u64) {
        self.next_id = self.next_id.max(id);
    }

    /// Drain queue and lanes; finished requests are returned in submission
    /// (id) order.
    pub fn run_to_completion(&mut self) -> Vec<FinishedRequest> {
        let mut done = Vec::new();
        while self.has_work() {
            done.extend(self.step());
        }
        done.sort_by_key(|f| f.id);
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::model::ParamStore;
    use crate::util::Rng;

    fn model() -> NativeModel {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        NativeModel::from_params(&ps)
    }

    /// Scalar per-sequence greedy reference (the seed engine's loop).
    fn reference_decode(m: &NativeModel, prompt: &[u32], gen: usize) -> Vec<u32> {
        let mut state = m.new_state();
        let mut logits = vec![0.0f32; m.cfg.vocab];
        for &t in prompt {
            logits = m.step(&mut state, t);
        }
        let mut out = Vec::with_capacity(gen);
        for _ in 0..gen {
            let next = greedy_argmax(&logits);
            out.push(next);
            logits = m.step(&mut state, next);
        }
        out
    }

    #[test]
    fn continuous_batching_is_bit_identical_to_per_sequence() {
        let m = model();
        let mut rng = Rng::new(4);
        // Mixed lengths force mid-flight eviction + splicing: with
        // max_batch = 2 and 5 requests, lanes finish at different steps.
        let prompts: Vec<Vec<u32>> = (0..5)
            .map(|i| (0..(2 + i % 3)).map(|_| rng.below(m.cfg.vocab) as u32).collect())
            .collect();
        let gens = [6usize, 3, 9, 1, 5];

        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 16, ..ServeConfig::default() },
        );
        for (p, &g) in prompts.iter().zip(&gens) {
            sched.submit(p, g).unwrap();
        }
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 5);
        for (i, fr) in done.iter().enumerate() {
            assert_eq!(fr.id, i as u64);
            let want = reference_decode(&m, &prompts[i], gens[i]);
            assert_eq!(fr.tokens, want, "request {i} diverged from scalar decode");
        }
        assert!(sched.mean_occupancy() > 1.0, "batching never engaged");
        assert!(sched.pooled_kv() > 0, "finished lanes should refill the arena");
    }

    #[test]
    fn admission_control_and_validation() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 1, max_queued: 2, ..ServeConfig::default() },
        );
        assert!(sched.submit(&[], 4).is_err(), "empty prompt must be rejected");
        let big = m.cfg.vocab as u32;
        assert!(sched.submit(&[big], 4).is_err(), "out-of-vocab token must be rejected");
        sched.submit(&[1], 2).unwrap();
        sched.submit(&[2], 2).unwrap();
        assert!(sched.submit(&[3], 2).is_err(), "queue beyond max_queued must refuse");
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|f| f.tokens.len() == 2));
        assert!(done.iter().all(|f| f.metrics.queue_wait_ms >= 0.0));
        assert!(done.iter().all(|f| f.metrics.ttft_ms >= f.metrics.queue_wait_ms));
    }

    #[test]
    fn zero_gen_tokens_completes_without_decoding() {
        let m = model();
        let mut sched = Scheduler::new(&m, ServeConfig::default());
        sched.submit(&[5, 6], 0).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 1);
        assert!(done[0].tokens.is_empty());
        assert_eq!(done[0].metrics.p50_ms, 0.0);
        assert_eq!(done[0].metrics.kv_bytes, 0);
    }

    #[test]
    fn greedy_argmax_breaks_ties_like_max_by() {
        assert_eq!(greedy_argmax(&[0.0, 1.0, 1.0, 0.5]), 2);
        assert_eq!(greedy_argmax(&[3.0]), 0);
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_scalar_prefill() {
        // Mixed prompt lengths (1..=4) force lanes to drop out of the
        // prefill chunk at different depths; both prefill paths must yield
        // the exact same generations as the scalar reference.
        let m = model();
        let mut rng = Rng::new(17);
        let prompts: Vec<Vec<u32>> = (0..6)
            .map(|i| (0..(1 + i % 4)).map(|_| rng.below(m.cfg.vocab) as u32).collect())
            .collect();
        let gens = [4usize, 2, 5, 3, 1, 4];

        let run = |scalar_prefill: bool| -> Vec<Vec<u32>> {
            let cfg = ServeConfig {
                max_batch: 3,
                max_queued: 16,
                scalar_prefill,
                ..ServeConfig::default()
            };
            let mut sched = Scheduler::new(&m, cfg);
            for (p, &g) in prompts.iter().zip(&gens) {
                sched.submit(p, g).unwrap();
            }
            sched.run_to_completion().into_iter().map(|f| f.tokens).collect()
        };
        let chunked = run(false);
        let scalar = run(true);
        assert_eq!(chunked, scalar, "prefill paths diverged");
        for (i, (p, &g)) in prompts.iter().zip(&gens).enumerate() {
            assert_eq!(chunked[i], reference_decode(&m, p, g), "request {i}");
        }
    }

    #[test]
    fn step_tokens_streams_generations_incrementally() {
        // Tokens drained per step must reassemble exactly into each
        // request's final output, and must be available BEFORE completion.
        use std::collections::HashMap;
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        sched.submit(&[1, 2], 5).unwrap();
        sched.submit(&[3], 3).unwrap();
        sched.submit(&[7, 8, 9], 4).unwrap();
        let mut streamed: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut done = Vec::new();
        let mut saw_partial = false;
        while sched.has_work() {
            done.extend(sched.step());
            for &(id, tok) in sched.step_tokens() {
                streamed.entry(id).or_default().push(tok);
            }
            saw_partial |= !sched.step_tokens().is_empty() && done.is_empty();
        }
        assert!(saw_partial, "tokens must stream before any request completes");
        assert_eq!(done.len(), 3);
        for fr in &done {
            assert_eq!(streamed[&fr.id], fr.tokens, "request {}", fr.id);
        }
    }

    #[test]
    fn evicted_lane_pages_are_recycled_by_spliced_lanes() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 1, max_queued: 8, ..ServeConfig::default() },
        );
        sched.reserve_kv_pages(4);
        assert!(sched.pooled_kv_pages() >= 4);
        sched.submit(&[1, 2, 3], 3).unwrap();
        sched.submit(&[4, 5], 2).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 2);
        // Both lanes' pages ended back in the slab.
        assert!(sched.pooled_kv_pages() >= 4);
        assert_eq!(sched.pooled_kv(), 1, "single lane slot reuses one shell");
    }

    #[test]
    fn steady_state_step_makes_no_heap_allocations() {
        // Acceptance criterion: a warm decode step — attention, the
        // column-sharded matmuls, and scheduler bookkeeping — must not
        // touch the heap. The model is sized so every kernel stays below
        // its parallelism threshold: the probe counts allocations on the
        // calling thread, which then executes the whole step.
        use crate::cfg::ModelConfig;
        use crate::testing::alloc_count::count_allocs;
        let cfg = ModelConfig {
            name: "alloc-probe".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ff: 64,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        sched.submit(&[1, 2, 3], 64).unwrap();
        sched.submit(&[4, 5], 64).unwrap();
        // Warm-up: admission fills scratch, the first KV page per lane, and
        // grows the thread-local score buffer past the probe's horizon
        // (Vec doubling: 20 warm steps leave capacity 32 > 24 probed
        // positions; still within the first 64-position KV page).
        for _ in 0..20 {
            let fin = sched.step();
            assert!(fin.is_empty());
        }
        let ((), allocs) = count_allocs(|| {
            for _ in 0..3 {
                let fin = sched.step();
                debug_assert!(fin.is_empty());
            }
        });
        assert_eq!(allocs, 0, "steady-state decode step hit the heap {allocs} time(s)");
    }

    #[test]
    fn steady_state_sharded_decode_step_is_allocation_free() {
        // Acceptance criterion (PR 4): zero allocation must hold INCLUDING
        // the column-sharded path. The head product (2 lanes × 32 × 2048)
        // clears SHARD_MIN_WORK, so at any pool width > 1 the decode step
        // fans shards out through `run_indexed` — whose submission is
        // plain-data stubs into the pool's reusable queue. The probe counts
        // the submitting thread, which always participates in the scatter
        // and warms its own thread-local decode scratch deterministically.
        use crate::cfg::ModelConfig;
        use crate::testing::alloc_count::count_allocs;
        let cfg = ModelConfig {
            name: "alloc-probe-sharded".into(),
            vocab: 2048,
            d_model: 32,
            n_layers: 1,
            n_heads: 4,
            d_ff: 64,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        sched.submit(&[1, 2, 3], 64).unwrap();
        sched.submit(&[4, 5], 64).unwrap();
        for _ in 0..20 {
            let fin = sched.step();
            assert!(fin.is_empty());
        }
        let ((), allocs) = count_allocs(|| {
            for _ in 0..3 {
                let fin = sched.step();
                debug_assert!(fin.is_empty());
            }
        });
        assert_eq!(allocs, 0, "sharded decode step hit the heap {allocs} time(s)");
    }

    #[test]
    fn warm_chunked_prefill_step_is_allocation_free() {
        // Satellite (PR 4): after one wave warms the lane shells, arena
        // pages, prefill scratch, and queue capacity, admitting and
        // chunk-prefilling a second wave of the same shape must not touch
        // the heap — recycled shells, reused fresh-scratch, and the
        // insertion co-sort replace every per-admission allocation.
        use crate::cfg::ModelConfig;
        use crate::testing::alloc_count::count_allocs;
        let cfg = ModelConfig {
            name: "alloc-probe-prefill".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ff: 64,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        // Wave 1: warms everything (runs to completion, shells recycled).
        sched.submit(&[1, 2, 3], 4).unwrap();
        sched.submit(&[4, 5], 4).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 2);
        // Wave 2: same prompt shapes and generation lengths.
        sched.submit(&[6, 7, 8], 4).unwrap();
        sched.submit(&[9, 10], 4).unwrap();
        let ((), allocs) = count_allocs(|| {
            // One step = admission + chunked prefill + first decode step.
            let fin = sched.step();
            debug_assert!(fin.is_empty());
        });
        assert_eq!(allocs, 0, "warm chunked-prefill step hit the heap {allocs} time(s)");
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|f| f.tokens.len() == 4));
    }

    #[test]
    fn f16_kv_serving_halves_kv_bytes_and_matches_greedy() {
        // Same workload under f32 and f16 KV storage: greedy tokens must
        // match token-for-token (the serving exactness contract for the
        // tiny preset) and both byte gauges must halve exactly.
        use crate::cfg::KvDtype;
        let m = model();
        let run = |dtype: KvDtype| {
            let cfg = ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_dtype: dtype,
                ..ServeConfig::default()
            };
            let mut sched = Scheduler::new(&m, cfg);
            assert_eq!(sched.kv_dtype(), dtype);
            assert_eq!(sched.kv_bytes(), 0, "no lanes yet");
            sched.submit(&[1, 2, 3], 3).unwrap();
            sched.submit(&[4, 5], 3).unwrap();
            let mut done = Vec::new();
            let mut peak_live = 0usize;
            while sched.has_work() {
                done.extend(sched.step());
                peak_live = peak_live.max(sched.kv_bytes());
            }
            done.sort_by_key(|f| f.id);
            let tokens: Vec<Vec<u32>> = done.into_iter().map(|f| f.tokens).collect();
            (tokens, peak_live, sched.kv_allocated_bytes())
        };
        let (tok32, live32, alloc32) = run(KvDtype::F32);
        let (tok16, live16, alloc16) = run(KvDtype::F16);
        assert_eq!(tok16, tok32, "f16 KV diverged from f32 greedy tokens");
        assert!(live32 > 0 && alloc32 > 0);
        assert_eq!(live16 * 2, live32, "f16 KV must halve live bytes");
        assert_eq!(alloc16 * 2, alloc32, "f16 KV must halve allocated bytes");
    }

    #[test]
    fn scheduler_new_uses_config_worker_count() {
        let m = model();
        let s = Scheduler::new(&m, ServeConfig::default());
        assert_eq!(s.workers(), crate::tensor::ops::num_threads());
        let s = Scheduler::new(&m, ServeConfig { workers: 3, ..ServeConfig::default() });
        assert_eq!(s.workers(), 3);
    }

    #[test]
    fn greedy_argmax_survives_degenerate_logits() {
        // The seed's `partial_cmp().unwrap()` panicked on any NaN; total
        // order must instead pick deterministically. Positive NaN is the
        // top of the total order, ties keep the last index.
        assert_eq!(greedy_argmax(&[f32::NAN, f32::NAN, f32::NAN]), 2, "all-NaN: last wins");
        assert_eq!(greedy_argmax(&[0.0, f32::NAN, 3.0]), 1, "+NaN outranks finite");
        assert_eq!(greedy_argmax(&[f32::NAN, f32::INFINITY]), 0, "+NaN outranks +inf");
        assert_eq!(greedy_argmax(&[-f32::NAN, 1.0]), 1, "-NaN is the bottom");
        assert_eq!(greedy_argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn cancel_evicts_queued_and_active_requests() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 1, max_queued: 8, ..ServeConfig::default() },
        );
        let a = sched.submit(&[1, 2], 50).unwrap();
        let b = sched.submit(&[3, 4], 50).unwrap();
        // Two steps: `a` occupies the single lane, `b` waits queued.
        sched.step();
        sched.step();
        assert_eq!((sched.active(), sched.queued()), (1, 1));

        let fb = sched.cancel(b).expect("queued request is cancellable");
        assert_eq!(fb.finish, FinishReason::Cancelled);
        assert!(fb.tokens.is_empty(), "queued request never decoded");
        assert_eq!(sched.queued(), 0);

        let fa = sched.cancel(a).expect("active request is cancellable");
        assert_eq!(fa.finish, FinishReason::Cancelled);
        assert!(!fa.tokens.is_empty(), "active lane returns partial output");
        assert!(fa.tokens.len() < 50);
        assert_eq!(sched.active(), 0);
        assert!(sched.pooled_kv() > 0, "cancelled lane's KV returned to the arena");

        assert!(sched.cancel(a).is_none(), "double cancel is a no-op");
        assert!(sched.cancel(999).is_none(), "unknown id is a no-op");
    }

    #[test]
    fn request_deadline_returns_partial_output_as_timeout() {
        let m = model();
        let mut sched = Scheduler::new(&m, ServeConfig::default());
        let opts = SubmitOpts {
            timeout: Some(Duration::from_millis(30)),
            ..SubmitOpts::default()
        };
        sched.submit_opts(&[1, 2, 3], 1_000_000, opts).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut done = Vec::new();
        while sched.has_work() && Instant::now() < deadline {
            done.extend(sched.step());
        }
        assert_eq!(done.len(), 1, "request must expire, not decode 1M tokens");
        assert_eq!(done[0].finish, FinishReason::Timeout);
        assert!(done[0].tokens.len() < 1_000_000);
        assert_eq!(sched.active(), 0);
        assert!(sched.pooled_kv() > 0, "expired lane's KV returned to the arena");
    }

    #[test]
    fn queue_timeout_expires_waiting_requests() {
        let m = model();
        let cfg = ServeConfig {
            max_batch: 1,
            max_queued: 8,
            queue_timeout_ms: 20,
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&m, cfg);
        let a = sched.submit(&[1, 2], 1_000_000).unwrap(); // holds the lane
        let b = sched.submit(&[3, 4], 30).unwrap();
        // `b` cannot be admitted while `a` holds the only lane; after 20ms
        // of queue wait the sweep must expire it (queue_timeout only
        // gates *waiting* requests — `a`, admitted on the first step,
        // decodes on unaffected).
        let mut done = Vec::new();
        let safety = Instant::now() + Duration::from_secs(10);
        while !done.iter().any(|f| f.id == b) && Instant::now() < safety {
            done.extend(sched.step());
        }
        let fb = done.iter().find(|f| f.id == b).expect("queued request expired");
        assert_eq!(fb.finish, FinishReason::Timeout);
        assert!(fb.tokens.is_empty());
        assert!(fb.metrics.queue_wait_ms >= 20.0);
        let fa = sched.cancel(a).expect("lane holder still active");
        assert!(!fa.tokens.is_empty(), "lane holder kept decoding past the queue timeout");
    }

    #[test]
    fn nan_logits_fail_only_the_poisoned_lane() {
        let m = model();
        let mut rng = Rng::new(9);
        let p0: Vec<u32> = (0..3).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let p1: Vec<u32> = (0..2).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let want1 = reference_decode(&m, &p1, 8);

        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        let a = sched.submit(&p0, 8).unwrap();
        sched.submit(&p1, 8).unwrap();
        // Fire on the 3rd decode step: lane 0 (request `a`) gets NaN
        // logits and must leave as Failed with 2 tokens; its neighbor
        // decodes to completion bit-identically to the scalar reference.
        fault::arm(fault::NAN_LOGITS, 3);
        let done = sched.run_to_completion();
        fault::disarm_all();
        assert_eq!(done.len(), 2);
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert_eq!(fa.finish, FinishReason::Failed);
        assert_eq!(fa.tokens.len(), 2, "tokens before the poisoned step survive");
        let fb = done.iter().find(|f| f.id != a).unwrap();
        assert_eq!(fb.finish, FinishReason::Length);
        assert_eq!(fb.tokens, want1, "unpoisoned lane must stay bit-identical");
        assert_eq!(sched.active(), 0);
    }

    #[test]
    fn admission_recovery_fails_fresh_requests_and_keeps_lanes() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        let a = sched.submit(&[1, 2], 40).unwrap();
        sched.step(); // `a` holds a lane
        let b = sched.submit(&[3, 4, 5], 6).unwrap();
        fault::arm(fault::PREFILL_PANIC, 1);
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sched.admit_phase();
        }));
        fault::disarm_all();
        assert!(panicked.is_err(), "armed prefill fault must panic");
        // The panic landed with `b` sitting in the admission scratch;
        // recovery must fail it, release its KV state, and leave the
        // in-flight lane `a` untouched.
        let failed = sched.recover_admission();
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].id, b);
        assert_eq!(failed[0].finish, FinishReason::Failed);
        assert_eq!(sched.active(), 1, "in-flight lane survives admission recovery");
        assert_eq!(sched.queued(), 0);
        let done = sched.run_to_completion();
        assert!(done.iter().any(|f| f.id == a && f.finish == FinishReason::Length));
    }

    #[test]
    fn fail_all_active_releases_every_lane() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        sched.submit(&[1, 2], 40).unwrap();
        sched.submit(&[3], 40).unwrap();
        for _ in 0..3 {
            sched.step();
        }
        assert_eq!(sched.active(), 2);
        let failed = sched.fail_all_active();
        assert_eq!(failed.len(), 2);
        assert!(failed.iter().all(|f| f.finish == FinishReason::Failed));
        assert!(failed.iter().all(|f| !f.tokens.is_empty()), "partial output kept");
        assert_eq!(sched.active(), 0);
        assert_eq!(sched.pooled_kv(), 2, "both KV shells back in the arena");
    }

    #[test]
    fn explicit_id_resubmission_bypasses_queue_and_bumps_next_id() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 1, max_queued: 1, ..ServeConfig::default() },
        );
        sched.submit(&[1], 2).unwrap();
        assert!(sched.submit(&[2], 2).is_err(), "queue full for plain submits");
        // Requeue-after-restart path: explicit ids must be accepted even
        // past max_queued, and must push next_id forward.
        let opts = SubmitOpts { id: Some(7), ..SubmitOpts::default() };
        sched.submit_opts(&[3], 2, opts).unwrap();
        assert_eq!(sched.next_request_id(), 8);
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 2);
        assert!(done.iter().any(|f| f.id == 7));
    }

    #[test]
    fn retry_after_clamps_to_one_to_sixty_seconds() {
        assert_eq!(retry_after_secs(0), 1, "never tell a client to retry in 0s");
        assert_eq!(retry_after_secs(1), 1);
        assert_eq!(retry_after_secs(999), 1);
        assert_eq!(retry_after_secs(1000), 1);
        assert_eq!(retry_after_secs(1001), 2, "partial seconds round up");
        assert_eq!(retry_after_secs(59_000), 59);
        assert_eq!(retry_after_secs(60_000), 60);
        assert_eq!(retry_after_secs(10_000_000), 60, "clamped at a minute");
        assert_eq!(retry_after_secs(u64::MAX), 60);
    }

    #[test]
    fn kv_budget_defers_admission_and_stays_bit_identical() {
        let m = model();
        // Budget sized so one request fits under the high watermark but
        // two do not: the second waits queued until the first drains, and
        // total allocated bytes never exceed the budget.
        let probe = Scheduler::new(&m, ServeConfig::default());
        let cost = probe.kv_request_cost_bytes(4 + 8);
        let budget = (cost as f64 / KV_HIGH_WATERMARK * 1.2) as usize;
        let mut sched = Scheduler::new(
            &m,
            ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_budget_bytes: budget,
                ..ServeConfig::default()
            },
        );
        let p0 = vec![1u32, 2, 3, 4];
        let p1 = vec![5u32, 6, 7, 8];
        sched.submit(&p0, 8).unwrap();
        sched.submit(&p1, 8).unwrap();
        sched.step();
        assert_eq!((sched.active(), sched.queued()), (1, 1), "second must wait for budget");
        assert!(sched.kv_pressure() > 0.0);
        let mut peak = sched.kv_allocated_bytes();
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.step());
            peak = peak.max(sched.kv_allocated_bytes());
        }
        assert!(peak <= budget, "kv_allocated_bytes {peak} exceeded budget {budget}");
        done.sort_by_key(|f| f.id);
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|f| f.finish == FinishReason::Length && !f.degraded));
        assert_eq!(done[0].tokens, reference_decode(&m, &p0, 8));
        assert_eq!(done[1].tokens, reference_decode(&m, &p1, 8));
        assert_eq!(sched.kv_pressure(), 0.0, "drained engine holds no live KV");
    }

    #[test]
    fn brownout_clamps_gen_tokens_and_flags_degraded() {
        // Geometry: one layer, two heads of dim 8 → a 64-position KV chunk
        // is 8 KiB. Request A spans 230 positions (4 chunks, 32 KiB); the
        // budget puts that between the watermarks, so B's admission browns
        // out: its 100 requested tokens clamp to BROWNOUT_MAX_TOKENS and
        // its (clamped) one-chunk cost still fits under the high watermark
        // — unclamped, its two-chunk cost would have been refused.
        use crate::cfg::ModelConfig;
        let cfg = ModelConfig {
            name: "brownout-probe".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let probe = Scheduler::new(&m, ServeConfig::default());
        let p_a: Vec<u32> = (0..200).map(|i| (i % 60) as u32 + 1).collect();
        let p_b = vec![7u32, 9];
        let cost_a = probe.kv_request_cost_bytes(p_a.len() + 30);
        let clamped = probe.kv_request_cost_bytes(p_b.len() + BROWNOUT_MAX_TOKENS);
        let budget = ((cost_a + clamped) as f64 / KV_HIGH_WATERMARK).ceil() as usize + 1;
        assert!(
            (cost_a as f64) >= KV_LOW_WATERMARK * budget as f64,
            "geometry: A alone must trip the low watermark"
        );
        let mut sched = Scheduler::new(
            &m,
            ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_budget_bytes: budget,
                ..ServeConfig::default()
            },
        );
        let a = sched.submit(&p_a, 30).unwrap();
        sched.step();
        assert_eq!(sched.active(), 1);
        assert!(sched.kv_pressure() >= KV_LOW_WATERMARK, "A alone is a brownout");
        let b = sched.submit(&p_b, 100).unwrap();
        let mut peak = sched.kv_allocated_bytes();
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.step());
            peak = peak.max(sched.kv_allocated_bytes());
        }
        assert!(peak <= budget, "kv_allocated_bytes {peak} exceeded budget {budget}");
        assert_eq!(sched.brownouts(), 1);
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert!(fb.degraded, "browned-out admission must be flagged");
        assert_eq!(fb.finish, FinishReason::Length);
        assert_eq!(fb.tokens.len(), BROWNOUT_MAX_TOKENS);
        assert_eq!(
            fb.tokens,
            reference_decode(&m, &p_b, BROWNOUT_MAX_TOKENS),
            "degraded output must still be bit-identical up to the clamp"
        );
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert!(!fa.degraded, "A was admitted below the low watermark");
        assert_eq!(fa.tokens, reference_decode(&m, &p_a, 30));
    }

    #[test]
    fn preempt_youngest_drops_pages_and_requeues_bit_identically() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        assert!(sched.preempt_youngest().is_none(), "empty engine: nothing to preempt");
        let a = sched.submit(&[1, 2], 50).unwrap();
        sched.step();
        assert!(sched.preempt_youngest().is_none(), "never preempt the only lane");
        let b = sched.submit(&[3, 4], 50).unwrap();
        sched.step();
        assert_eq!(sched.active(), 2);
        let before = sched.kv_allocated_bytes();
        let (picked, picked_prec) =
            sched.preempt_youngest().expect("two lanes: youngest is preemptible");
        assert_eq!(picked, b, "most recently admitted lane goes first");
        assert_eq!(picked_prec, 0, "single-model engine serves the native label");
        assert_eq!((sched.active(), sched.preemptions()), (1, 1));
        assert!(
            sched.kv_allocated_bytes() < before,
            "preempted pages must deallocate, not return to the pool"
        );
        // Requeue under the original id — what the supervisor does — and
        // drain: the replayed request must be bit-identical from scratch.
        let opts = SubmitOpts { id: Some(picked), ..SubmitOpts::default() };
        sched.submit_opts(&[3, 4], 50, opts).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 2);
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert_eq!(fb.finish, FinishReason::Length);
        assert_eq!(fb.tokens, reference_decode(&m, &[3, 4], 50));
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert_eq!(fa.tokens, reference_decode(&m, &[1, 2], 50));
    }

    #[test]
    fn kv_submit_refusal_feasibility_and_fault_site() {
        let m = model();
        let probe = Scheduler::new(&m, ServeConfig::default());
        let budget = probe.kv_request_cost_bytes(4 + 8) * 10;
        let governed = Scheduler::new(
            &m,
            ServeConfig { kv_budget_bytes: budget, ..ServeConfig::default() },
        );
        assert!(
            governed.kv_submit_refused(4, 1_000_000),
            "a request that could never fit is refused up front"
        );
        assert!(!governed.kv_submit_refused(4, 8), "a feasible request is not");
        let open = Scheduler::new(&m, ServeConfig::default());
        assert!(!open.kv_submit_refused(4, 1_000_000), "no budget, no refusal");
        fault::arm(fault::KV_EXHAUST, 1);
        assert!(open.kv_submit_refused(4, 8), "armed kv-exhaust refuses regardless");
        assert!(!open.kv_submit_refused(4, 8), "fires exactly once");
        fault::disarm_all();
    }

    #[test]
    fn infeasible_direct_submit_fails_instead_of_wedging_the_queue() {
        let m = model();
        let probe = Scheduler::new(&m, ServeConfig::default());
        let budget = probe.kv_request_cost_bytes(4 + 8) * 2;
        let mut sched = Scheduler::new(
            &m,
            ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_budget_bytes: budget,
                ..ServeConfig::default()
            },
        );
        // HTTP refuses infeasible requests before they queue; a direct
        // scheduler user who sneaks one in must get Failed, not a queue
        // head that blocks every request behind it forever.
        let a = sched.submit(&[1, 2, 3, 4], 100_000).unwrap();
        let b = sched.submit(&[1, 2, 3, 4], 8).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 2);
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert_eq!(fa.finish, FinishReason::Failed);
        assert!(fa.tokens.is_empty());
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert_eq!(fb.finish, FinishReason::Length);
        assert_eq!(fb.tokens, reference_decode(&m, &[1, 2, 3, 4], 8));
    }

    #[test]
    fn prefix_hit_skips_prefill_and_stays_bit_identical() {
        // A 130-token prompt donates two page-aligned chunks on finish; a
        // resubmission maps 128 cached positions, a prompt diverging in
        // the second chunk maps 64 — and every generation must equal both
        // the scalar reference and a cache-off scheduler token-for-token.
        let m = model();
        let mut rng = Rng::new(23);
        let p: Vec<u32> = (0..130).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let mut divergent = p.clone();
        divergent[100] = (divergent[100] + 1) % m.cfg.vocab as u32;
        let run = |prefix_cache: bool| {
            let cfg = ServeConfig {
                max_batch: 2,
                max_queued: 8,
                prefix_cache,
                ..ServeConfig::default()
            };
            let mut sched = Scheduler::new(&m, cfg);
            sched.submit(&p, 6).unwrap();
            assert_eq!(sched.run_to_completion().len(), 1);
            sched.submit(&p, 6).unwrap();
            sched.submit(&divergent, 6).unwrap();
            let mut done = sched.run_to_completion();
            done.sort_by_key(|f| f.id);
            let toks: Vec<Vec<u32>> = done.into_iter().map(|f| f.tokens).collect();
            (toks, sched.prefix_hits(), sched.prefill_tokens_saved())
        };
        let (on, hits, saved) = run(true);
        let (off, off_hits, off_saved) = run(false);
        assert_eq!(on, off, "prefix cache changed greedy tokens");
        assert_eq!(on[0], reference_decode(&m, &p, 6));
        assert_eq!(on[1], reference_decode(&m, &p, 6));
        assert_eq!(on[2], reference_decode(&m, &divergent, 6));
        assert_eq!(hits, 2, "both warm submissions must hit");
        assert_eq!(saved, 128 + 64, "cached positions skip prefill");
        assert_eq!((off_hits, off_saved), (0, 0), "cache off records nothing");
    }

    #[test]
    fn f16_prefix_hits_stay_bit_identical() {
        // The on/off contract must hold for f16 KV pages too: a cached
        // chunk stores the same rounded values cold prefill would write,
        // so sharing cannot move a single bit.
        use crate::cfg::KvDtype;
        let m = model();
        let mut rng = Rng::new(31);
        let p: Vec<u32> = (0..70).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let run = |prefix_cache: bool| {
            let cfg = ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_dtype: KvDtype::F16,
                prefix_cache,
                ..ServeConfig::default()
            };
            let mut sched = Scheduler::new(&m, cfg);
            sched.submit(&p, 5).unwrap();
            assert_eq!(sched.run_to_completion().len(), 1);
            sched.submit(&p, 5).unwrap();
            let done = sched.run_to_completion();
            (done[0].tokens.clone(), sched.prefix_hits())
        };
        let (on, hits) = run(true);
        let (off, _) = run(false);
        assert_eq!(on, off, "f16 prefix hit diverged from cold prefill");
        assert_eq!(hits, 1);
    }

    #[test]
    fn warm_decode_over_shared_prefix_is_allocation_free() {
        // Tentpole acceptance: prefix hits resume page-aligned, so a
        // borrowing lane's first append opens a FRESH page — never a COW
        // fork — and the zero-allocation steady state survives sharing.
        use crate::cfg::ModelConfig;
        use crate::testing::alloc_count::count_allocs;
        let cfg = ModelConfig {
            name: "alloc-probe-prefix".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ff: 64,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        let p: Vec<u32> = (0..65).map(|i| (i % 60) as u32 + 1).collect();
        sched.submit(&p, 4).unwrap();
        assert_eq!(sched.run_to_completion().len(), 1);
        assert!(sched.prefix_cached_pages() > 0, "finished lane must donate");
        // Two lanes borrow the donated 64-position chunk; warm-up opens
        // their fresh tail pages and grows scratch past the probe horizon.
        sched.submit(&p, 64).unwrap();
        sched.submit(&p, 64).unwrap();
        for _ in 0..20 {
            let fin = sched.step();
            assert!(fin.is_empty());
        }
        assert_eq!(sched.prefix_hits(), 2);
        let ((), allocs) = count_allocs(|| {
            for _ in 0..3 {
                let fin = sched.step();
                debug_assert!(fin.is_empty());
            }
        });
        assert_eq!(allocs, 0, "shared-prefix decode step hit the heap {allocs} time(s)");
    }

    #[test]
    fn shared_prefix_pages_are_charged_once_against_the_budget() {
        // Geometry (1 layer × 2 heads of dim 8): a 64-position chunk is 4
        // pages = 8 KiB. A spans 65+8 = 73 positions → 16 KiB cost; with a
        // 20 KB budget (high watermark 18 KB) it admits alone and donates
        // one chunk. B shares the prompt: undiscounted, cache (8 KiB) +
        // cost (16 KiB) would cross the watermark — the 64 cached
        // positions discount B to 8 KiB, so it must admit immediately,
        // keep the cache intact, and never break the budget invariant.
        use crate::cfg::ModelConfig;
        let cfg = ModelConfig {
            name: "charge-once-probe".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let p: Vec<u32> = (0..65).map(|i| (i % 60) as u32 + 1).collect();
        let budget = 20_000;
        let mut sched = Scheduler::new(
            &m,
            ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_budget_bytes: budget,
                ..ServeConfig::default()
            },
        );
        sched.submit(&p, 8).unwrap();
        assert_eq!(sched.run_to_completion().len(), 1);
        assert_eq!(sched.prefix_cached_pages(), 4, "one 64-position chunk donated");
        assert!(!sched.kv_submit_refused_for(&p, 8, None), "discounted request is feasible");
        sched.submit(&p, 8).unwrap();
        sched.step();
        assert_eq!((sched.active(), sched.queued()), (1, 0), "B must admit immediately");
        let mut peak = sched.kv_allocated_bytes();
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.step());
            peak = peak.max(sched.kv_allocated_bytes());
        }
        assert!(peak <= budget, "kv_allocated_bytes {peak} exceeded budget {budget}");
        assert_eq!(sched.brownouts(), 0, "cache pressure must not brown out B");
        assert_eq!(sched.prefix_hits(), 1);
        assert_eq!(sched.prefix_cached_pages(), 4, "hit admission must not shed the cache");
        assert_eq!(done.len(), 1);
        assert!(!done[0].degraded);
        assert_eq!(done[0].tokens, reference_decode(&m, &p, 8));
    }

    #[test]
    fn cached_prefixes_shed_before_brownout() {
        // A 256-token donor leaves 16 cached pages (32 KiB) — above the
        // 70% low watermark of a 46 KB budget on its own. The next,
        // unrelated admission must trim the cache back under the
        // watermark and admit ungoverned: cached pages nobody references
        // are shed before any request is degraded.
        use crate::cfg::ModelConfig;
        let cfg = ModelConfig {
            name: "shed-probe".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let p: Vec<u32> = (0..256).map(|i| (i % 60) as u32 + 1).collect();
        let budget = 46_000;
        let mut sched = Scheduler::new(
            &m,
            ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_budget_bytes: budget,
                ..ServeConfig::default()
            },
        );
        sched.submit(&p, 1).unwrap();
        assert_eq!(sched.run_to_completion().len(), 1);
        assert_eq!(sched.prefix_cached_pages(), 16, "four chunks donated");
        assert!(sched.kv_pressure() > KV_LOW_WATERMARK, "cache alone trips the watermark");
        let b = sched.submit(&[7, 9], 8).unwrap();
        let mut peak = sched.kv_allocated_bytes();
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.step());
            peak = peak.max(sched.kv_allocated_bytes());
        }
        assert!(peak <= budget, "kv_allocated_bytes {peak} exceeded budget {budget}");
        assert_eq!(sched.brownouts(), 0, "sheddable cache must never cause a brownout");
        assert!(sched.prefix_cached_pages() < 16, "admission must have shed cache");
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert!(!fb.degraded);
        assert_eq!(fb.finish, FinishReason::Length);
        assert_eq!(fb.tokens, reference_decode(&m, &[7, 9], 8));
    }

    #[test]
    fn cached_prefixes_shed_before_refusing_admission() {
        // Cache from a 200-token donor (24 KiB) sits BELOW the low
        // watermark of a 40 KB budget, so the wholesale shed stays idle —
        // but a 16 KiB request on top would cross the high watermark and,
        // alone in an empty engine, be failed outright. Rung 0 must also
        // run at request grain: evict just enough cached pages to fit the
        // request instead of refusing it.
        use crate::cfg::ModelConfig;
        let cfg = ModelConfig {
            name: "shed-fit-probe".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let donor: Vec<u32> = (0..200).map(|i| (i % 60) as u32 + 1).collect();
        let other: Vec<u32> = (0..65).map(|i| (i % 50) as u32 + 2).collect();
        let budget = 40_000;
        let mut sched = Scheduler::new(
            &m,
            ServeConfig {
                max_batch: 2,
                max_queued: 8,
                kv_budget_bytes: budget,
                ..ServeConfig::default()
            },
        );
        sched.submit(&donor, 8).unwrap();
        assert_eq!(sched.run_to_completion().len(), 1);
        assert_eq!(sched.prefix_cached_pages(), 12, "three chunks donated");
        assert!(sched.kv_pressure() < KV_LOW_WATERMARK, "below the wholesale-shed bar");
        let b = sched.submit(&other, 8).unwrap();
        let mut peak = sched.kv_allocated_bytes();
        let mut done = sched.step();
        peak = peak.max(sched.kv_allocated_bytes());
        assert_eq!((sched.active(), sched.queued()), (1, 0), "shed must rescue the admission");
        assert_eq!(sched.prefix_cached_pages(), 8, "one donor chunk evicted to make room");
        while sched.has_work() {
            done.extend(sched.step());
            peak = peak.max(sched.kv_allocated_bytes());
        }
        assert!(peak <= budget, "kv_allocated_bytes {peak} exceeded budget {budget}");
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert_eq!(fb.finish, FinishReason::Length, "a refusal would read Failed here");
        assert_eq!(fb.tokens, reference_decode(&m, &other, 8));
        assert_eq!(sched.brownouts(), 0);
    }

    #[test]
    fn prefix_evict_fault_drops_cache_but_lanes_decode_on() {
        // Chaos: the prefix-evict site force-clears the index while a
        // dependent lane is mid-decode. The lane's own page refs keep the
        // shared storage alive — generation must stay bit-identical, and
        // the finished lane re-donates into the emptied index.
        let m = model();
        let mut rng = Rng::new(41);
        let p: Vec<u32> = (0..130).map(|_| rng.below(m.cfg.vocab) as u32).collect();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() },
        );
        sched.submit(&p, 4).unwrap();
        assert_eq!(sched.run_to_completion().len(), 1);
        assert!(sched.prefix_cached_pages() > 0);
        fault::arm(fault::PREFIX_EVICT, 1);
        sched.submit(&p, 6).unwrap();
        // One step: admission maps the 128 cached positions, then the
        // armed fault clears the whole index mid-decode.
        sched.step();
        fault::disarm_all();
        assert_eq!(sched.prefix_cached_pages(), 0, "fault must empty the cache");
        assert_eq!(sched.prefix_hits(), 1);
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Length);
        assert_eq!(
            done[0].tokens,
            reference_decode(&m, &p, 6),
            "borrowed pages must survive forced eviction"
        );
        assert!(sched.prefix_cached_pages() > 0, "finished lane re-donates");
    }

    #[test]
    fn kv_prewarm_clamps_to_the_budget() {
        let m = model();
        let mut open = Scheduler::new(&m, ServeConfig::default());
        open.reserve_kv_pages(8);
        assert!(open.pooled_kv_pages() >= 8, "ungoverned pre-warm honors the request");
        let budget = 256 * 1024;
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { kv_budget_bytes: budget, ..ServeConfig::default() },
        );
        sched.reserve_kv_pages(1_000_000);
        assert!(
            sched.kv_allocated_bytes() <= budget,
            "pre-warm must clamp to the KV budget ceiling"
        );
        assert!(sched.pooled_kv_pages() > 0, "clamp still pre-warms up to the ceiling");
    }

    #[test]
    fn predicted_wait_follows_measured_drain_rate() {
        let m = model();
        let mut sched = Scheduler::new(
            &m,
            ServeConfig { max_batch: 1, max_queued: 16, ..ServeConfig::default() },
        );
        assert_eq!(sched.predicted_wait_ms(), 0, "no measurements, no queue, no wait");
        for i in 0..4u32 {
            sched.submit(&[1 + i], 40).unwrap();
        }
        sched.step();
        // One lane active, three queued, step time measured: prediction
        // must be positive and can only shrink as the queue shallows.
        let deep = sched.predicted_wait_ms();
        assert!(deep > 0, "measured steps + queued work must predict a wait");
        sched.cancel(2).unwrap();
        sched.cancel(3).unwrap();
        let shallow = sched.predicted_wait_ms();
        assert!(shallow <= deep, "a shallower queue cannot predict a longer wait");
        sched.run_to_completion();
        assert_eq!(sched.predicted_wait_ms(), 0, "empty queue predicts no wait");
    }

    /// Two same-shape models under different bank labels. Their weights
    /// differ (seeds 0 and 1), so a lane's token stream proves WHICH
    /// model served it — the strongest possible precision-routing check.
    fn bank_pair() -> (NativeModel, NativeModel) {
        let (cfg, _) = preset("tiny");
        let m4 = NativeModel::from_params(&ParamStore::init(&cfg, &mut Rng::new(0)));
        let m2 = NativeModel::from_params(&ParamStore::init(&cfg, &mut Rng::new(1)));
        (m2, m4)
    }

    #[test]
    fn mixed_precision_lanes_decode_bit_identically() {
        let (m2, m4) = bank_pair();
        let cfg = ServeConfig { max_batch: 3, max_queued: 8, ..ServeConfig::default() };
        let mut sched = Scheduler::with_bank(vec![(4, &m4), (2, &m2)], cfg, 4, 0);
        assert_eq!(sched.precisions(), vec![2, 4], "bank sorts ascending");
        assert_eq!((sched.default_precision(), sched.floor_precision()), (4, 0));
        let bad = SubmitOpts { precision: Some(3), ..SubmitOpts::default() };
        assert!(
            sched.submit_opts(&[1], 4, bad).is_err(),
            "a precision outside the bank is rejected at submit"
        );
        let a = sched.submit(&[1, 2, 3], 20).unwrap();
        let two = SubmitOpts { precision: Some(2), ..SubmitOpts::default() };
        let b = sched.submit_opts(&[4, 5], 24, two).unwrap();
        let c = sched.submit_opts(&[1, 2, 3], 20, two).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 3);
        let f = |id: u64| done.iter().find(|f| f.id == id).unwrap();
        assert_eq!((f(a).precision, f(b).precision, f(c).precision), (4, 2, 2));
        assert_eq!(f(a).tokens, reference_decode(&m4, &[1, 2, 3], 20), "default lane → label 4");
        assert_eq!(f(b).tokens, reference_decode(&m2, &[4, 5], 24), "explicit label 2 honored");
        assert_eq!(
            f(c).tokens,
            reference_decode(&m2, &[1, 2, 3], 20),
            "same prompt at the other precision follows the other model"
        );
        assert_ne!(f(a).tokens, f(c).tokens, "geometry: the two bank models must disagree");
        assert_eq!(sched.precision_downshifts(), 0, "no pressure, no downshift");
    }

    /// Brownout-probe pressure geometry over a two-label bank: request A
    /// parks live KV between the watermarks, so B's admission happens
    /// under pressure. Returns `(m2, m4, serve_cfg, p_a, p_b)`; B asks
    /// for [`PRESSURE_GEN_B`] tokens — more than the brownout clamp, but
    /// within the same KV chunk, so the downshifted (unclamped) cost
    /// equals the clamped cost and the budget arithmetic of
    /// `brownout_clamps_gen_tokens_and_flags_degraded` carries over.
    const PRESSURE_GEN_B: usize = 40;
    fn pressure_bank() -> (NativeModel, NativeModel, ServeConfig, Vec<u32>, Vec<u32>) {
        use crate::cfg::ModelConfig;
        let cfg = ModelConfig {
            name: "downshift-probe".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            rope_theta: 10000.0,
        };
        let m4 = NativeModel::from_params(&ParamStore::init(&cfg, &mut Rng::new(0)));
        let m2 = NativeModel::from_params(&ParamStore::init(&cfg, &mut Rng::new(1)));
        let p_a: Vec<u32> = (0..200).map(|i| (i % 60) as u32 + 1).collect();
        let p_b = vec![7u32, 9];
        let probe = Scheduler::new(&m4, ServeConfig::default());
        let cost_a = probe.kv_request_cost_bytes(p_a.len() + 30);
        let cost_b = probe.kv_request_cost_bytes(p_b.len() + PRESSURE_GEN_B);
        assert!(PRESSURE_GEN_B > BROWNOUT_MAX_TOKENS);
        assert_eq!(
            cost_b,
            probe.kv_request_cost_bytes(p_b.len() + BROWNOUT_MAX_TOKENS),
            "geometry: clamped and unclamped B must cost the same chunk"
        );
        let budget = ((cost_a + cost_b) as f64 / KV_HIGH_WATERMARK).ceil() as usize + 1;
        assert!(
            (cost_a as f64) >= KV_LOW_WATERMARK * budget as f64,
            "geometry: A alone must trip the low watermark"
        );
        let serve = ServeConfig {
            max_batch: 2,
            max_queued: 8,
            kv_budget_bytes: budget,
            ..ServeConfig::default()
        };
        (m2, m4, serve, p_a, p_b)
    }

    #[test]
    fn pressure_downshifts_admissions_to_the_floor_precision() {
        let (m2, m4, serve, p_a, p_b) = pressure_bank();
        let budget = serve.kv_budget_bytes;
        let mut sched = Scheduler::with_bank(vec![(2, &m2), (4, &m4)], serve, 4, 2);
        let a = sched.submit(&p_a, 30).unwrap();
        sched.step();
        assert!(sched.kv_pressure() >= KV_LOW_WATERMARK, "A alone trips the low watermark");
        let b = sched.submit(&p_b, PRESSURE_GEN_B).unwrap();
        let mut peak = sched.kv_allocated_bytes();
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.step());
            peak = peak.max(sched.kv_allocated_bytes());
        }
        assert!(peak <= budget, "kv_allocated_bytes {peak} exceeded budget {budget}");
        assert_eq!(
            (sched.precision_downshifts(), sched.brownouts()),
            (1, 0),
            "the downshift rung must fire INSTEAD of a brownout"
        );
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert!(!fb.degraded, "downshifted admissions are not degraded");
        assert_eq!(fb.precision, 2, "B was served at the floor");
        assert_eq!(fb.finish, FinishReason::Length);
        assert_eq!(fb.tokens.len(), PRESSURE_GEN_B, "full token budget, no clamp");
        assert_eq!(
            fb.tokens,
            reference_decode(&m2, &p_b, PRESSURE_GEN_B),
            "B must have decoded through the floor model end to end"
        );
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert!(!fa.degraded && fa.precision == 4, "A stays on the default label");
        assert_eq!(fa.tokens, reference_decode(&m4, &p_a, 30));
    }

    #[test]
    fn pinned_precision_rides_out_pressure_with_a_brownout_clamp() {
        // Same pressure geometry, but B *explicitly* asks for label 4:
        // per-request precision is honored — the downshift rung skips
        // pinned admissions, so the next rung (the brownout clamp)
        // applies instead.
        let (m2, m4, serve, p_a, p_b) = pressure_bank();
        let mut sched = Scheduler::with_bank(vec![(2, &m2), (4, &m4)], serve, 4, 2);
        let a = sched.submit(&p_a, 30).unwrap();
        sched.step();
        let four = SubmitOpts { precision: Some(4), ..SubmitOpts::default() };
        let b = sched.submit_opts(&p_b, PRESSURE_GEN_B, four).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(
            (sched.precision_downshifts(), sched.brownouts()),
            (0, 1),
            "a pinned admission browns out instead of downshifting"
        );
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert!(fb.degraded && fb.precision == 4);
        assert_eq!(fb.tokens.len(), BROWNOUT_MAX_TOKENS);
        assert_eq!(fb.tokens, reference_decode(&m4, &p_b, BROWNOUT_MAX_TOKENS));
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert_eq!(fa.tokens, reference_decode(&m4, &p_a, 30));
    }

    #[test]
    fn prefix_caches_are_isolated_per_precision() {
        // KV pages decoded by different-precision models hold different
        // values: a warm prefix under one label must never be mapped into
        // a lane decoding under another, and every lane's stream must
        // stay bit-identical to its own model's scalar reference.
        let (m2, m4) = bank_pair();
        let mut rng = Rng::new(23);
        let p: Vec<u32> = (0..130).map(|_| rng.below(m4.cfg.vocab) as u32).collect();
        let cfg = ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() };
        let mut sched = Scheduler::with_bank(vec![(2, &m2), (4, &m4)], cfg, 4, 0);
        let two = SubmitOpts { precision: Some(2), ..SubmitOpts::default() };
        // Warm label 4's cache.
        let a = sched.submit(&p, 6).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(done.iter().find(|f| f.id == a).unwrap().tokens, reference_decode(&m4, &p, 6));
        assert!(sched.prefix_cached_pages() > 0, "finished lane donated its prefix");
        // The same prompt at label 2 must MISS label 4's entry.
        let b = sched.submit_opts(&p, 6, two).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(sched.prefix_hits(), 0, "no cross-precision prefix reuse");
        assert_eq!(done.iter().find(|f| f.id == b).unwrap().tokens, reference_decode(&m2, &p, 6));
        // Each label now re-hits its OWN warm entry, bit-identically.
        let c = sched.submit(&p, 6).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(sched.prefix_hits(), 1, "label 4 hits its own entry");
        assert_eq!(done.iter().find(|f| f.id == c).unwrap().tokens, reference_decode(&m4, &p, 6));
        let d = sched.submit_opts(&p, 6, two).unwrap();
        let done = sched.run_to_completion();
        assert_eq!(sched.prefix_hits(), 2, "label 2 hits its own entry");
        assert_eq!(done.iter().find(|f| f.id == d).unwrap().tokens, reference_decode(&m2, &p, 6));
    }
}
