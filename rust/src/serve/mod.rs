//! Continuous-batching serving engine over quantized models — the Table 2
//! measurement rig, grown into a request-scheduler architecture.
//!
//! Layers, bottom-up:
//!
//! * **Batched kernels** — every serving format ([`quant::formats`])
//!   plugs into the shared tiled GEMM engine (`tensor::gemm`): each
//!   `[tile × window]` block of weights (packed codes, LUT gather, VQ
//!   centroids, checkpointed trellis state walk) is decoded ONCE per
//!   engine step into thread-local f32 scratch and applied to all batch
//!   lanes by a register-blocked micro-kernel; the `matmul_col_sharded`
//!   driver splits the output channels across the persistent worker pool
//!   as in-place column windows (bit-exact at any tile height, shard
//!   count, and thread count; `GQ_TILE=0` falls back to the row-at-a-time
//!   kernels). This is the paper's amortized-decode story: per-sequence
//!   decode re-pays the dequant cost for every token of every sequence,
//!   batched decode pays it once per tile.
//! * **Batched model step** — `NativeModel::step_batch` advances a slab of
//!   per-sequence `DecodeState`s (KV caches pooled in a `KvArena`) with
//!   per-lane arithmetic bit-identical to the scalar `step`.
//! * **[`scheduler::Scheduler`]** — admission queue (`max_queued`
//!   back-pressure), continuous batching up to `max_batch` lanes (finished
//!   sequences evicted mid-flight — their KV pages return to the arena
//!   slab — and queued requests spliced in at the next step), per-request
//!   metrics (queue wait, time-to-first-token, per-token latency
//!   percentiles), and a streaming drain (`step_tokens`) exposing every
//!   step's tokens as they are generated. With `kv_budget_bytes` set,
//!   admission becomes cost-aware memory governance: worst-case KV page
//!   cost gates admission under watermarks; under pressure, un-pinned
//!   admissions first downshift to the floor decode precision (full
//!   output, milder than any clamp), then brownouts clamp `max_tokens`,
//!   and the measured drain rate feeds honest
//!   `Retry-After`/predicted-wait backpressure. The [`prefix`] index
//!   shares page-aligned prompt-prefix KV pages across requests
//!   (copy-on-write; prefix hits skip their prefill compute), with
//!   cached-unreferenced pages the first thing trimmed under pressure.
//! * **[`supervisor::SupervisedEngine`]** — fault isolation around the
//!   scheduler: each step phase runs under `catch_unwind`, panics are
//!   attributed (admission fault → fail the mid-prefill batch; single-lane
//!   decode fault → fail that request; unattributable fault → engine
//!   restart with a requeue-or-fail-fast policy), restarts are budgeted,
//!   and per-request deadlines/cancellation evict lanes through the
//!   splicing path so KV pages always return to the arena. Under KV
//!   pressure the supervisor preempts the youngest lane through the same
//!   requeue machinery (pages deallocated, tokens replay-suppressed)
//!   before anything is shed. Chaos scenarios are driven by the
//!   deterministic `util::fault` injection sites.
//! * **[`engine`]** — `generate_batch` (compatibility wrapper over the
//!   scheduler, bit-identical greedy outputs), `generate_scheduled` (with
//!   explicit knobs), and `generate_per_sequence` (the original
//!   thread-per-sequence baseline, kept for benchmarking and regression).
//! * **[`http`]** — the network front-end (`gq serve --http <addr>`): a
//!   dependency-free HTTP/1.1 server whose connection threads feed a single
//!   scheduler-owning engine thread over an mpsc channel. `POST
//!   /v1/completions` serves blocking and SSE-streamed completions (greedy
//!   tokens bit-identical to `generate_scheduled`) at a per-request
//!   `"precision"`, `GET /v1/capabilities` reports the loaded format and
//!   the supported precision set, `GET /metrics` exposes queue depth and
//!   TTFT/per-token percentiles, `GET /healthz` is the liveness probe.
//!   Admission control maps to HTTP status codes: a full `max_queued`
//!   queue answers 429, malformed bodies 400 — all errors in a structured
//!   v1 envelope (legacy plain-string bodies behind an `Accept`
//!   fallback) — and graceful shutdown drains every in-flight lane before
//!   the threads join. CI's `serve-e2e` job exercises all of this against
//!   the release binary.
//! * **[`builder`]** — quantizes a checkpoint into any serving format;
//!   [`builder::ModelSet`] is the unit a server binds: one model per
//!   served precision (the `anyprec` format's entries share one bit-plane
//!   artifact, so 2/3/4-bit views cost one quantized model's storage).

pub mod builder;
pub mod engine;
pub mod http;
pub(crate) mod prefix;
pub mod scheduler;
pub mod supervisor;

pub use builder::{build_serving_model, build_serving_set, ModelSet, ServeFormat};
pub use engine::{
    generate_batch, generate_per_sequence, generate_scheduled, generate_scheduled_streaming,
    random_prompts, ServeStats,
};
pub use http::HttpServer;
pub use scheduler::{
    greedy_argmax, retry_after_secs, FinishReason, FinishedRequest, RequestMetrics, Scheduler,
    SubmitOpts, BROWNOUT_MAX_TOKENS, KV_HIGH_WATERMARK, KV_LOW_WATERMARK,
};
pub use supervisor::SupervisedEngine;
