//! Serving engine: batched token generation over quantized models with
//! format-specific fused dequant kernels — the Table 2 measurement rig.

pub mod builder;
pub mod engine;

pub use builder::{build_serving_model, ServeFormat};
pub use engine::{generate_batch, ServeStats};
