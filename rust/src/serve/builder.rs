//! Build a NativeModel whose seven per-block linears use a chosen serving
//! format. Embedding / norms / head stay fp32 (as in all the paper's
//! weight-only kernels).

use anyhow::{Context, Result};

use crate::fisher::CalibStats;
use crate::model::forward::{Block, LinearOp, NativeModel};
use crate::model::ParamStore;
use crate::quant::formats::{LutLinear, TrellisLinear, UniformScalarLinear, VqLinear};
use crate::quant::gptq::gptq_with_grid;
use crate::quant::gptvq::{gptvq_vq_quantize, GptvqVq};
use crate::quant::grid::UniformGrid;
use crate::quant::lnq::{lnq_quantize, Lnq};
use crate::quant::trellis::{trellis_quantize, Trellis};
use crate::tensor::Mat;

/// Table 2 rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeFormat {
    /// fp32 baseline ("Original" row; fp16 on the paper's GPUs).
    Fp32,
    /// Uniform scalar (LUT-GEMM analog).
    UniformScalar,
    /// Non-uniform scalar LUT (Any-Precision-LLM analog).
    NonUniformScalar,
    /// Vector quantization decode.
    Vector,
    /// QTIP-style trellis decode.
    Trellis,
}

impl ServeFormat {
    pub fn name(&self) -> &'static str {
        match self {
            ServeFormat::Fp32 => "fp32",
            ServeFormat::UniformScalar => "uniform",
            ServeFormat::NonUniformScalar => "nonuniform",
            ServeFormat::Vector => "vector",
            ServeFormat::Trellis => "trellis",
        }
    }
}

/// Quantize every linear of `ps` at `bits` for the given serving format and
/// assemble the serving model. `stats` supplies the layer Hessians (uses
/// identity-free RTN-style fits when absent).
pub fn build_serving_model(
    ps: &ParamStore,
    stats: Option<&CalibStats>,
    format: ServeFormat,
    bits: u32,
) -> Result<NativeModel> {
    let cfg = ps.cfg.clone();
    let make_linear = |name: &str| -> Result<Box<dyn LinearOp>> {
        let w = ps.get(name);
        let h = match stats.and_then(|s| s.layer(name)) {
            Some(ls) => ls.plain_hessian().clone(),
            None => Mat::eye(w.rows),
        };
        Ok(match format {
            ServeFormat::Fp32 => Box::new(w.clone()),
            ServeFormat::UniformScalar => {
                let grid = UniformGrid::fit(w, bits);
                let (_, codes) = gptq_with_grid(&h, w, &grid, 32)?;
                Box::new(UniformScalarLinear::new(&codes, &grid, w.rows, w.cols))
            }
            ServeFormat::NonUniformScalar => {
                let res = lnq_quantize(&h, w, &Lnq { t_iters: 1, ..Lnq::new(bits) })?;
                Box::new(LutLinear::new(
                    &res.codes.context("lnq codes")?,
                    res.codebooks.context("lnq codebooks")?,
                    bits,
                    w.rows,
                    w.cols,
                ))
            }
            ServeFormat::Vector => {
                let dim = 2usize;
                let res = gptvq_vq_quantize(&h, w, &GptvqVq::new(bits, dim))?;
                let cbs = res.codebooks.context("vq codebooks")?;
                let k = cbs.cols / dim;
                let code_bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
                Box::new(VqLinear::new(
                    &res.codes.context("vq codes")?,
                    cbs,
                    dim,
                    code_bits,
                    w.rows,
                    w.cols,
                ))
            }
            ServeFormat::Trellis => {
                let tcfg = Trellis::new(bits, crate::cfg::TrellisVariant::Hyb);
                let (_, codes, gen) = trellis_quantize(&h, w, &tcfg)?;
                Box::new(TrellisLinear::new(&codes, gen, tcfg, w.rows))
            }
        })
    };

    let blocks = (0..cfg.n_layers)
        .map(|l| {
            let p = format!("layers.{l}.");
            Ok(Block {
                attn_norm: ps.get(&format!("{p}attn_norm")).data.clone(),
                mlp_norm: ps.get(&format!("{p}mlp_norm")).data.clone(),
                wq: make_linear(&format!("{p}wq"))?,
                wk: make_linear(&format!("{p}wk"))?,
                wv: make_linear(&format!("{p}wv"))?,
                wo: make_linear(&format!("{p}wo"))?,
                wgate: make_linear(&format!("{p}wgate"))?,
                wup: make_linear(&format!("{p}wup"))?,
                wdown: make_linear(&format!("{p}wdown"))?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(NativeModel {
        tok_emb: ps.get("tok_emb").clone(),
        head: Box::new(ps.get("head").clone()),
        final_norm: ps.get("final_norm").data.clone(),
        cfg,
        blocks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::util::Rng;

    fn params() -> ParamStore {
        let (cfg, _) = preset("tiny");
        ParamStore::init(&cfg, &mut Rng::new(0))
    }

    #[test]
    fn all_formats_build_and_decode() {
        let ps = params();
        let toks = [1u32, 5, 9, 2];
        let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
        let fp_logits = fp.forward_sequence(&toks);
        for format in [
            ServeFormat::UniformScalar,
            ServeFormat::NonUniformScalar,
            ServeFormat::Vector,
            ServeFormat::Trellis,
        ] {
            let m = build_serving_model(&ps, None, format, 4).unwrap();
            let logits = m.forward_sequence(&toks);
            assert_eq!((logits.rows, logits.cols), (fp_logits.rows, fp_logits.cols));
            assert!(
                logits.data.iter().all(|v| v.is_finite()),
                "{format:?} produced non-finite logits"
            );
        }
    }

    #[test]
    fn quantized_formats_use_less_storage() {
        let ps = params();
        let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
        let q = build_serving_model(&ps, None, ServeFormat::UniformScalar, 2).unwrap();
        assert!(q.linear_storage_bytes() * 8 < fp.linear_storage_bytes());
    }

    #[test]
    fn four_bit_lut_model_tracks_fp_logits() {
        let ps = params();
        let toks = [3u32, 7];
        let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
        let q = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
        let a = fp.forward_sequence(&toks);
        let b = q.forward_sequence(&toks);
        // 4-bit LNQ on a tiny model: logits should correlate strongly.
        let mut dot = 0.0f64;
        let mut na = 0.0f64;
        let mut nb = 0.0f64;
        for (x, y) in a.data.iter().zip(&b.data) {
            dot += (*x as f64) * (*y as f64);
            na += (*x as f64).powi(2);
            nb += (*y as f64).powi(2);
        }
        let cos = dot / (na.sqrt() * nb.sqrt()).max(1e-12);
        assert!(cos > 0.95, "cosine {cos}");
    }
}
