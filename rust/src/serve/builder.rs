//! Build a NativeModel whose seven per-block linears use a chosen serving
//! format. Embedding / norms / head stay fp32 (as in all the paper's
//! weight-only kernels).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::fisher::CalibStats;
use crate::model::forward::{Block, LinearOp, NativeModel};
use crate::model::ParamStore;
use crate::quant::formats::{
    AnyPrecArtifact, AnyPrecisionLinear, LutLinear, TrellisLinear, UniformScalarLinear, VqLinear,
};
use crate::quant::gptq::gptq_with_grid;
use crate::quant::gptvq::{gptvq_vq_quantize, GptvqVq};
use crate::quant::grid::UniformGrid;
use crate::quant::lnq::{lnq_quantize, Lnq};
use crate::quant::trellis::{trellis_quantize, Trellis};
use crate::tensor::Mat;

/// Table 2 rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeFormat {
    /// fp32 baseline ("Original" row; fp16 on the paper's GPUs).
    Fp32,
    /// Uniform scalar (LUT-GEMM analog).
    UniformScalar,
    /// Non-uniform scalar LUT (Any-Precision-LLM analog).
    NonUniformScalar,
    /// Vector quantization decode.
    Vector,
    /// QTIP-style trellis decode.
    Trellis,
    /// Bit-plane non-uniform LUT (Any-Precision-LLM): one stored artifact
    /// serves every precision 2..=bits by reading a plane prefix.
    AnyPrecision,
}

impl ServeFormat {
    pub fn name(&self) -> &'static str {
        match self {
            ServeFormat::Fp32 => "fp32",
            ServeFormat::UniformScalar => "uniform",
            ServeFormat::NonUniformScalar => "nonuniform",
            ServeFormat::Vector => "vector",
            ServeFormat::Trellis => "trellis",
            ServeFormat::AnyPrecision => "anyprec",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fp32" => Self::Fp32,
            "uniform" => Self::UniformScalar,
            "nonuniform" => Self::NonUniformScalar,
            "vector" => Self::Vector,
            "trellis" => Self::Trellis,
            "anyprec" => Self::AnyPrecision,
            other => bail!(
                "unknown serve format `{other}` (expected fp32|uniform|nonuniform|vector|trellis|anyprec)"
            ),
        })
    }
}

/// Quantize every linear of `ps` at `bits` for the given serving format and
/// assemble the serving model. `stats` supplies the layer Hessians (uses
/// identity-free RTN-style fits when absent).
pub fn build_serving_model(
    ps: &ParamStore,
    stats: Option<&CalibStats>,
    format: ServeFormat,
    bits: u32,
) -> Result<NativeModel> {
    let cfg = ps.cfg.clone();
    let make_linear = |name: &str| -> Result<Box<dyn LinearOp>> {
        let w = ps.get(name);
        let h = match stats.and_then(|s| s.layer(name)) {
            Some(ls) => ls.plain_hessian().clone(),
            None => Mat::eye(w.rows),
        };
        Ok(match format {
            ServeFormat::Fp32 => Box::new(w.clone()),
            ServeFormat::UniformScalar => {
                let grid = UniformGrid::fit(w, bits);
                let (_, codes) = gptq_with_grid(&h, w, &grid, 32)?;
                Box::new(UniformScalarLinear::new(&codes, &grid, w.rows, w.cols))
            }
            ServeFormat::NonUniformScalar => {
                let res = lnq_quantize(&h, w, &Lnq { t_iters: 1, ..Lnq::new(bits) })?;
                Box::new(LutLinear::new(
                    &res.codes.context("lnq codes")?,
                    res.codebooks.context("lnq codebooks")?,
                    bits,
                    w.rows,
                    w.cols,
                ))
            }
            ServeFormat::Vector => {
                let dim = 2usize;
                let res = gptvq_vq_quantize(&h, w, &GptvqVq::new(bits, dim))?;
                let cbs = res.codebooks.context("vq codebooks")?;
                let k = cbs.cols / dim;
                let code_bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
                Box::new(VqLinear::new(
                    &res.codes.context("vq codes")?,
                    cbs,
                    dim,
                    code_bits,
                    w.rows,
                    w.cols,
                ))
            }
            ServeFormat::Trellis => {
                let tcfg = Trellis::new(bits, crate::cfg::TrellisVariant::Hyb);
                let (_, codes, gen) = trellis_quantize(&h, w, &tcfg)?;
                Box::new(TrellisLinear::new(&codes, gen, tcfg, w.rows))
            }
            ServeFormat::AnyPrecision => {
                // Full-precision view; `build_serving_set` is the
                // multi-precision entry point that shares artifacts.
                let res = lnq_quantize(&h, w, &Lnq { t_iters: 1, ..Lnq::new(bits) })?;
                Box::new(AnyPrecisionLinear::new(
                    &res.codes.context("lnq codes")?,
                    res.codebooks.context("lnq codebooks")?,
                    bits,
                    w.rows,
                    w.cols,
                ))
            }
        })
    };

    let blocks = (0..cfg.n_layers)
        .map(|l| {
            let p = format!("layers.{l}.");
            Ok(Block {
                attn_norm: ps.get(&format!("{p}attn_norm")).data.clone(),
                mlp_norm: ps.get(&format!("{p}mlp_norm")).data.clone(),
                wq: make_linear(&format!("{p}wq"))?,
                wk: make_linear(&format!("{p}wk"))?,
                wv: make_linear(&format!("{p}wv"))?,
                wo: make_linear(&format!("{p}wo"))?,
                wgate: make_linear(&format!("{p}wgate"))?,
                wup: make_linear(&format!("{p}wup"))?,
                wdown: make_linear(&format!("{p}wdown"))?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(NativeModel {
        tok_emb: ps.get("tok_emb").clone(),
        head: Box::new(ps.get("head").clone()),
        final_norm: ps.get("final_norm").data.clone(),
        cfg,
        blocks,
    })
}

/// The set of serving models one `gq serve` process exposes: one
/// `(precision, NativeModel)` entry per supported decode precision,
/// ascending. Fixed-precision formats have exactly one entry; the
/// `anyprec` format has one per precision 2..=bits, all of whose linears
/// share the SAME `Arc<AnyPrecArtifact>` weight storage — the set costs
/// one artifact plus per-view structs, not N quantized models.
pub struct ModelSet {
    format: ServeFormat,
    models: Vec<(u8, NativeModel)>,
}

impl ModelSet {
    /// Wrap a single fixed-precision model (also used by tests that need
    /// a set without running a quantizer).
    pub fn single(format: ServeFormat, precision: u8, model: NativeModel) -> Self {
        ModelSet { format, models: vec![(precision, model)] }
    }

    pub fn format(&self) -> ServeFormat {
        self.format
    }

    /// Supported precisions, ascending; the last is the native one.
    pub fn precisions(&self) -> Vec<u8> {
        self.models.iter().map(|(p, _)| *p).collect()
    }

    pub fn supports(&self, prec: u8) -> bool {
        self.models.iter().any(|(p, _)| *p == prec)
    }

    pub fn get(&self, prec: u8) -> Option<&NativeModel> {
        self.models.iter().find(|(p, _)| *p == prec).map(|(_, m)| m)
    }

    /// The highest (native) precision in the set.
    pub fn native_precision(&self) -> u8 {
        self.models.last().expect("ModelSet is never empty").0
    }

    /// The native-precision model — the default when no precision is
    /// requested and the benchmark-mode model.
    pub fn native_model(&self) -> &NativeModel {
        &self.models.last().expect("ModelSet is never empty").1
    }

    /// Borrowed `(precision, model)` bank for `Scheduler::with_bank`.
    pub fn bank(&self) -> Vec<(u8, &NativeModel)> {
        self.models.iter().map(|(p, m)| (*p, m)).collect()
    }

    /// Resolve a configured precision knob (0 = native) against the set.
    pub fn resolve(&self, prec: u8) -> Result<u8> {
        if prec == 0 {
            return Ok(self.native_precision());
        }
        if !self.supports(prec) {
            bail!(
                "precision {prec} not served by format `{}` (supported: {:?})",
                self.format.name(),
                self.precisions()
            );
        }
        Ok(prec)
    }
}

/// Build the full serving set for a format. Fixed-precision formats wrap
/// `build_serving_model` in a one-entry set; `anyprec` quantizes each
/// linear ONCE, wraps the codes in a shared bit-plane artifact, and
/// assembles one model per precision 2..=bits whose views alias it.
pub fn build_serving_set(
    ps: &ParamStore,
    stats: Option<&CalibStats>,
    format: ServeFormat,
    bits: u32,
) -> Result<ModelSet> {
    if format != ServeFormat::AnyPrecision {
        let prec = if format == ServeFormat::Fp32 { 32 } else { bits as u8 };
        let model = build_serving_model(ps, stats, format, bits)?;
        return Ok(ModelSet::single(format, prec, model));
    }
    if !(2..=8).contains(&bits) {
        bail!("anyprec serving needs bits in 2..=8, got {bits}");
    }
    let cfg = ps.cfg.clone();
    let quantize = |name: &str| -> Result<Arc<AnyPrecArtifact>> {
        let w = ps.get(name);
        let h = match stats.and_then(|s| s.layer(name)) {
            Some(ls) => ls.plain_hessian().clone(),
            None => Mat::eye(w.rows),
        };
        let res = lnq_quantize(&h, w, &Lnq { t_iters: 1, ..Lnq::new(bits) })?;
        let cbs = res.codebooks.context("lnq codebooks")?;
        Ok(Arc::new(AnyPrecArtifact::new(
            &res.codes.context("lnq codes")?,
            &cbs,
            bits,
            w.rows,
            w.cols,
        )))
    };
    let precs: Vec<u8> = (2..=bits as u8).collect();
    let mut blocks: Vec<Vec<Block>> =
        precs.iter().map(|_| Vec::with_capacity(cfg.n_layers)).collect();
    const LINEARS: [&str; 7] = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];
    for l in 0..cfg.n_layers {
        let p = format!("layers.{l}.");
        let arts = LINEARS
            .iter()
            .map(|n| quantize(&format!("{p}{n}")))
            .collect::<Result<Vec<_>>>()?;
        for (bi, &prec) in precs.iter().enumerate() {
            let view = |k: usize| -> Box<dyn LinearOp> {
                Box::new(AnyPrecisionLinear::from_artifact(arts[k].clone(), prec as u32))
            };
            blocks[bi].push(Block {
                attn_norm: ps.get(&format!("{p}attn_norm")).data.clone(),
                mlp_norm: ps.get(&format!("{p}mlp_norm")).data.clone(),
                wq: view(0),
                wk: view(1),
                wv: view(2),
                wo: view(3),
                wgate: view(4),
                wup: view(5),
                wdown: view(6),
            });
        }
    }
    let models = precs
        .iter()
        .zip(blocks)
        .map(|(&prec, blocks)| {
            (
                prec,
                NativeModel {
                    tok_emb: ps.get("tok_emb").clone(),
                    head: Box::new(ps.get("head").clone()),
                    final_norm: ps.get("final_norm").data.clone(),
                    cfg: cfg.clone(),
                    blocks,
                },
            )
        })
        .collect();
    Ok(ModelSet { format, models })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::util::Rng;

    fn params() -> ParamStore {
        let (cfg, _) = preset("tiny");
        ParamStore::init(&cfg, &mut Rng::new(0))
    }

    #[test]
    fn all_formats_build_and_decode() {
        let ps = params();
        let toks = [1u32, 5, 9, 2];
        let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
        let fp_logits = fp.forward_sequence(&toks);
        for format in [
            ServeFormat::UniformScalar,
            ServeFormat::NonUniformScalar,
            ServeFormat::Vector,
            ServeFormat::Trellis,
        ] {
            let m = build_serving_model(&ps, None, format, 4).unwrap();
            let logits = m.forward_sequence(&toks);
            assert_eq!((logits.rows, logits.cols), (fp_logits.rows, fp_logits.cols));
            assert!(
                logits.data.iter().all(|v| v.is_finite()),
                "{format:?} produced non-finite logits"
            );
        }
    }

    #[test]
    fn quantized_formats_use_less_storage() {
        let ps = params();
        let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
        let q = build_serving_model(&ps, None, ServeFormat::UniformScalar, 2).unwrap();
        assert!(q.linear_storage_bytes() * 8 < fp.linear_storage_bytes());
    }

    #[test]
    fn anyprec_set_shares_artifacts_and_matches_lut_at_full_precision() {
        let ps = params();
        let toks = [1u32, 5, 9, 2];
        let set = build_serving_set(&ps, None, ServeFormat::AnyPrecision, 4).unwrap();
        assert_eq!(set.precisions(), vec![2, 3, 4]);
        assert_eq!(set.native_precision(), 4);
        assert!(set.supports(3) && !set.supports(5));
        // Acceptance: the 4-bit view is bit-identical to the fixed
        // NonUniformScalar model (same lnq run, permuted-gather tables).
        let lut = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
        let want = lut.forward_sequence(&toks);
        let got = set.get(4).unwrap().forward_sequence(&toks);
        assert_eq!(got.data, want.data, "anyprec@4 logits != LutLinear logits");
        // Coarser views decode (finite), differ from the full view, and
        // cost no extra weight storage (views alias one artifact).
        for prec in [2u8, 3] {
            let logits = set.get(prec).unwrap().forward_sequence(&toks);
            assert!(logits.data.iter().all(|v| v.is_finite()), "{prec}-bit non-finite");
            assert_ne!(logits.data, want.data, "{prec}-bit view should be coarser");
            assert_eq!(
                set.get(prec).unwrap().linear_storage_bytes(),
                lut_storage_of(&set),
                "every view reports the one shared artifact"
            );
        }
        // Precision resolution: 0 = native, unsupported is an error.
        assert_eq!(set.resolve(0).unwrap(), 4);
        assert_eq!(set.resolve(2).unwrap(), 2);
        assert!(set.resolve(5).is_err());
    }

    fn lut_storage_of(set: &ModelSet) -> usize {
        set.native_model().linear_storage_bytes()
    }

    #[test]
    fn fixed_formats_build_single_entry_sets() {
        let ps = params();
        let set = build_serving_set(&ps, None, ServeFormat::Fp32, 16).unwrap();
        assert_eq!(set.precisions(), vec![32]);
        assert_eq!(set.format().name(), "fp32");
        let set = build_serving_set(&ps, None, ServeFormat::UniformScalar, 3).unwrap();
        assert_eq!(set.precisions(), vec![3]);
        assert_eq!(set.resolve(0).unwrap(), 3);
        assert!(set.resolve(2).is_err());
        assert!(build_serving_set(&ps, None, ServeFormat::AnyPrecision, 1).is_err());
    }

    #[test]
    fn serve_format_parse_round_trips() {
        for f in [
            ServeFormat::Fp32,
            ServeFormat::UniformScalar,
            ServeFormat::NonUniformScalar,
            ServeFormat::Vector,
            ServeFormat::Trellis,
            ServeFormat::AnyPrecision,
        ] {
            assert_eq!(ServeFormat::parse(f.name()).unwrap(), f);
        }
        assert!(ServeFormat::parse("int8").is_err());
    }

    #[test]
    fn four_bit_lut_model_tracks_fp_logits() {
        let ps = params();
        let toks = [3u32, 7];
        let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
        let q = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
        let a = fp.forward_sequence(&toks);
        let b = q.forward_sequence(&toks);
        // 4-bit LNQ on a tiny model: logits should correlate strongly.
        let mut dot = 0.0f64;
        let mut na = 0.0f64;
        let mut nb = 0.0f64;
        for (x, y) in a.data.iter().zip(&b.data) {
            dot += (*x as f64) * (*y as f64);
            na += (*x as f64).powi(2);
            nb += (*y as f64).powi(2);
        }
        let cos = dot / (na.sqrt() * nb.sqrt()).max(1e-12);
        assert!(cos > 0.95, "cosine {cos}");
    }
}
