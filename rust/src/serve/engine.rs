//! Serving engine facade + throughput/latency measurement (Table 2 rig).
//!
//! Two decode paths, guaranteed to emit bit-identical greedy tokens:
//!
//! * [`generate_batch`] / [`generate_scheduled`] — the continuous-batching
//!   [`Scheduler`]: one batched model step per engine step, quantized weight
//!   tiles decoded once per step and applied to every lane.
//! * [`generate_per_sequence`] — the original per-sequence reference (one
//!   worker thread per sequence, scalar decode), kept as the baseline the
//!   batched path is benchmarked and regression-tested against.

use anyhow::{ensure, Result};

use crate::cfg::ServeConfig;
use crate::coordinator::run_jobs;
use crate::model::NativeModel;
use crate::util::{mean, percentile, Rng};

use super::scheduler::{greedy_argmax, Scheduler};

#[derive(Debug, Clone)]
pub struct ServeStats {
    pub total_tokens: usize,
    pub wall_secs: f64,
    pub tok_per_sec: f64,
    /// Per-token decode latencies (ms), pooled across sequences.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Time-to-first-token across requests (ms).
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    /// Mean admission-queue wait across requests (ms).
    pub queue_wait_ms: f64,
    /// Mean active lanes per decode step (1.0 on the per-sequence path).
    pub batch_occupancy: f64,
    pub weight_bytes: usize,
    pub kv_bytes: usize,
}

/// Greedy-decode `gen_tokens` continuation tokens for each prompt through
/// the continuous-batching scheduler. Compatibility wrapper: every prompt
/// is admitted immediately (`max_batch = prompts.len()`). Errors on empty
/// prompts — the old path silently greedy-decoded token 0 from zeroed
/// logits when a prompt had no tokens to prefill.
pub fn generate_batch(
    model: &NativeModel,
    prompts: &[Vec<u32>],
    gen_tokens: usize,
    workers: usize,
) -> Result<(Vec<Vec<u32>>, ServeStats)> {
    let cfg = ServeConfig {
        max_batch: prompts.len().max(1),
        max_queued: prompts.len().max(1),
        ..ServeConfig::default()
    };
    generate_scheduled(model, prompts, gen_tokens, workers, cfg)
}

/// Scheduler path with explicit admission-control knobs (`max_batch`
/// bounds the continuous-batch width; queued requests splice in as lanes
/// free up).
pub fn generate_scheduled(
    model: &NativeModel,
    prompts: &[Vec<u32>],
    gen_tokens: usize,
    workers: usize,
    cfg: ServeConfig,
) -> Result<(Vec<Vec<u32>>, ServeStats)> {
    generate_scheduled_streaming(model, prompts, gen_tokens, workers, cfg, |_, _| {})
}

/// [`generate_scheduled`] with a streaming sink: `on_token(request_id,
/// token)` fires for every token the moment its engine step completes
/// (drained from [`Scheduler::step_tokens`]), so consumers see output
/// incrementally instead of waiting for sequence completion. Tokens of one
/// request arrive in order; tokens of different requests interleave in
/// lane order per step.
pub fn generate_scheduled_streaming(
    model: &NativeModel,
    prompts: &[Vec<u32>],
    gen_tokens: usize,
    workers: usize,
    cfg: ServeConfig,
    mut on_token: impl FnMut(u64, u32),
) -> Result<(Vec<Vec<u32>>, ServeStats)> {
    let t0 = std::time::Instant::now();
    // An explicit [serve] workers knob overrides the positional argument,
    // so config files drive the engine the same way the CLI does.
    let workers = if cfg.workers != 0 { cfg.workers } else { workers };
    let mut sched = Scheduler::with_workers(model, cfg, workers);
    let mut done = Vec::with_capacity(prompts.len());
    let mut drain_step = |sched: &mut Scheduler, done: &mut Vec<_>| {
        done.extend(sched.step());
        for &(id, tok) in sched.step_tokens() {
            on_token(id, tok);
        }
    };
    for p in prompts {
        // Back-pressure: when the admission queue is full, drain decode
        // steps until a slot frees instead of erroring — `max_queued` is a
        // buffering knob here, not a hard cap on the request set.
        while sched.queued() >= sched.cfg.max_queued {
            drain_step(&mut sched, &mut done);
        }
        sched.submit(p, gen_tokens)?;
    }
    while sched.has_work() {
        drain_step(&mut sched, &mut done);
    }
    done.sort_by_key(|f| f.id);
    let wall = t0.elapsed().as_secs_f64();
    ensure!(done.len() == prompts.len(), "scheduler dropped requests");

    let mut outs = Vec::with_capacity(done.len());
    let mut lats = Vec::new();
    let mut ttfts = Vec::with_capacity(done.len());
    let mut waits = Vec::with_capacity(done.len());
    let mut kv_bytes = 0usize;
    // `done` was sorted by id above: submission order, which is prompt order.
    for fr in done {
        lats.extend_from_slice(&fr.metrics.token_ms);
        ttfts.push(fr.metrics.ttft_ms);
        waits.push(fr.metrics.queue_wait_ms);
        kv_bytes += fr.metrics.kv_bytes;
        outs.push(fr.tokens);
    }
    let total_tokens: usize = outs.iter().map(|o| o.len()).sum();
    let stats = ServeStats {
        total_tokens,
        wall_secs: wall,
        tok_per_sec: total_tokens as f64 / wall.max(1e-9),
        p50_ms: percentile(&lats, 50.0),
        p99_ms: percentile(&lats, 99.0),
        ttft_p50_ms: percentile(&ttfts, 50.0),
        ttft_p99_ms: percentile(&ttfts, 99.0),
        queue_wait_ms: mean(&waits),
        batch_occupancy: sched.mean_occupancy(),
        weight_bytes: model.linear_storage_bytes(),
        kv_bytes,
    };
    Ok((outs, stats))
}

/// Reference path: one worker thread per sequence, scalar decode, no
/// batching — the CPU analog of batched single-stream decoding that the
/// seed engine implemented. Kept for benchmarking the amortized-decode win
/// and for bit-identity regression tests against the scheduler.
pub fn generate_per_sequence(
    model: &NativeModel,
    prompts: &[Vec<u32>],
    gen_tokens: usize,
    workers: usize,
) -> Result<(Vec<Vec<u32>>, ServeStats)> {
    ensure!(
        prompts.iter().all(|p| !p.is_empty()),
        "empty prompt: prefill needs at least one (BOS) token"
    );
    // Mirror Scheduler::submit's validation so the two paths fail the same
    // way instead of this one panicking inside the embedding lookup.
    let vocab = model.cfg.vocab;
    ensure!(
        prompts.iter().flatten().all(|&t| (t as usize) < vocab),
        "prompt token out of range for vocab {vocab}"
    );
    let t0 = std::time::Instant::now();
    let jobs: Vec<_> = prompts
        .iter()
        .map(|prompt| {
            let prompt = prompt.clone();
            move || {
                // TTFT is measured from batch start (t0), not worker
                // pickup, so it includes waiting for a free worker thread —
                // the same clock the scheduler path's submit-based TTFT
                // uses, keeping the two paths' columns comparable.
                let mut state = model.new_state();
                let mut latencies = Vec::with_capacity(gen_tokens);
                let mut logits = vec![0.0f32; model.cfg.vocab];
                for &t in &prompt {
                    logits = model.step(&mut state, t);
                }
                let mut out = Vec::with_capacity(gen_tokens);
                let mut ttft = 0.0f64;
                for i in 0..gen_tokens {
                    let tt = std::time::Instant::now();
                    let next = greedy_argmax(&logits);
                    out.push(next);
                    if i == 0 {
                        ttft = t0.elapsed().as_secs_f64() * 1000.0;
                    }
                    logits = model.step(&mut state, next);
                    latencies.push(tt.elapsed().as_secs_f64() * 1000.0);
                }
                (out, latencies, ttft, state.kv_bytes())
            }
        })
        .collect();
    let results = run_jobs(jobs, workers);
    let wall = t0.elapsed().as_secs_f64();
    let mut outs = Vec::with_capacity(prompts.len());
    let mut lats = Vec::new();
    let mut ttfts = Vec::new();
    let mut kv_bytes = 0usize;
    for (o, l, ttft, kv) in results {
        outs.push(o);
        lats.extend(l);
        ttfts.push(ttft);
        kv_bytes += kv;
    }
    let total_tokens = gen_tokens * prompts.len();
    let stats = ServeStats {
        total_tokens,
        wall_secs: wall,
        tok_per_sec: total_tokens as f64 / wall.max(1e-9),
        p50_ms: percentile(&lats, 50.0),
        p99_ms: percentile(&lats, 99.0),
        ttft_p50_ms: percentile(&ttfts, 50.0),
        ttft_p99_ms: percentile(&ttfts, 99.0),
        queue_wait_ms: 0.0,
        batch_occupancy: 1.0,
        weight_bytes: model.linear_storage_bytes(),
        kv_bytes,
    };
    Ok((outs, stats))
}

/// Deterministic random prompts for benchmarking.
pub fn random_prompts(vocab: usize, n: usize, len: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed ^ 0x5e21e);
    (0..n)
        .map(|_| (0..len).map(|_| rng.below(vocab) as u32).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::model::ParamStore;

    fn model() -> NativeModel {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        NativeModel::from_params(&ps)
    }

    #[test]
    fn generates_requested_tokens() {
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 3, 4, 1);
        let (outs, stats) = generate_batch(&m, &prompts, 5, 2).unwrap();
        assert_eq!(outs.len(), 3);
        assert!(outs.iter().all(|o| o.len() == 5));
        assert_eq!(stats.total_tokens, 15);
        assert!(stats.tok_per_sec > 0.0);
        assert!(stats.p99_ms >= stats.p50_ms);
        assert!(stats.kv_bytes > 0);
        assert!(stats.batch_occupancy >= 1.0);
        assert!(stats.ttft_p99_ms >= stats.ttft_p50_ms);
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 2, 6, 2);
        let (a, _) = generate_batch(&m, &prompts, 4, 1).unwrap();
        let (b, _) = generate_batch(&m, &prompts, 4, 2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn scheduler_path_matches_per_sequence_path_bitwise() {
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 4, 5, 3);
        let (want, _) = generate_per_sequence(&m, &prompts, 7, 2).unwrap();
        // Full-width batch.
        let (got, _) = generate_batch(&m, &prompts, 7, 2).unwrap();
        assert_eq!(got, want);
        // Narrow batch: continuous splicing, still identical.
        let cfg = ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() };
        let (got2, stats) = generate_scheduled(&m, &prompts, 7, 1, cfg).unwrap();
        assert_eq!(got2, want);
        assert!(stats.batch_occupancy <= 2.0 + 1e-9);
    }

    #[test]
    fn streaming_callback_sees_every_token_in_order() {
        // The streamed (id, token) feed must reassemble exactly into the
        // returned outputs, even with back-pressure draining mid-submit.
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 4, 4, 11);
        let mut streamed: std::collections::HashMap<u64, Vec<u32>> = Default::default();
        let cfg = ServeConfig { max_batch: 2, max_queued: 2, ..ServeConfig::default() };
        let (outs, _) = generate_scheduled_streaming(&m, &prompts, 5, 1, cfg, |id, tok| {
            streamed.entry(id).or_default().push(tok);
        })
        .unwrap();
        assert_eq!(outs.len(), 4);
        for (i, out) in outs.iter().enumerate() {
            assert_eq!(&streamed[&(i as u64)], out, "request {i}");
        }
        // And the non-streaming wrapper returns identical outputs.
        let cfg = ServeConfig { max_batch: 2, max_queued: 2, ..ServeConfig::default() };
        let (plain, _) = generate_scheduled(&m, &prompts, 5, 1, cfg).unwrap();
        assert_eq!(plain, outs);
    }

    #[test]
    fn empty_prompts_are_rejected() {
        let m = model();
        let prompts = vec![vec![1u32, 2], vec![]];
        assert!(generate_batch(&m, &prompts, 3, 1).is_err());
        assert!(generate_per_sequence(&m, &prompts, 3, 1).is_err());
    }

    #[test]
    fn zero_gen_tokens_has_sane_stats() {
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 2, 3, 5);
        let (outs, stats) = generate_batch(&m, &prompts, 0, 1).unwrap();
        assert!(outs.iter().all(|o| o.is_empty()));
        assert_eq!(stats.total_tokens, 0);
        assert_eq!(stats.tok_per_sec, 0.0);
        assert!(stats.tok_per_sec.is_finite());
        assert_eq!(stats.p50_ms, 0.0);
        assert_eq!(stats.p99_ms, 0.0);
    }

    #[test]
    fn request_set_larger_than_queue_capacity_is_still_served() {
        // max_queued is a buffering knob: generate_scheduled drains decode
        // steps when the queue fills instead of erroring.
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 6, 3, 7);
        let (want, _) = generate_per_sequence(&m, &prompts, 3, 1).unwrap();
        let cfg = ServeConfig { max_batch: 2, max_queued: 2, ..ServeConfig::default() };
        let (outs, _) = generate_scheduled(&m, &prompts, 3, 1, cfg).unwrap();
        assert_eq!(outs, want);
    }

    #[test]
    fn narrow_batch_reports_queue_wait() {
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 4, 3, 6);
        let cfg = ServeConfig { max_batch: 1, max_queued: 8, ..ServeConfig::default() };
        let (_, stats) = generate_scheduled(&m, &prompts, 3, 1, cfg).unwrap();
        // With a single lane, later requests must have waited in the queue.
        assert!(stats.queue_wait_ms > 0.0);
        assert!((stats.batch_occupancy - 1.0).abs() < 1e-9);
    }
}
