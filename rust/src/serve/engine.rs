//! Batched decode loop + throughput/latency measurement (Table 2 rig).
//!
//! Requests are independent sequences; the engine decodes them on the
//! worker pool (one sequence per worker at a time — the CPU analog of
//! batched single-stream decoding) and reports aggregate tokens/s plus
//! per-token latency percentiles.

use crate::coordinator::run_jobs;
use crate::model::NativeModel;
use crate::util::{percentile, Rng};

#[derive(Debug, Clone)]
pub struct ServeStats {
    pub total_tokens: usize,
    pub wall_secs: f64,
    pub tok_per_sec: f64,
    /// Per-token decode latencies (ms), pooled across sequences.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub weight_bytes: usize,
    pub kv_bytes: usize,
}

/// Greedy-decode `gen_tokens` continuation tokens for each prompt.
pub fn generate_batch(
    model: &NativeModel,
    prompts: &[Vec<u32>],
    gen_tokens: usize,
    workers: usize,
) -> (Vec<Vec<u32>>, ServeStats) {
    let t0 = std::time::Instant::now();
    let jobs: Vec<_> = prompts
        .iter()
        .map(|prompt| {
            let prompt = prompt.clone();
            move || {
                let mut state = model.new_state();
                let mut latencies = Vec::with_capacity(gen_tokens);
                let mut logits = vec![0.0f32; model.cfg.vocab];
                for &t in &prompt {
                    logits = model.step(&mut state, t);
                }
                let mut out = Vec::with_capacity(gen_tokens);
                for _ in 0..gen_tokens {
                    let tt = std::time::Instant::now();
                    let next = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as u32)
                        .unwrap();
                    out.push(next);
                    logits = model.step(&mut state, next);
                    latencies.push(tt.elapsed().as_secs_f64() * 1000.0);
                }
                (out, latencies, state.kv_bytes())
            }
        })
        .collect();
    let results = run_jobs(jobs, workers);
    let wall = t0.elapsed().as_secs_f64();
    let mut outs = Vec::with_capacity(prompts.len());
    let mut lats = Vec::new();
    let mut kv_bytes = 0usize;
    for (o, l, kv) in results {
        outs.push(o);
        lats.extend(l);
        kv_bytes += kv;
    }
    let total_tokens = gen_tokens * prompts.len();
    let stats = ServeStats {
        total_tokens,
        wall_secs: wall,
        tok_per_sec: total_tokens as f64 / wall.max(1e-9),
        p50_ms: percentile(&lats, 50.0),
        p99_ms: percentile(&lats, 99.0),
        weight_bytes: model.linear_storage_bytes(),
        kv_bytes,
    };
    (outs, stats)
}

/// Deterministic random prompts for benchmarking.
pub fn random_prompts(vocab: usize, n: usize, len: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed ^ 0x5e21e);
    (0..n)
        .map(|_| (0..len).map(|_| rng.below(vocab) as u32).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::model::ParamStore;

    fn model() -> NativeModel {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        NativeModel::from_params(&ps)
    }

    #[test]
    fn generates_requested_tokens() {
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 3, 4, 1);
        let (outs, stats) = generate_batch(&m, &prompts, 5, 2);
        assert_eq!(outs.len(), 3);
        assert!(outs.iter().all(|o| o.len() == 5));
        assert_eq!(stats.total_tokens, 15);
        assert!(stats.tok_per_sec > 0.0);
        assert!(stats.p99_ms >= stats.p50_ms);
        assert!(stats.kv_bytes > 0);
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        let m = model();
        let prompts = random_prompts(m.cfg.vocab, 2, 6, 2);
        let (a, _) = generate_batch(&m, &prompts, 4, 1);
        let (b, _) = generate_batch(&m, &prompts, 4, 2);
        assert_eq!(a, b);
    }
}
