//! Engine supervision: fault isolation and bounded restarts around the
//! continuous-batching [`Scheduler`].
//!
//! The HTTP engine thread used to call `Scheduler::step` bare — one panic
//! (a poisoned weight tile tripping the always-on code-range validation,
//! a degenerate logit row) killed the engine permanently while `/healthz`
//! kept reporting healthy. [`SupervisedEngine`] wraps each step phase in
//! `catch_unwind` and **attributes** the fault:
//!
//! * a panic in the **admission phase** can only involve freshly admitted
//!   requests (in-flight lanes are untouched by admission) — those
//!   requests fail with [`FinishReason::Failed`], their KV states return
//!   to the arena, and everything else proceeds;
//! * a panic in the **decode phase** with a single active lane is pinned
//!   on that request — it alone fails;
//! * a multi-lane decode panic is unattributable — the supervisor
//!   **restarts** the engine with a fresh [`Scheduler`] (dropping the old
//!   one frees every KV page) and, per [`RestartPolicy`], either fails
//!   in-flight requests fast or requeues them under their original ids
//!   and deadlines. Greedy decode is deterministic, so a requeued lane's
//!   first tokens are bit-identical replays; the supervisor suppresses
//!   the ones already streamed, so consumers see each token exactly once.
//!
//! Restarts are bounded by [`ServeConfig::max_engine_restarts`]; past the
//! budget the engine is declared dead ([`SupervisedEngine::alive`] turns
//! false), every tracked request fails, and new submissions are refused —
//! the HTTP layer flips `/healthz` to 503 and drains.
//!
//! The supervisor is also where **KV pressure preemption** lives: when
//! live KV bytes cross the high watermark of
//! [`ServeConfig::kv_budget_bytes`], the step preempts the youngest
//! active lane ([`Scheduler::preempt_youngest`] deallocates its pages)
//! and resubmits the request through the same requeue machinery a
//! restart uses — original id and deadline, streamed prefix marked for
//! replay suppression — so the client keeps its connection and sees each
//! token exactly once. Preemption reclaims memory without failing anyone;
//! shedding (429) is the HTTP layer's last resort, not the first.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cfg::{RestartPolicy, ServeConfig};
use crate::model::NativeModel;
use crate::serve::scheduler::{
    FinishReason, FinishedRequest, RequestMetrics, Scheduler, SubmitOpts,
};

/// Everything the supervisor needs to recover a request after an engine
/// restart: resubmit the original prompt under the original id/deadline,
/// and suppress replayed tokens.
struct Tracked {
    prompt: Vec<u32>,
    gen_tokens: usize,
    /// Absolute deadline, fixed at submission — survives restarts (a
    /// scheduler-relative deadline would silently extend on requeue).
    deadline: Option<Instant>,
    /// Tokens already exposed through [`SupervisedEngine::step_tokens`].
    streamed: usize,
    /// Replayed tokens still to swallow after a requeue (deterministic
    /// decode re-emits exactly the `streamed` prefix, bit-identical).
    replay_skip: usize,
    /// Precision the request was submitted with (`None` = engine
    /// default). Requeues with streamed tokens override this with the
    /// precision the lane was *serving* at, pinning the replay to the
    /// same bank model so suppression stays bit-identical.
    precision: Option<u8>,
}

/// A [`Scheduler`] under `catch_unwind` supervision with fault
/// attribution, restart budgeting, and replay suppression. Drop-in for
/// the engine loop: `submit` / `step` / `step_tokens` mirror the
/// scheduler's surface.
pub struct SupervisedEngine<'m> {
    /// Precision bank a fresh post-restart scheduler is rebuilt from
    /// (single-model engines hold one entry labelled 0).
    bank: Vec<(u8, &'m NativeModel)>,
    default_prec: u8,
    floor_prec: u8,
    cfg: ServeConfig,
    sched: Scheduler<'m>,
    tracked: HashMap<u64, Tracked>,
    restarts: usize,
    dead: bool,
    /// Post-suppression tokens of the most recent step.
    emitted: Vec<(u64, u32)>,
}

impl<'m> SupervisedEngine<'m> {
    pub fn new(model: &'m NativeModel, cfg: ServeConfig) -> Self {
        Self::with_bank(vec![(0, model)], cfg, 0, 0)
    }

    /// Supervised engine over a precision bank (see
    /// [`Scheduler::with_bank`]); restarts rebuild the scheduler from the
    /// same bank, default, and floor.
    pub fn with_bank(
        bank: Vec<(u8, &'m NativeModel)>,
        cfg: ServeConfig,
        default_prec: u8,
        floor_prec: u8,
    ) -> Self {
        SupervisedEngine {
            sched: Scheduler::with_bank(bank.clone(), cfg.clone(), default_prec, floor_prec),
            bank,
            default_prec,
            floor_prec,
            cfg,
            tracked: HashMap::new(),
            restarts: 0,
            dead: false,
            emitted: Vec::new(),
        }
    }

    /// Submit a request. `timeout_ms` (from the HTTP body) overrides
    /// [`ServeConfig::request_timeout_ms`]; 0/absent falls back. Errors
    /// when the engine is dead or the scheduler refuses admission.
    pub fn submit(
        &mut self,
        prompt: &[u32],
        gen_tokens: usize,
        timeout_ms: Option<u64>,
    ) -> Result<u64> {
        self.submit_prec(prompt, gen_tokens, timeout_ms, None)
    }

    /// [`SupervisedEngine::submit`] with an explicit decode precision
    /// (`None`/`Some(0)` = engine default; an explicit bank label is
    /// pinned — the downshift rung never moves it).
    pub fn submit_prec(
        &mut self,
        prompt: &[u32],
        gen_tokens: usize,
        timeout_ms: Option<u64>,
        precision: Option<u8>,
    ) -> Result<u64> {
        if self.dead {
            bail!("engine dead: restart budget exhausted");
        }
        let ms = timeout_ms.filter(|&t| t > 0).unwrap_or(self.cfg.request_timeout_ms);
        // The absolute deadline is fixed here, not inside the scheduler,
        // so the supervisor can carry it across restarts verbatim.
        let deadline = (ms > 0).then(|| Instant::now() + Duration::from_millis(ms));
        let id = self.sched.submit_opts(
            prompt,
            gen_tokens,
            SubmitOpts { deadline, precision, ..SubmitOpts::default() },
        )?;
        self.tracked.insert(
            id,
            Tracked {
                prompt: prompt.to_vec(),
                gen_tokens,
                deadline,
                streamed: 0,
                replay_skip: 0,
                precision,
            },
        );
        Ok(id)
    }

    /// Cancel a queued or in-flight request (client disconnect, explicit
    /// abort). Returns the partial result, `None` if the id is unknown.
    pub fn cancel(&mut self, id: u64) -> Option<FinishedRequest> {
        let fr = self.sched.cancel(id)?;
        self.tracked.remove(&id);
        Some(fr)
    }

    /// One supervised engine step. Never panics; faults surface as
    /// [`FinishReason::Failed`] events (and, for unattributable faults,
    /// an engine restart). Step tokens — with requeue replays suppressed —
    /// are exposed via [`SupervisedEngine::step_tokens`].
    pub fn step(&mut self) -> Vec<FinishedRequest> {
        self.emitted.clear();
        if self.dead {
            return Vec::new();
        }
        let mut finished = Vec::new();
        self.governance_preempt(&mut finished);
        match catch_unwind(AssertUnwindSafe(|| self.sched.admit_phase())) {
            Ok(f) => finished.extend(f),
            Err(payload) => {
                crate::log_warn!(
                    "supervisor",
                    "admission panic ({}); failing mid-prefill requests",
                    panic_msg(&payload)
                );
                finished.extend(self.sched.recover_admission());
            }
        }
        // Read attribution context BEFORE the step: lane membership only
        // changes at eviction, after the panic window.
        let single_lane = self.sched.active() == 1;
        match catch_unwind(AssertUnwindSafe(|| self.sched.decode_phase())) {
            Ok(f) => finished.extend(f),
            Err(payload) if single_lane => {
                crate::log_warn!(
                    "supervisor",
                    "decode panic with one lane ({}); failing that request",
                    panic_msg(&payload)
                );
                finished.extend(self.sched.fail_all_active());
            }
            Err(payload) => {
                crate::log_warn!(
                    "supervisor",
                    "unattributable decode panic ({}); restarting engine",
                    panic_msg(&payload)
                );
                finished.extend(self.restart());
            }
        }
        // Stream this step's tokens, swallowing post-restart replays.
        for &(id, tok) in self.sched.step_tokens() {
            if let Some(t) = self.tracked.get_mut(&id) {
                if t.replay_skip > 0 {
                    t.replay_skip -= 1;
                    continue;
                }
                t.streamed += 1;
            }
            self.emitted.push((id, tok));
        }
        for fr in &finished {
            self.tracked.remove(&fr.id);
        }
        finished
    }

    /// KV pressure response, run before admission so freed pages are
    /// visible to the admit pass: while live KV bytes sit above the high
    /// watermark, preempt the youngest lane and resubmit it under its
    /// original id/deadline with its streamed prefix marked for replay
    /// suppression — the restart requeue machinery, applied to one lane.
    /// A no-op when `kv_budget_bytes` is 0 (`kv_over_high` is false).
    fn governance_preempt(&mut self, finished: &mut Vec<FinishedRequest>) {
        // Mildest relief first: cached-but-unreferenced prefix pages are
        // shed before any lane is preempted — giving back cache memory
        // costs nobody anything.
        self.sched.shed_cached_prefixes();
        while self.sched.kv_over_high() {
            let Some((id, served_prec)) = self.sched.preempt_youngest() else { break };
            crate::log_warn!(
                "supervisor",
                "kv pressure {:.2}: preempted lane {id} for requeue",
                self.sched.kv_pressure()
            );
            let Some(t) = self.tracked.get_mut(&id) else { continue };
            t.replay_skip = t.streamed;
            t.streamed = 0;
            // Tokens already streamed were decoded at `served_prec`
            // (possibly a downshift); pin the requeue there so the replay
            // is bit-identical. With nothing streamed the original
            // request stands — the adaptive policy stays free to act.
            let precision = if t.replay_skip > 0 { Some(served_prec) } else { t.precision };
            let opts = SubmitOpts {
                deadline: t.deadline,
                id: Some(id),
                precision,
                ..SubmitOpts::default()
            };
            let (prompt, gen) = (t.prompt.clone(), t.gen_tokens);
            if let Err(e) = self.sched.submit_opts(&prompt, gen, opts) {
                crate::log_warn!("supervisor", "requeue of preempted request {id} failed: {e}");
                let prec = self.effective_prec(id);
                self.tracked.remove(&id);
                finished.push(failed_event(id, prec));
            }
        }
    }

    /// The bank label a tracked request would report if it failed before
    /// serving (its explicit pin, else the engine default).
    fn effective_prec(&self, id: u64) -> u8 {
        self.tracked
            .get(&id)
            .and_then(|t| t.precision.filter(|&p| p != 0))
            .unwrap_or(self.default_prec)
    }

    /// Replace the scheduler with a fresh one (freeing every KV page of
    /// the old) and apply [`RestartPolicy`] to tracked requests. Declares
    /// the engine dead past the restart budget.
    fn restart(&mut self) -> Vec<FinishedRequest> {
        self.restarts += 1;
        // Snapshot (id, served precision) of active lanes before the old
        // scheduler drops: a requeued lane with streamed tokens must
        // replay through the same bank model.
        let was_active: Vec<(u64, u8)> = self.sched.lane_infos();
        let next_id = self.sched.next_request_id();
        // Dropping the old scheduler releases all lanes' KV pages.
        self.sched = Scheduler::with_bank(
            self.bank.clone(),
            self.cfg.clone(),
            self.default_prec,
            self.floor_prec,
        );
        self.sched.set_next_id(next_id);

        let mut ids: Vec<u64> = self.tracked.keys().copied().collect();
        ids.sort_unstable();
        let mut events = Vec::new();
        if self.restarts > self.cfg.max_engine_restarts {
            crate::log_warn!(
                "supervisor",
                "restart budget exhausted ({} > {}); engine dead",
                self.restarts,
                self.cfg.max_engine_restarts
            );
            self.dead = true;
            for id in ids {
                let prec = self.effective_prec(id);
                events.push(failed_event(id, prec));
            }
            self.tracked.clear();
            return events;
        }
        for id in ids {
            let active = was_active.iter().find(|(lid, _)| *lid == id).copied();
            if active.is_some() && self.cfg.restart_policy == RestartPolicy::FailFast {
                let prec = self.effective_prec(id);
                self.tracked.remove(&id);
                events.push(failed_event(id, prec));
                continue;
            }
            // Queued requests (no output yet) are requeued under either
            // policy; active ones only under Requeue, with their already
            // streamed prefix marked for replay suppression — pinned to
            // the precision they were serving at, so the replay is
            // bit-identical even after a pressure downshift.
            let t = self.tracked.get_mut(&id).expect("tracked id");
            t.replay_skip = if active.is_some() { t.streamed } else { 0 };
            t.streamed = 0;
            let precision = match active {
                Some((_, served_prec)) if t.replay_skip > 0 => Some(served_prec),
                _ => t.precision,
            };
            let opts = SubmitOpts {
                deadline: t.deadline,
                id: Some(id),
                precision,
                ..SubmitOpts::default()
            };
            let (prompt, gen) = (t.prompt.clone(), t.gen_tokens);
            if let Err(e) = self.sched.submit_opts(&prompt, gen, opts) {
                crate::log_warn!("supervisor", "requeue of request {id} failed: {e}");
                let prec = self.effective_prec(id);
                self.tracked.remove(&id);
                events.push(failed_event(id, prec));
            }
        }
        events
    }

    /// Tokens of the most recent [`SupervisedEngine::step`], requeue
    /// replays suppressed — each consumer sees each token exactly once.
    pub fn step_tokens(&self) -> &[(u64, u32)] {
        &self.emitted
    }

    /// False once the restart budget is exhausted: the engine refuses new
    /// work and `/healthz` must report 503.
    pub fn alive(&self) -> bool {
        !self.dead
    }

    /// Engine restarts so far (the `/metrics` counter).
    pub fn restarts(&self) -> usize {
        self.restarts
    }

    pub fn has_work(&self) -> bool {
        !self.dead && self.sched.has_work()
    }

    pub fn queued(&self) -> usize {
        self.sched.queued()
    }

    pub fn active(&self) -> usize {
        self.sched.active()
    }

    pub fn kv_bytes(&self) -> usize {
        self.sched.kv_bytes()
    }

    pub fn kv_allocated_bytes(&self) -> usize {
        self.sched.kv_allocated_bytes()
    }

    pub fn kv_live_bytes(&self) -> usize {
        self.sched.kv_live_bytes()
    }

    /// Live-KV pressure against the configured budget (0.0 when off).
    pub fn kv_pressure(&self) -> f64 {
        self.sched.kv_pressure()
    }

    /// Whether a request of this shape is refused up front by the KV
    /// budget (or the armed `kv-exhaust` fault site).
    pub fn kv_submit_refused(&self, prompt_len: usize, gen_tokens: usize) -> bool {
        self.sched.kv_submit_refused(prompt_len, gen_tokens)
    }

    /// Worst-case KV bytes for a request spanning `total_pos` positions.
    pub fn kv_request_cost_bytes(&self, total_pos: usize) -> usize {
        self.sched.kv_request_cost_bytes(total_pos)
    }

    /// [`Scheduler::kv_submit_refused`] with the prefix-cache discount
    /// (read from the cache of the precision the request would decode at).
    pub fn kv_submit_refused_for(
        &self,
        prompt: &[u32],
        gen_tokens: usize,
        precision: Option<u8>,
    ) -> bool {
        self.sched.kv_submit_refused_for(prompt, gen_tokens, precision)
    }

    /// Bank labels served by this engine, ascending.
    pub fn precisions(&self) -> Vec<u8> {
        self.sched.precisions()
    }

    /// The bank label unspecified requests decode at.
    pub fn default_precision(&self) -> u8 {
        self.default_prec
    }

    /// The downshift target (0 = rung disabled).
    pub fn floor_precision(&self) -> u8 {
        self.floor_prec
    }

    /// Admissions downshifted to the floor precision so far.
    pub fn precision_downshifts(&self) -> u64 {
        self.sched.precision_downshifts()
    }

    /// Admissions that mapped at least one cached prefix chunk so far.
    pub fn prefix_hits(&self) -> u64 {
        self.sched.prefix_hits()
    }

    /// Prompt positions whose prefill compute was skipped, cumulative.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.sched.prefill_tokens_saved()
    }

    /// KV pages currently held by the prefix cache.
    pub fn prefix_cached_pages(&self) -> usize {
        self.sched.prefix_cached_pages()
    }

    /// Requests admitted with a brownout-clamped token budget so far.
    pub fn brownouts(&self) -> u64 {
        self.sched.brownouts()
    }

    /// Lanes preempted under KV pressure so far.
    pub fn preemptions(&self) -> u64 {
        self.sched.preemptions()
    }

    /// Predicted queue wait (ms) from the measured per-step drain rate.
    pub fn predicted_wait_ms(&self) -> u64 {
        self.sched.predicted_wait_ms()
    }

    pub fn kv_dtype(&self) -> crate::cfg::KvDtype {
        self.sched.kv_dtype()
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.sched.mean_occupancy()
    }
}

fn failed_event(id: u64, precision: u8) -> FinishedRequest {
    FinishedRequest {
        id,
        tokens: Vec::new(),
        metrics: RequestMetrics::empty(),
        finish: FinishReason::Failed,
        degraded: false,
        precision,
    }
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::preset;
    use crate::model::ParamStore;
    use crate::util::{fault, Rng};
    use std::collections::HashMap;

    fn model() -> NativeModel {
        let (cfg, _) = preset("tiny");
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        NativeModel::from_params(&ps)
    }

    fn reference(m: &NativeModel, prompt: &[u32], gen: usize) -> Vec<u32> {
        let mut sched = Scheduler::new(m, ServeConfig::default());
        sched.submit(prompt, gen).unwrap();
        sched.run_to_completion().remove(0).tokens
    }

    fn prompts(m: &NativeModel, n: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| (0..(2 + i % 3)).map(|_| rng.below(m.cfg.vocab) as u32).collect())
            .collect()
    }

    /// Drive to quiescence, collecting (finished, streamed-per-id).
    fn drain(
        eng: &mut SupervisedEngine<'_>,
    ) -> (Vec<FinishedRequest>, HashMap<u64, Vec<u32>>) {
        let mut done = Vec::new();
        let mut streamed: HashMap<u64, Vec<u32>> = HashMap::new();
        let safety = Instant::now() + Duration::from_secs(30);
        while eng.has_work() && Instant::now() < safety {
            done.extend(eng.step());
            for &(id, tok) in eng.step_tokens() {
                streamed.entry(id).or_default().push(tok);
            }
        }
        done.sort_by_key(|f| f.id);
        (done, streamed)
    }

    #[test]
    fn happy_path_is_bit_identical_to_bare_scheduler() {
        let m = model();
        let ps = prompts(&m, 4, 11);
        let gens = [5usize, 3, 7, 4];
        let cfg = ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() };
        let mut eng = SupervisedEngine::new(&m, cfg);
        for (p, &g) in ps.iter().zip(&gens) {
            eng.submit(p, g, None).unwrap();
        }
        let (done, streamed) = drain(&mut eng);
        assert_eq!(done.len(), 4);
        assert_eq!(eng.restarts(), 0);
        assert!(eng.alive());
        for (i, fr) in done.iter().enumerate() {
            assert_eq!(fr.finish, FinishReason::Length);
            assert_eq!(fr.tokens, reference(&m, &ps[i], gens[i]), "request {i}");
            assert_eq!(streamed[&fr.id], fr.tokens, "streamed != final for {i}");
        }
    }

    #[test]
    fn single_lane_panic_fails_only_that_request() {
        let m = model();
        let p = prompts(&m, 1, 3).remove(0);
        let want = reference(&m, &p, 6);
        let mut eng = SupervisedEngine::new(&m, ServeConfig::default());
        let a = eng.submit(&p, 6, None).unwrap();
        fault::arm(fault::STEP_PANIC, 3);
        let (done, _) = drain(&mut eng);
        fault::disarm_all();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, a);
        assert_eq!(done[0].finish, FinishReason::Failed);
        assert_eq!(done[0].tokens.len(), 2, "two steps decoded before the panic");
        assert_eq!(eng.restarts(), 0, "single-lane fault must not restart");
        assert!(eng.alive());
        assert_eq!(eng.kv_bytes(), 0, "failed lane's KV released");
        // The engine keeps serving, bit-identically.
        eng.submit(&p, 6, None).unwrap();
        let (done, _) = drain(&mut eng);
        assert_eq!(done[0].finish, FinishReason::Length);
        assert_eq!(done[0].tokens, want);
    }

    #[test]
    fn admission_panic_spares_in_flight_lanes() {
        let m = model();
        let ps = prompts(&m, 2, 5);
        let want0 = reference(&m, &ps[0], 8);
        let cfg = ServeConfig { max_batch: 1, max_queued: 8, ..ServeConfig::default() };
        let mut eng = SupervisedEngine::new(&m, cfg);
        let a = eng.submit(&ps[0], 8, None).unwrap();
        eng.step(); // `a` holds the lane
        let b = eng.submit(&ps[1], 8, None).unwrap();
        // `b` is admitted only after `a` finishes; make its admission panic.
        fault::arm(fault::PREFILL_PANIC, 1);
        let (done, _) = drain(&mut eng);
        fault::disarm_all();
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert_eq!(fa.finish, FinishReason::Length);
        assert_eq!(fa.tokens, want0, "in-flight lane survives admission fault");
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert_eq!(fb.finish, FinishReason::Failed);
        assert_eq!(eng.restarts(), 0);
    }

    #[test]
    fn multi_lane_panic_fail_fast_restarts_and_keeps_queued() {
        let m = model();
        let ps = prompts(&m, 3, 7);
        let want2 = reference(&m, &ps[2], 5);
        let cfg = ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() };
        let mut eng = SupervisedEngine::new(&m, cfg);
        let a = eng.submit(&ps[0], 40, None).unwrap();
        let b = eng.submit(&ps[1], 40, None).unwrap();
        let c = eng.submit(&ps[2], 5, None).unwrap();
        fault::arm(fault::STEP_PANIC, 2);
        let (done, _) = drain(&mut eng);
        fault::disarm_all();
        assert_eq!(eng.restarts(), 1);
        assert!(eng.alive());
        for id in [a, b] {
            let f = done.iter().find(|f| f.id == id).unwrap();
            assert_eq!(f.finish, FinishReason::Failed, "active lanes fail fast");
        }
        let fc = done.iter().find(|f| f.id == c).unwrap();
        assert_eq!(fc.finish, FinishReason::Length, "queued request survives restart");
        assert_eq!(fc.tokens, want2);
        assert_eq!(eng.kv_bytes(), 0);
    }

    #[test]
    fn requeue_policy_replays_without_duplicate_tokens() {
        let m = model();
        let ps = prompts(&m, 3, 13);
        let gens = [6usize, 4, 5];
        let cfg = ServeConfig {
            max_batch: 2,
            max_queued: 8,
            restart_policy: RestartPolicy::Requeue,
            ..ServeConfig::default()
        };
        let mut eng = SupervisedEngine::new(&m, cfg);
        for (p, &g) in ps.iter().zip(&gens) {
            eng.submit(p, g, None).unwrap();
        }
        fault::arm(fault::STEP_PANIC, 3);
        let (done, streamed) = drain(&mut eng);
        fault::disarm_all();
        assert_eq!(eng.restarts(), 1);
        assert_eq!(done.len(), 3);
        for (i, fr) in done.iter().enumerate() {
            assert_eq!(fr.finish, FinishReason::Length, "request {i} must complete");
            assert_eq!(fr.tokens, reference(&m, &ps[i], gens[i]), "request {i} diverged");
            assert_eq!(
                streamed[&fr.id], fr.tokens,
                "request {i}: replay suppression must hand out each token exactly once"
            );
        }
    }

    #[test]
    fn restart_budget_exhaustion_kills_the_engine() {
        let m = model();
        let ps = prompts(&m, 2, 21);
        let cfg = ServeConfig {
            max_batch: 2,
            max_queued: 8,
            max_engine_restarts: 0,
            ..ServeConfig::default()
        };
        let mut eng = SupervisedEngine::new(&m, cfg);
        eng.submit(&ps[0], 40, None).unwrap();
        eng.submit(&ps[1], 40, None).unwrap();
        fault::arm(fault::STEP_PANIC, 2);
        let (done, _) = drain(&mut eng);
        fault::disarm_all();
        assert!(!eng.alive(), "budget 0 means the first restart is fatal");
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|f| f.finish == FinishReason::Failed));
        assert!(eng.submit(&ps[0], 4, None).is_err(), "dead engine refuses work");
        assert!(!eng.has_work());
        assert!(eng.step().is_empty());
    }

    #[test]
    fn kv_pressure_preempts_youngest_and_replays_bit_identically() {
        // Geometry: one layer, two heads of dim 8 → a 64-position KV
        // chunk is 8 KiB. A (gen 150) and B (gen 100) both fit at
        // admission, but their combined page growth crosses the high
        // watermark mid-decode: the supervisor must preempt B (youngest),
        // deallocate its pages, and requeue it — B then waits for A to
        // drain and completes bit-identically, every token seen once.
        use crate::cfg::ModelConfig;
        let cfg = ModelConfig {
            name: "preempt-probe".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            rope_theta: 10000.0,
        };
        let ps = ParamStore::init(&cfg, &mut Rng::new(0));
        let m = NativeModel::from_params(&ps);
        let (p_a, p_b) = (vec![1u32, 2], vec![3u32, 4]);
        let (want_a, want_b) = (reference(&m, &p_a, 150), reference(&m, &p_b, 100));
        let budget = 32 * 1024; // 4 chunks: A peaks at 3, B holds 2
        let scfg = ServeConfig {
            max_batch: 2,
            max_queued: 8,
            kv_budget_bytes: budget,
            restart_policy: RestartPolicy::Requeue,
            ..ServeConfig::default()
        };
        let mut eng = SupervisedEngine::new(&m, scfg);
        let a = eng.submit(&p_a, 150, None).unwrap();
        eng.step();
        let b = eng.submit(&p_b, 100, None).unwrap();

        let mut done = Vec::new();
        let mut streamed: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut peak = eng.kv_allocated_bytes();
        let safety = Instant::now() + Duration::from_secs(30);
        while eng.has_work() && Instant::now() < safety {
            done.extend(eng.step());
            peak = peak.max(eng.kv_allocated_bytes());
            for &(id, tok) in eng.step_tokens() {
                streamed.entry(id).or_default().push(tok);
            }
        }
        assert_eq!(eng.preemptions(), 1, "combined growth must force one preemption");
        assert_eq!(eng.restarts(), 0, "preemption is not a restart");
        assert!(peak <= budget, "kv_allocated_bytes {peak} exceeded budget {budget}");
        assert_eq!(done.len(), 2);
        let fa = done.iter().find(|f| f.id == a).unwrap();
        assert_eq!(fa.finish, FinishReason::Length);
        assert_eq!(fa.tokens, want_a, "survivor lane diverged");
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert_eq!(fb.finish, FinishReason::Length, "preempted request must complete");
        assert!(!fb.degraded, "requeued under an empty engine, not browned out");
        assert_eq!(fb.tokens, want_b, "preempted request diverged after replay");
        assert_eq!(
            streamed[&b], want_b,
            "replay suppression must hand out each of B's tokens exactly once"
        );
    }

    #[test]
    fn bank_engine_routes_precisions_and_survives_restart() {
        // Two different models under bank labels 2 and 4 (weights differ,
        // so streams prove which model served a lane). An unattributable
        // two-lane panic forces a restart; the Requeue policy must rebuild
        // the bank scheduler and replay each lane through the SAME bank
        // model it was serving at, every token seen exactly once.
        let (cfg, _) = preset("tiny");
        let m4 = NativeModel::from_params(&ParamStore::init(&cfg, &mut Rng::new(0)));
        let m2 = NativeModel::from_params(&ParamStore::init(&cfg, &mut Rng::new(1)));
        let scfg = ServeConfig {
            max_batch: 2,
            max_queued: 8,
            restart_policy: RestartPolicy::Requeue,
            ..ServeConfig::default()
        };
        let mut eng = SupervisedEngine::with_bank(vec![(2, &m2), (4, &m4)], scfg, 4, 2);
        assert_eq!(eng.precisions(), vec![2, 4]);
        assert_eq!((eng.default_precision(), eng.floor_precision()), (4, 2));
        let a = eng.submit(&[1, 2], 6, None).unwrap();
        let b = eng.submit_prec(&[1, 2], 6, None, Some(2)).unwrap();
        fault::arm(fault::STEP_PANIC, 2);
        let (done, streamed) = drain(&mut eng);
        fault::disarm_all();
        assert_eq!(eng.restarts(), 1, "two-lane panic is unattributable");
        assert_eq!(eng.precision_downshifts(), 0, "no pressure, no downshift");
        let fa = done.iter().find(|f| f.id == a).unwrap();
        let fb = done.iter().find(|f| f.id == b).unwrap();
        assert_eq!((fa.precision, fb.precision), (4, 2));
        assert_eq!(fa.finish, FinishReason::Length);
        assert_eq!(fb.finish, FinishReason::Length);
        assert_eq!(fa.tokens, reference(&m4, &[1, 2], 6), "default lane replays on label 4");
        assert_eq!(fb.tokens, reference(&m2, &[1, 2], 6), "pinned lane replays on label 2");
        assert_eq!(streamed[&a], fa.tokens, "replay suppression on the restarted bank");
        assert_eq!(streamed[&b], fb.tokens);
    }

    #[test]
    fn per_request_timeout_flows_through_supervision() {
        let m = model();
        let mut eng = SupervisedEngine::new(&m, ServeConfig::default());
        eng.submit(&[1, 2, 3], 1_000_000, Some(30)).unwrap();
        let (done, _) = drain(&mut eng);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Timeout);
        assert!(done[0].tokens.len() < 1_000_000);
        assert_eq!(eng.kv_bytes(), 0);
    }
}
