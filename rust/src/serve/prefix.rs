//! Radix prefix index over shared KV pages — the scheduler's prompt cache.
//!
//! Most production traffic shares a long system-prompt / few-shot-template
//! prefix, so the engine used to re-prefill and re-store identical KV pages
//! for every request. This index keys *page-aligned* 64-token prompt chunks
//! ([`KV_PAGE_POS`]) to the refcounted KV pages a finished lane computed
//! for them: admission walks the trie chunk-by-chunk, maps every matched
//! chunk's pages read-only into the new lane
//! ([`DecodeState::borrow_prefix_chunk`]), and chunked prefill starts
//! *after* the cached positions — a warm-template hit skips its prefill
//! compute entirely and TTFT drops to near-decode latency.
//!
//! Structure: a chunk trie. Each edge is labelled by exactly one
//! [`KV_PAGE_POS`]-token chunk of prompt ids, and the node it leads to
//! holds that chunk's K and V pages (one per `(layer, head)` list, shared
//! by refcount with every borrower). The longest-cached-prefix walk is
//! `O(prefix pages)` and allocation-free — `HashMap<Vec<u32>, _>` lookups
//! borrow the prompt slice (`Vec<u32>: Borrow<[u32]>`) — so admission
//! stays off the heap on the warm path. Donation (insertions) happens only
//! when a lane finishes, off the steady-state decode path.
//!
//! Eviction is LRU-leaf-first and refcount-aware: only nodes whose pages
//! nobody else references (`strong_count == 1`) are trimmed under KV
//! pressure, and the governance ladder trims them *before* any brownout,
//! preemption, or 429 — cached-but-unreferenced pages are the cheapest
//! memory in the engine. [`PrefixIndex::clear`] (the `prefix-evict` chaos
//! site) force-drops every node regardless; dependent lanes survive
//! because their own page references keep the storage alive.
//!
//! Correctness: greedy decode is deterministic, so the pages a donor
//! computed for a prompt chunk are bit-identical to the pages any later
//! lane would compute for the same chunk (per dtype — f16 stores round the
//! same way every time). Mapping them by reference therefore preserves the
//! house rule: outputs are bit-identical with the cache on or off.

use std::collections::HashMap;

use crate::model::attention::Page;
use crate::model::{DecodeState, KV_PAGE_POS};

/// One trie node: the KV pages of the chunk leading here, plus children
/// keyed by the next 64-token chunk. The root holds no pages.
struct Node {
    /// Outgoing edges: exactly-[`KV_PAGE_POS`]-token chunks.
    children: HashMap<Vec<u32>, Node>,
    /// This chunk's key pages, one per `(layer, head)` list (empty at the
    /// root).
    keys: Vec<Page>,
    /// This chunk's value pages, one per `(layer, head)` list.
    vals: Vec<Page>,
    /// Logical timestamp of the last lookup or donation touching this
    /// node (LRU eviction order).
    last_used: u64,
}

impl Node {
    fn new(keys: Vec<Page>, vals: Vec<Page>, now: u64) -> Self {
        Node { children: HashMap::new(), keys, vals, last_used: now }
    }

    /// No lane or donor holds these pages anymore: every page reference
    /// is ours alone, so dropping the node actually frees the memory.
    fn unreferenced(&mut self) -> bool {
        self.keys.iter_mut().chain(self.vals.iter_mut()).all(Page::is_unique)
    }
}

/// The prefix cache: chunk trie + hit counters (see module docs).
pub(crate) struct PrefixIndex {
    root: Node,
    /// KV pages held by the index (2 × lists per node).
    pages: usize,
    /// Monotonic logical clock driving LRU order.
    clock: u64,
    /// Admissions that matched at least one cached chunk.
    hits: u64,
    /// Prompt positions whose prefill compute was skipped, cumulative.
    tokens_saved: u64,
}

impl PrefixIndex {
    pub fn new() -> Self {
        PrefixIndex {
            root: Node::new(Vec::new(), Vec::new(), 0),
            pages: 0,
            clock: 0,
            hits: 0,
            tokens_saved: 0,
        }
    }

    /// Admissions that matched at least one cached chunk.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cumulative prompt positions skipped by prefix hits.
    pub fn tokens_saved(&self) -> u64 {
        self.tokens_saved
    }

    /// KV pages currently held by the index.
    pub fn cached_pages(&self) -> usize {
        self.pages
    }

    /// Longest cached page-aligned prefix of `prompt`, in positions,
    /// without touching the trie's LRU state. Admission uses this to price
    /// a request (shared pages are charged once, to the cache) before
    /// committing to admit it. Allocation-free.
    pub fn matched_positions(&self, prompt: &[u32]) -> usize {
        let max_chunks = prompt.len().saturating_sub(1) / KV_PAGE_POS;
        let mut node = &self.root;
        let mut matched = 0;
        while matched < max_chunks {
            let chunk = &prompt[matched * KV_PAGE_POS..(matched + 1) * KV_PAGE_POS];
            match node.children.get(chunk) {
                Some(child) => {
                    node = child;
                    matched += 1;
                }
                None => break,
            }
        }
        matched * KV_PAGE_POS
    }

    /// Walk the longest cached prefix of `prompt` and map every matched
    /// chunk's pages into `state` (which must be fresh). Returns the
    /// number of cached positions mapped; prefill then starts after them.
    /// At least one prompt token always remains un-cached — the last
    /// prompt token must run through the model to produce first logits —
    /// so the walk is capped at `(len - 1) / KV_PAGE_POS` chunks.
    /// Allocation-free (refcount bumps into the state's pre-sized lists).
    pub fn lookup_into(&mut self, prompt: &[u32], state: &mut DecodeState) -> usize {
        let max_chunks = prompt.len().saturating_sub(1) / KV_PAGE_POS;
        self.clock += 1;
        let now = self.clock;
        let mut node = &mut self.root;
        let mut matched = 0;
        while matched < max_chunks {
            let chunk = &prompt[matched * KV_PAGE_POS..(matched + 1) * KV_PAGE_POS];
            match node.children.get_mut(chunk) {
                Some(child) => {
                    child.last_used = now;
                    state.borrow_prefix_chunk(&child.keys, &child.vals);
                    node = child;
                    matched += 1;
                }
                None => break,
            }
        }
        if matched > 0 {
            self.hits += 1;
            self.tokens_saved += (matched * KV_PAGE_POS) as u64;
        }
        matched * KV_PAGE_POS
    }

    /// Donate the full prompt chunks a finished lane computed: each chunk
    /// not yet in the trie gets the lane's pages by reference (no copy —
    /// the lane's release then drops its own refs and the index keeps the
    /// pages alive). `stored_pos` caps donation at what the lane actually
    /// wrote (a lane that failed early may not have finished its prompt).
    pub fn donate(&mut self, prompt: &[u32], stored_pos: usize, state: &DecodeState) {
        let chunks = prompt.len().min(stored_pos) / KV_PAGE_POS;
        if chunks == 0 {
            return;
        }
        self.clock += 1;
        let now = self.clock;
        let mut node = &mut self.root;
        for c in 0..chunks {
            let chunk = &prompt[c * KV_PAGE_POS..(c + 1) * KV_PAGE_POS];
            if !node.children.contains_key(chunk) {
                let (keys, vals) = state.clone_prefix_chunk(c);
                self.pages += keys.len() + vals.len();
                node.children.insert(chunk.to_vec(), Node::new(keys, vals, now));
            }
            node = node.children.get_mut(chunk).unwrap();
            node.last_used = now;
        }
    }

    /// Evict unreferenced leaves, least-recently-used first, until at most
    /// `max_pages` pages remain cached (referenced nodes are pinned by
    /// their borrowers and never trimmed here). Returns pages evicted.
    pub fn trim_to(&mut self, max_pages: usize) -> usize {
        let before = self.pages;
        while self.pages > max_pages {
            let Some(lru) = Self::lru_evictable_leaf(&mut self.root) else { break };
            let freed = Self::remove_leaf(&mut self.root, lru).expect("leaf found above");
            self.pages -= freed;
        }
        before - self.pages
    }

    /// `last_used` of the least-recently-used evictable leaf, if any.
    fn lru_evictable_leaf(node: &mut Node) -> Option<u64> {
        let mut best: Option<u64> = None;
        for child in node.children.values_mut() {
            let cand = if child.children.is_empty() {
                if child.unreferenced() {
                    Some(child.last_used)
                } else {
                    None
                }
            } else {
                Self::lru_evictable_leaf(child)
            };
            best = match (best, cand) {
                (Some(b), Some(c)) => Some(b.min(c)),
                (b, c) => b.or(c),
            };
        }
        best
    }

    /// Remove the (unique) evictable leaf stamped `last_used`; returns the
    /// number of pages it held.
    fn remove_leaf(node: &mut Node, last_used: u64) -> Option<usize> {
        let mut hit_key: Option<Vec<u32>> = None;
        for (key, child) in node.children.iter_mut() {
            if child.children.is_empty() && child.last_used == last_used && child.unreferenced()
            {
                hit_key = Some(key.clone());
                break;
            }
            if let Some(freed) = Self::remove_leaf(child, last_used) {
                return Some(freed);
            }
        }
        let key = hit_key?;
        let child = node.children.remove(&key).expect("key found above");
        Some(child.keys.len() + child.vals.len())
    }

    /// Drop every cached node unconditionally (the `prefix-evict` chaos
    /// site). Lanes currently borrowing cached pages are unaffected: their
    /// own references keep the page storage alive, so a dependent
    /// mid-decode lane completes bit-identically.
    pub fn clear(&mut self) {
        self.root.children.clear();
        self.pages = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DecodeState;

    fn filled_state(n_layers: usize, h: usize, hd: usize, n_pos: usize) -> DecodeState {
        let d = h * hd;
        let mut st = DecodeState::new(n_layers, h, hd);
        while st.pos < n_pos {
            let p = st.pos;
            let k: Vec<f32> = (0..d).map(|i| (p * d + i) as f32).collect();
            let v: Vec<f32> = (0..d).map(|i| -((p * d + i) as f32)).collect();
            st.append_kv(0, &k, &v);
            st.pos += 1;
        }
        st
    }

    fn prompt(len: usize, salt: u32) -> Vec<u32> {
        (0..len as u32).map(|i| i * 3 + salt).collect()
    }

    #[test]
    fn donate_then_lookup_maps_page_aligned_prefix() {
        let (h, hd) = (2usize, 8usize);
        let mut idx = PrefixIndex::new();
        // 130-position prompt: two full chunks donatable; lookups on the
        // same prompt can use both (2 * 64 = 128 <= 129 = len - 1).
        let p = prompt(130, 1);
        let donor = filled_state(1, h, hd, 130);
        idx.donate(&p, donor.pos, &donor);
        assert_eq!(idx.cached_pages(), 2 * 2 * h, "2 chunks x (K+V) x lists");

        let mut lane = DecodeState::new(1, h, hd);
        let cached = idx.lookup_into(&p, &mut lane);
        assert_eq!(cached, 128, "two page-aligned chunks hit");
        assert_eq!(lane.pos, 128);
        assert_eq!(lane.borrowed_prefix_pages(), 2);
        assert_eq!(idx.hits(), 1);
        assert_eq!(idx.tokens_saved(), 128);

        // A diverging prompt shares only the first chunk.
        let mut other = p.clone();
        other[100] ^= 1;
        let mut lane2 = DecodeState::new(1, h, hd);
        assert_eq!(idx.lookup_into(&other, &mut lane2), 64);

        // A prompt of exactly one page can use no cached chunk (its last
        // token must still run to produce logits).
        let mut lane3 = DecodeState::new(1, h, hd);
        assert_eq!(idx.lookup_into(&p[..64], &mut lane3), 0);
        assert_eq!(idx.hits(), 2, "a zero-chunk walk is not a hit");
    }

    #[test]
    fn matched_positions_probe_agrees_with_lookup() {
        let (h, hd) = (2usize, 8usize);
        let mut idx = PrefixIndex::new();
        let p = prompt(200, 5);
        let donor = filled_state(1, h, hd, 200);
        idx.donate(&p, donor.pos, &donor);
        assert_eq!(idx.matched_positions(&p), 192, "3 full chunks cached and usable");
        let mut lane = DecodeState::new(1, h, hd);
        assert_eq!(idx.lookup_into(&p, &mut lane), idx.matched_positions(&p));
        assert_eq!(idx.matched_positions(&prompt(200, 99)), 0);
    }

    #[test]
    fn donation_is_idempotent_and_capped_by_stored_positions() {
        let (h, hd) = (2usize, 8usize);
        let mut idx = PrefixIndex::new();
        let p = prompt(130, 2);
        let donor = filled_state(1, h, hd, 130);
        idx.donate(&p, donor.pos, &donor);
        let pages = idx.cached_pages();
        idx.donate(&p, donor.pos, &donor);
        assert_eq!(idx.cached_pages(), pages, "re-donation must not duplicate");
        // A lane that only stored 70 positions donates one chunk.
        let mut idx2 = PrefixIndex::new();
        let partial = filled_state(1, h, hd, 70);
        idx2.donate(&p, partial.pos, &partial);
        assert_eq!(idx2.cached_pages(), 2 * h);
        // Too short for even one chunk: nothing to donate.
        let mut idx3 = PrefixIndex::new();
        let short = filled_state(1, h, hd, 10);
        idx3.donate(&p[..10], short.pos, &short);
        assert_eq!(idx3.cached_pages(), 0);
    }

    #[test]
    fn trim_evicts_lru_unreferenced_leaves_first() {
        let (h, hd) = (1usize, 4usize);
        let per_chunk = 2 * h; // K+V pages per chunk
        let mut idx = PrefixIndex::new();
        let p_old = prompt(65, 1);
        let p_new = prompt(65, 2);
        let donor_old = filled_state(1, h, hd, 65);
        let donor_new = filled_state(1, h, hd, 65);
        idx.donate(&p_old, donor_old.pos, &donor_old);
        idx.donate(&p_new, donor_new.pos, &donor_new);
        assert_eq!(idx.cached_pages(), 2 * per_chunk);
        // While the donors are alive their refs pin both nodes.
        assert_eq!(idx.trim_to(0), 0, "donor refs pin the nodes");
        drop(donor_old);
        drop(donor_new);
        // One page over target: the older donation goes first.
        let evicted = idx.trim_to(per_chunk);
        assert_eq!(evicted, per_chunk);
        let mut lane = DecodeState::new(1, h, hd);
        assert_eq!(idx.lookup_into(&p_old, &mut lane), 0, "older entry evicted");
        let mut lane2 = DecodeState::new(1, h, hd);
        assert_eq!(idx.lookup_into(&p_new, &mut lane2), 64, "newer entry survives");

        // `lane2` still borrows p_new's pages: the node is referenced and
        // must be pinned even under a trim-to-zero.
        assert_eq!(idx.trim_to(0), 0, "referenced nodes are pinned");
        drop(lane2);
        assert_eq!(idx.trim_to(0), per_chunk, "unreferenced again: evictable");
        assert_eq!(idx.cached_pages(), 0);
    }

    #[test]
    fn trim_evicts_leaves_before_their_parents() {
        let (h, hd) = (1usize, 4usize);
        let per_chunk = 2 * h;
        let mut idx = PrefixIndex::new();
        let p = prompt(200, 3);
        let donor = filled_state(1, h, hd, 200);
        idx.donate(&p, donor.pos, &donor); // chunks at depth 1, 2, 3
        assert_eq!(idx.cached_pages(), 3 * per_chunk);
        drop(donor);
        idx.trim_to(2 * per_chunk);
        // The deepest chunk is the only leaf; the 128-position prefix
        // must survive and still serve hits.
        let mut lane = DecodeState::new(1, h, hd);
        assert_eq!(idx.lookup_into(&p, &mut lane), 128);
    }

    #[test]
    fn clear_drops_everything_but_borrowers_keep_their_pages() {
        let (h, hd) = (1usize, 4usize);
        let mut idx = PrefixIndex::new();
        let p = prompt(65, 4);
        let donor = filled_state(1, h, hd, 65);
        idx.donate(&p, donor.pos, &donor);
        let mut lane = DecodeState::new(1, h, hd);
        assert_eq!(idx.lookup_into(&p, &mut lane), 64);
        idx.clear();
        assert_eq!(idx.cached_pages(), 0);
        assert_eq!(idx.matched_positions(&p), 0);
        // The borrower still reads its pages (they are alive through its
        // own refs): kv accounting still sees a borrowed page.
        assert_eq!(lane.pos, 64);
        assert_eq!(lane.borrowed_prefix_pages(), 1);
        assert!(lane.kv_allocated_bytes() > 0);
    }
}
